"""AIMC noise-injection unit (paper SS VI): fresh noise each round,
pristine weights untouched, statistics in the modeled band."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.aimc import (
    AIMCNoiseModel,
    NoiseInjectionUnit,
    inject_noise_float,
    snr_db,
)
from repro.core.quant import QTensor, quantize


def test_fresh_noise_each_round(key):
    w = {"layer": {"w": jax.random.normal(key, (32, 32))}}
    niu = NoiseInjectionUnit(w, AIMCNoiseModel())
    a = niu.refresh(jax.random.PRNGKey(1))
    b = niu.refresh(jax.random.PRNGKey(2))
    assert float(jnp.max(jnp.abs(a["layer"]["w"] - b["layer"]["w"]))) > 0
    # pristine copy untouched
    np.testing.assert_array_equal(
        np.asarray(niu.pristine["layer"]["w"]), np.asarray(w["layer"]["w"])
    )


def test_same_key_is_deterministic(key):
    w = {"w": jax.random.normal(key, (16, 16))}
    niu = NoiseInjectionUnit(w, AIMCNoiseModel())
    a = niu.refresh(jax.random.PRNGKey(7))
    b = niu.refresh(jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))


def test_noise_statistics_match_model(key):
    """Programming-noise std ~ scale * (0.25|w| + 0.05 w_max) at the
    large-sample limit (drift/read disabled)."""
    model = AIMCNoiseModel(prog_noise_scale=0.1, read_noise_scale=0.0, drift_nu=0.0)
    w = jnp.ones((400, 400))
    noisy = inject_noise_float(w, key, model)
    err = np.asarray(noisy - w)
    expected_sigma = 0.1 * (0.25 * 1.0 + 0.05 * 1.0)
    assert err.std() == pytest.approx(expected_sigma, rel=0.05)
    assert abs(err.mean()) < 3 * expected_sigma / np.sqrt(err.size) * 2


def test_drift_shrinks_weights(key):
    model = AIMCNoiseModel(prog_noise_scale=0.0, read_noise_scale=0.0,
                           drift_nu=0.06, t_read=3600.0, t0=20.0)
    w = jnp.ones((64, 64)) * 2.0
    noisy = inject_noise_float(w, key, model)
    factor = (3600.0 / 20.0) ** (-0.06)
    np.testing.assert_allclose(np.asarray(noisy), 2.0 * factor, rtol=1e-6)
    assert factor < 1.0


def test_qtensor_leaves_requantized_on_same_grid(key):
    wq = quantize(jax.random.normal(key, (32, 32)))
    niu = NoiseInjectionUnit({"w": wq}, AIMCNoiseModel())
    out = niu.refresh(jax.random.PRNGKey(3))
    assert isinstance(out["w"], QTensor)
    # exponent (the power-of-two grid) unchanged -- NIU overwrites payload
    assert int(out["w"].exp) == int(wq.exp)
    assert bool(jnp.any(out["w"].q != wq.q))


def test_biases_and_vectors_stay_digital(key):
    params = {
        "w": jax.random.normal(key, (8, 8)),
        "bias": jnp.ones((8,)),
        "norm_scale": jnp.ones((8,)),
    }
    niu = NoiseInjectionUnit(params, AIMCNoiseModel())
    out = niu.refresh(jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out["bias"]), np.asarray(params["bias"]))
    np.testing.assert_array_equal(
        np.asarray(out["norm_scale"]), np.asarray(params["norm_scale"])
    )
    assert bool(jnp.any(out["w"] != params["w"]))


def test_snr_decreases_with_noise_scale(key):
    w = jax.random.normal(key, (64, 64))
    lo = inject_noise_float(w, key, AIMCNoiseModel(prog_noise_scale=0.02))
    hi = inject_noise_float(w, key, AIMCNoiseModel(prog_noise_scale=0.4))
    assert float(snr_db(w, lo)) > float(snr_db(w, hi))


def test_disabled_model_detected():
    assert not AIMCNoiseModel(0.0, 0.0, 0.0).enabled()
    assert AIMCNoiseModel().enabled()
