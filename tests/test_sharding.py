"""Logical-axis sharding rules: resolution, divisibility dropping, and the
named rule-sets used by the dry-run."""
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import abstract_mesh
from repro.parallel.sharding import (
    NAMED_RULES,
    RULES_DP_ONLY,
    RULES_FSDP_TP,
    resolve_spec,
)

MESH_1POD = abstract_mesh((16, 16), ("data", "model"))
MESH_2POD = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_batch_shards_over_pod_and_data():
    spec = resolve_spec(("batch", None), MESH_2POD, RULES_FSDP_TP)
    assert spec == P(("pod", "data"))
    spec1 = resolve_spec(("batch", None), MESH_1POD, RULES_FSDP_TP)
    assert spec1 == P("data")            # pod axis absent -> dropped


def test_ff_shards_over_model():
    spec = resolve_spec((None, "ff"), MESH_1POD, RULES_FSDP_TP)
    assert spec == P(None, "model")


def test_divisibility_drops_axis():
    # dim 24 not divisible by 16 -> axis dropped
    spec = resolve_spec(("ff",), MESH_1POD, RULES_FSDP_TP, dims=(24,))
    assert spec == P()
    spec2 = resolve_spec(("ff",), MESH_1POD, RULES_FSDP_TP, dims=(32,))
    assert spec2 == P("model")


def test_no_axis_reuse_across_dims():
    """The same mesh axis can appear at most once in a PartitionSpec."""
    spec = resolve_spec(("ff", "vocab"), MESH_1POD, RULES_FSDP_TP)
    # both map to 'model'; second must be dropped
    flat = []
    for part in spec:
        if part is None:
            continue
        flat.extend(part if isinstance(part, tuple) else [part])
    assert len(flat) == len(set(flat))


def test_unknown_logical_axis_is_replicated():
    spec = resolve_spec(("nonexistent-axis",), MESH_1POD, RULES_FSDP_TP)
    assert spec == P()


def test_dp_only_rules_put_batch_on_everything():
    spec = resolve_spec(("batch",), MESH_2POD, RULES_DP_ONLY)
    assert spec == P(("pod", "data", "model"))


def test_partial_divisibility_keeps_prefix():
    """batch -> (pod, data): dim 32 divisible by pod(2)*data(16)=32 keeps
    both; dim 16 keeps only a prefix that divides."""
    spec = resolve_spec(("batch",), MESH_2POD, RULES_FSDP_TP, dims=(32,))
    assert spec == P(("pod", "data"))
    spec2 = resolve_spec(("batch",), MESH_2POD, RULES_FSDP_TP, dims=(2,))
    # jax >= 0.5 normalizes the singleton tuple to the bare name
    assert spec2 in (P(("pod",)), P("pod"))


def test_named_rules_registry():
    assert set(NAMED_RULES) >= {"fsdp_tp", "dp_only", "tp_heavy"}


def test_trailing_nones_trimmed():
    spec = resolve_spec(("batch", None, None), MESH_1POD, RULES_FSDP_TP)
    assert spec == P("data")
