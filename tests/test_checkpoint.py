"""Checkpointing: atomicity, retention, async error surfacing, restore."""
import json
import shutil
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, latest_step, restore, save


def _state(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {
        "params": {"w": jax.random.normal(k1, (8, 4)) * scale,
                   "b": jnp.zeros((4,))},
        "opt": {"m": jax.random.normal(k2, (8, 4)), "step": jnp.int32(3)},
    }


def test_save_restore_roundtrip(tmp_path, key):
    state = _state(key)
    save(tmp_path, 10, state, extra={"next_step": 10})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, extra = restore(tmp_path, like)
    assert extra == {"next_step": 10}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_ignores_incomplete(tmp_path, key):
    save(tmp_path, 5, _state(key))
    # a crashed write: directory without manifest
    (tmp_path / "step_00000009").mkdir()
    (tmp_path / "step_00000009" / "arrays.npz").write_bytes(b"junk")
    assert latest_step(tmp_path) == 5


def test_shape_mismatch_rejected(tmp_path, key):
    save(tmp_path, 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore(tmp_path, {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)})


def test_missing_key_rejected(tmp_path, key):
    save(tmp_path, 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(KeyError):
        restore(tmp_path, {"other": jax.ShapeDtypeStruct((4, 4), jnp.float32)})


def test_retention_gc(tmp_path, key):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(key, scale=s))
    steps = sorted(
        int(d.name.split("_")[1]) for d in tmp_path.iterdir()
        if d.name.startswith("step_")
    )
    assert steps == [3, 4]


def test_async_write_and_wait(tmp_path, key):
    mgr = CheckpointManager(tmp_path, keep=3, async_write=True)
    mgr.save(7, _state(key))
    mgr.wait()
    assert mgr.latest_step() == 7


def test_async_error_surfaces(tmp_path, key):
    mgr = CheckpointManager(tmp_path / "sub", async_write=True)
    # poison the target: a *file* where the directory must go
    (tmp_path / "sub").write_text("not a dir")
    mgr.save(1, _state(key))
    with pytest.raises(Exception):
        mgr.wait()


def test_overwrite_same_step_is_atomic(tmp_path, key):
    save(tmp_path, 3, {"w": jnp.zeros((2,))})
    save(tmp_path, 3, {"w": jnp.ones((2,))})
    restored, _ = restore(
        tmp_path, {"w": jax.ShapeDtypeStruct((2,), jnp.float32)}, step=3
    )
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.ones((2,)))
