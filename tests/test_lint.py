"""The linter's own tests (DESIGN.md SS11).

Three layers: fixture pairs per rule (the bad file fires, the good
file is quiet), waiver semantics (justified waivers waive, bare ones
do not), and the self-check -- zero unwaived findings on the real
``src``/``tests`` tree, which is exactly the CI gate."""
import pathlib
import textwrap

import pytest

from repro.analysis.lint import RULES, FileSource, Project, lint_paths, main
from repro.analysis.lint.core import resolve_waivers

FIXTURES = pathlib.Path(__file__).parent / "lint_fixtures"
REPO = pathlib.Path(__file__).parent.parent

RULE_FIXTURES = [
    ("RPL001", "donation_after_use"),
    ("RPL002", "eager_host_op"),
    ("RPL003", "hardcoded_interpret"),
    ("RPL004", "unlocked_shared_write"),
    ("RPL005", "jit_missing_static"),
]


def _lint_file(path, rule_id):
    return [
        f
        for f in lint_paths([str(path)], exclude_parts=())
        if f.rule_id == rule_id
    ]


@pytest.mark.parametrize("rule_id,stem", RULE_FIXTURES)
def test_bad_fixture_fires(rule_id, stem):
    findings = _lint_file(FIXTURES / f"{stem}_bad.py", rule_id)
    assert findings, f"{rule_id} silent on {stem}_bad.py"
    assert all(not f.waived for f in findings)
    # findings carry precise spans
    assert all(f.line > 0 for f in findings)


@pytest.mark.parametrize("rule_id,stem", RULE_FIXTURES)
def test_good_fixture_quiet(rule_id, stem):
    findings = _lint_file(FIXTURES / f"{stem}_good.py", rule_id)
    assert findings == [], [f.format() for f in findings]


def test_donation_fixture_flags_both_donated_names():
    # the PR 6 reconstruction: cache AND state are read after donation
    findings = _lint_file(FIXTURES / "donation_after_use_bad.py", "RPL001")
    flagged = {f.message.split("'")[1] for f in findings}
    assert flagged == {"self.cache", "self.state"}


def test_eager_op_found_through_call_graph():
    # the np.asarray lives in a helper the round calls, not in the
    # root function itself
    findings = _lint_file(FIXTURES / "eager_host_op_bad.py", "RPL002")
    assert any("_tick" in f.message for f in findings)
    assert any("decode_round" in f.message for f in findings)


def _lint_source(source, rule_id=None):
    file = FileSource("<mem>.py", source=textwrap.dedent(source))
    project = Project([file])
    out = []
    for rule in RULES:
        if rule_id is not None and rule.rule_id != rule_id:
            continue
        out.extend(rule.check(project))
    return out


WAIVABLE = """
    import numpy as np

    class R:
        def decode_round(self, pos):
            {comment}
            n = int(pos[0])
            return n
"""


def test_justified_waiver_waives():
    findings = _lint_source(
        WAIVABLE.format(
            comment="# lint: disable=RPL002 -- boundary sync by design"
        ),
        "RPL002",
    )
    assert len(findings) == 1
    assert findings[0].waived
    assert findings[0].waiver_note == "boundary sync by design"


def test_bare_waiver_does_not_waive():
    findings = _lint_source(
        WAIVABLE.format(comment="# lint: disable=RPL002"), "RPL002"
    )
    assert len(findings) == 1
    assert not findings[0].waived
    assert "missing justification" in findings[0].waiver_note


def test_waiver_by_slug_and_on_same_line():
    src = """
        import numpy as np

        class R:
            def decode_round(self, pos):
                n = int(pos[0])  # lint: disable=eager-host-op-in-hot-path -- drained above
                return n
    """
    findings = _lint_source(src, "RPL002")
    assert len(findings) == 1 and findings[0].waived


def test_waiver_for_other_rule_does_not_waive():
    findings = _lint_source(
        WAIVABLE.format(comment="# lint: disable=RPL001 -- wrong rule"),
        "RPL002",
    )
    assert len(findings) == 1
    assert not findings[0].waived


def test_self_check_repo_tree_is_clean():
    """The CI gate: zero unwaived findings on the real tree."""
    findings = lint_paths([str(REPO / "src"), str(REPO / "tests")])
    unwaived = [f for f in findings if not f.waived]
    assert unwaived == [], "\n".join(f.format() for f in unwaived)


def test_every_waiver_on_tree_is_justified():
    findings = lint_paths([str(REPO / "src"), str(REPO / "tests")])
    for f in findings:
        if f.waived:
            assert f.waiver_note, f.format()


def test_cli_exit_codes(capsys):
    bad = str(FIXTURES / "donation_after_use_bad.py")
    assert main([bad, "--include-fixtures"]) == 1
    out = capsys.readouterr().out
    assert "RPL001" in out and "unwaived" in out
    good = str(FIXTURES / "donation_after_use_good.py")
    assert main([good, "--include-fixtures"]) == 0


def test_cli_excludes_fixtures_by_default():
    # pointing the default gate at tests/ must not trip on the
    # deliberately-bad fixture corpus
    assert main([str(FIXTURES)]) == 0


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule.rule_id in out


def test_rule_table_is_the_documented_five():
    assert [r.rule_id for r in RULES] == [
        "RPL001", "RPL002", "RPL003", "RPL004", "RPL005"
    ]
    assert {r.slug for r in RULES} == {
        "donation-after-use",
        "eager-host-op-in-hot-path",
        "hardcoded-interpret",
        "unlocked-shared-write",
        "jit-missing-static",
    }
