"""repro.plan: the unified planning subsystem.

Identity against the reference scheduler (the incremental planner must be
bit-identical -- same windows, stalls, makespan), scheduler edge cases
(zero-exec tiles, capacity-exact tiles, deadlock reporting), multi-PU
partitioning (a K=2 pipeline must beat either single PU via FleetSim's
replacement API), and the content-hashed plan cache.
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pu import PU_1X, PU_2X, PUConfig, TileCost
from repro.core import scheduler as sched
from repro.core import simulator as sim
from repro.plan import (
    PlanCache,
    PartitionedPlan,
    SearchConfig,
    balance_layer_ranges,
    partition_gemms,
    plan,
    plan_key,
)
from repro.plan.engine import PlanEngine


def tiles_from(lists):
    return [TileCost(load_s=l, exec_s=e, mem_bytes=m) for l, e, m in lists]


# ------------------------------------------------ reference identity ------


@st.composite
def tile_lists(draw):
    n = draw(st.integers(1, 12))
    tiles = []
    for _ in range(n):
        tiles.append(
            TileCost(
                load_s=draw(st.floats(0.01, 10, allow_nan=False)),
                exec_s=draw(st.floats(0.01, 10, allow_nan=False)),
                mem_bytes=draw(st.integers(1, 50)),
            )
        )
    return tiles


def assert_same_schedule(ref: sched.Schedule, got: sched.Schedule):
    assert ref.feasible == got.feasible
    if not ref.feasible:
        return
    assert len(ref.tiles) == len(got.tiles)
    for a, b in zip(ref.tiles, got.tiles):
        assert a.window == b.window
        assert a.load_start == b.load_start
        assert a.load_end == b.load_end
        assert a.exec_start == b.exec_start
        assert a.exec_end == b.exec_end
        assert a.stall == b.stall
    assert ref.total_stall == got.total_stall
    assert ref.makespan == got.makespan


@settings(max_examples=60, deadline=None)
@given(
    tiles=tile_lists(),
    cap=st.integers(50, 200),
    exhaustive=st.booleans(),
)
def test_incremental_planner_matches_reference(tiles, cap, exhaustive):
    """Property: the incremental planner is bit-identical to the seed
    two-phase implementation on randomized tile sets."""
    ref = sched.reference_two_phase(tiles, cap, exhaustive=exhaustive)
    got = plan(tiles, cap, exhaustive=exhaustive).to_two_phase()
    assert_same_schedule(ref.baseline, got.baseline)
    assert_same_schedule(ref.adaptive, got.adaptive)


@settings(max_examples=30, deadline=None)
@given(tiles=tile_lists(), cap=st.integers(50, 200))
def test_bounded_scan_matches_reference(tiles, cap):
    ref = sched.reference_two_phase(tiles, cap, max_window_scan=2)
    got = plan(tiles, cap, max_window_scan=2).to_two_phase()
    assert_same_schedule(ref.adaptive, got.adaptive)


def test_wrapped_entry_points_route_through_plan():
    """two_phase / adaptive_schedule are thin wrappers over repro.plan and
    still reproduce the reference exactly."""
    tiles = tiles_from([(1.0, 6.0, 10), (1.0, 1.0, 10), (4.0, 1.0, 10)])
    ref = sched.reference_two_phase(tiles, capacity=100)
    wrapped = sched.two_phase(tiles, capacity=100)
    assert_same_schedule(ref.adaptive, wrapped.adaptive)
    adaptive = sched.adaptive_schedule(tiles, capacity=100)
    assert_same_schedule(ref.adaptive, adaptive)


def test_resnet50_adaptive_bit_identical_and_faster():
    """Acceptance gate: identical windows + total stall on ResNet-50 tiles
    (speed is asserted by benchmarks/sched_micro.py)."""
    tiles = sim.model_tiles(PU_2X, sim.resnet_gemm_layers(50))
    cap = int(PU_2X.fast_mem_bytes * 0.6)
    ref = sched.reference_two_phase(tiles, cap, max_window_scan=6)
    got = plan(tiles, cap, max_window_scan=6)
    assert list(got.windows) == [t.window for t in ref.adaptive.tiles]
    assert got.total_stall == ref.adaptive.total_stall


@st.composite
def window_assignments(draw):
    tiles = draw(tile_lists())
    windows = [draw(st.integers(-1, j - 1)) for j in range(len(tiles))]
    return tiles, windows


@settings(max_examples=40, deadline=None)
@given(tw=window_assignments(), cap=st.integers(30, 150))
def test_engine_simulate_matches_reference_on_random_windows(tw, cap):
    """The vectorized engine reproduces the reference event simulation
    bit-for-bit on arbitrary (not just planner-generated) assignments --
    including infeasible/deadlocking ones."""
    tiles, windows = tw
    ref = sched.simulate(tiles, cap, windows)
    eng = PlanEngine(
        [t.load_s for t in tiles],
        [t.exec_s for t in tiles],
        [t.mem_bytes for t in tiles],
        cap,
    )
    got = eng.simulate(windows)
    assert ref.feasible == got.feasible
    if ref.feasible:
        for i, t in enumerate(ref.tiles):
            assert t.load_start == got.load_start[i]
            assert t.load_end == got.load_end[i]
            assert t.exec_start == got.exec_start[i]
            assert t.exec_end == got.exec_end[i]
        assert ref.total_stall == got.total_stall


# ------------------------------------------------------- edge cases -------


def test_zero_exec_time_tiles():
    """Zero-exec tiles cannot conceal any load; every downstream load
    stalls fully, and the adaptive phase must not crash or regress."""
    tiles = tiles_from([(1.0, 0.0, 10)] * 4)
    ref = sched.reference_two_phase(tiles, capacity=100)
    got = plan(tiles, capacity=100)
    assert_same_schedule(ref.adaptive, got.to_two_phase().adaptive)
    assert got.feasible
    # loads serialize back-to-back: each stall is the full load time
    assert got.total_stall == pytest.approx(3.0)


def test_zero_exec_makespan_utilization():
    tiles = tiles_from([(1.0, 0.0, 10), (1.0, 0.0, 10)])
    p = plan(tiles, capacity=100)
    assert p.utilization == pytest.approx(0.0)
    assert p.makespan == pytest.approx(1.0)   # serialized second load


def test_tile_exactly_at_capacity():
    """A tile whose footprint equals capacity is feasible -- but only one
    may be resident, so loads fully serialize behind releases."""
    cap = 100
    tiles = tiles_from([(1.0, 2.0, cap), (3.0, 2.0, cap), (3.0, 2.0, cap)])
    ref = sched.reference_two_phase(tiles, capacity=cap)
    got = plan(tiles, capacity=cap)
    assert got.feasible
    assert_same_schedule(ref.adaptive, got.to_two_phase().adaptive)
    assert got.peak_memory() == cap
    # each later load waits for the previous exec to release => full stall
    assert got.total_stall == pytest.approx(6.0)


def test_tile_over_capacity_infeasible():
    got = plan(tiles_from([(1.0, 1.0, 101)]), capacity=100)
    assert not got.feasible
    assert got.to_schedule().feasible is False
    assert got.to_schedule().tiles == []


def test_deadlock_reported_infeasible():
    """A memory wait that can only be satisfied by the execution of a tile
    whose own load is queued *behind* the blocked load deadlocks and must
    be reported infeasible by both the reference and the engine."""
    # tile 2 is pre-loaded (window -1) and pins 60 B until its execution
    # -- which cannot run before exec 1, whose load is queued behind
    # tile 1's.  Tile 1 (60 B) then never fits: 60 (tile 2) + 60 > 100
    # and the only remaining release is exec 1 itself.
    tiles = tiles_from([(1.0, 1.0, 10), (1.0, 1.0, 60), (1.0, 1.0, 60)])
    windows = [-1, 0, -1]
    cap = 100
    ref = sched.simulate(tiles, cap, windows)
    eng = PlanEngine([t.load_s for t in tiles], [t.exec_s for t in tiles],
                     [t.mem_bytes for t in tiles], cap)
    got = eng.simulate(windows)
    assert not ref.feasible
    assert not got.feasible
    # the planner's default (baseline-derived) assignments stay feasible
    assert plan(tiles, cap).feasible


def test_empty_and_single_tile():
    assert plan([], capacity=10).feasible
    p = plan(tiles_from([(2.0, 1.0, 5)]), capacity=10)
    assert p.feasible
    # first tile is pre-loaded (window -1): zero stall, exec at t=0
    assert p.total_stall == pytest.approx(0.0)
    assert p.windows == (-1,)


def test_residency_account_matches_legacy_trace():
    """The vectorized prefix-sum residency account agrees with the legacy
    O(n^2) Schedule.peak_memory / memory_trace."""
    tiles = tiles_from(
        [(1.0, 2.0, 30), (3.0, 1.0, 40), (1.0, 4.0, 20), (2.0, 1.0, 35)]
    )
    p = plan(tiles, capacity=90)
    legacy = p.to_schedule("adaptive")
    assert p.peak_memory() == legacy.peak_memory()
    times, resident = p.residency()
    assert resident.max() <= 90
    # spot-check against the legacy trace at each edge time
    trace = dict(legacy.memory_trace())
    for t, r in zip(times.tolist(), resident.tolist()):
        if t in trace:
            # legacy samples *after* all edges at t: compare at the last
            # occurrence of each timestamp
            last = max(i for i, tt in enumerate(times.tolist()) if tt == t)
            assert resident[last] == trace[t]


# -------------------------------------------------- multi-PU pipeline -----


def test_balance_layer_ranges_bottleneck_optimal():
    costs = np.array([[4.0, 1.0, 1.0, 1.0, 1.0]] * 2)
    ranges = balance_layer_ranges(costs)
    # optimal split: [0,1) | [1,5) with bottleneck 4
    assert ranges == [(0, 1), (1, 5)]
    homog = np.array([[1.0] * 6] * 3)
    parts = balance_layer_ranges(homog)
    assert [b - a for a, b in parts] == [2, 2, 2]


def test_balance_rejects_more_stages_than_layers():
    with pytest.raises(ValueError):
        balance_layer_ranges(np.ones((3, 2)))


def test_partitioned_k2_beats_single_pus_via_fleetsim():
    """Acceptance gate: a K=2 partitioned ResNet-50 plan achieves strictly
    higher scheduled FPS than a single PU of either profile, surfaced via
    FleetSim's replacement API."""
    layers = sim.resnet_gemm_layers(50)
    f1 = sim.simulate_model(PU_1X, layers).fps_scheduled
    f2 = sim.simulate_model(PU_2X, layers).fps_scheduled
    part = sim.simulate_partitioned([PU_1X, PU_2X], layers)
    assert part.feasible
    assert isinstance(part, PartitionedPlan)
    assert part.fps > max(f1, f2)

    fleet = sim.FleetSim(pipelines=[("r50_k2", part, 1)])
    assert fleet.fps == pytest.approx(part.fps)
    assert fleet.fps > max(f1, f2)
    # mixed fleets compose: pipelines + replicated frames stay additive
    mixed = sim.FleetSim(
        sims=[("pu2x", sim.simulate_model(PU_2X, layers), 1)],
        pipelines=[("r50_k2", part, 1)],
    )
    assert mixed.fps == pytest.approx(part.fps + f2)
    assert mixed.tops == pytest.approx(part.tops + PU_2X.peak_ops_per_s / 1e12)


def test_partition_stages_cover_all_layers():
    layers = sim.resnet_gemm_layers(18)
    part = sim.simulate_partitioned([PU_1X, PU_2X, PU_2X], layers)
    spans = [(s.layer_start, s.layer_stop) for s in part.stages]
    assert spans[0][0] == 0 and spans[-1][1] == len(layers)
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 == b0
    assert all(s.n_layers > 0 for s in part.stages)
    # every stage schedules its own tiles against its own capacity
    for s in part.stages:
        assert s.plan.feasible
        assert s.plan.capacity == s.pu.fast_mem_bytes


def test_partition_gemms_latency_balancing():
    gemms = [(f"g{i}", 64, 64, 32) for i in range(8)]
    part = partition_gemms(gemms, [PU_2X, PU_2X])
    # homogeneous profiles + homogeneous layers: even split
    assert [s.n_layers for s in part.stages] == [4, 4]


# ------------------------------------------------------------ cache -------


def test_plan_cache_hits_identical_workloads():
    cache = PlanCache(max_entries=8)
    tiles = tiles_from([(1.0, 2.0, 10), (2.0, 2.0, 15), (1.5, 1.0, 12)])
    p1 = cache.get_or_plan(tiles, 50)
    p2 = cache.get_or_plan(list(tiles), 50)     # equal content, new list
    assert p1 is p2
    assert cache.stats() == {
        "entries": 1, "hits": 1, "misses": 1, "disk_hits": 0, "disk_errors": 0,
    }
    # different capacity or tile costs miss
    cache.get_or_plan(tiles, 51)
    cache.get_or_plan(tiles[:-1], 50)
    assert cache.stats()["misses"] == 3


def test_plan_cache_key_sensitivity():
    tiles = tiles_from([(1.0, 2.0, 10)])
    k = plan_key(tiles, 50)
    assert plan_key(tiles_from([(1.0, 2.0, 10)]), 50) == k
    assert plan_key(tiles, 51) != k
    assert plan_key(tiles_from([(1.0, 2.0, 11)]), 50) != k
    assert plan_key(tiles, 50, exhaustive=True) != k
    assert plan_key(tiles, 50, max_window_scan=3) != k


def test_plan_cache_key_search_strategy_and_seed():
    """Heuristic / beam / differently-seeded annealed plans of the same
    workload must never alias -- strategy, parameters and seed are all
    part of the key (the explicit heuristic config is the default)."""
    tiles = tiles_from([(1.0, 2.0, 10), (2.0, 1.0, 12)])
    k = plan_key(tiles, 50)
    assert plan_key(tiles, 50, search=SearchConfig()) == k
    kb = plan_key(tiles, 50, search=SearchConfig(strategy="beam"))
    ka0 = plan_key(tiles, 50, search=SearchConfig(strategy="anneal", seed=0))
    ka1 = plan_key(tiles, 50, search=SearchConfig(strategy="anneal", seed=1))
    assert len({k, kb, ka0, ka1}) == 4
    assert plan_key(
        tiles, 50, search=SearchConfig(strategy="beam", beam_width=8)
    ) != kb
    assert plan_key(
        tiles, 50, search=SearchConfig(strategy="anneal", seed=0,
                                       anneal_steps=99)
    ) != ka0


def test_plan_cache_search_plans_do_not_alias(tmp_path):
    """End-to-end: one cache, one workload, three strategies -> three
    distinct entries and three distinct spill files."""
    tiles = sim.model_tiles(PU_2X, sim.resnet_gemm_layers(18))
    cap = int(PU_2X.fast_mem_bytes * 0.25)
    cache = PlanCache(persist_dir=tmp_path)
    h = cache.get_or_plan(tiles, cap)
    a = cache.get_or_plan(
        tiles, cap, search=SearchConfig(strategy="anneal", seed=0,
                                        anneal_steps=300)
    )
    b = cache.get_or_plan(
        tiles, cap, search=SearchConfig(strategy="anneal", seed=1,
                                        anneal_steps=300)
    )
    assert cache.stats()["misses"] == 3
    assert h.search == "heuristic" and a.search != h.search
    assert a.search != b.search
    assert len(list(tmp_path.glob("*.json"))) == 3
    # reloading an annealed plan from disk keeps its identity
    fresh = PlanCache(persist_dir=tmp_path)
    a2 = fresh.get_or_plan(
        tiles, cap, search=SearchConfig(strategy="anneal", seed=0,
                                        anneal_steps=300)
    )
    assert fresh.stats()["disk_hits"] == 1
    assert a2.windows == a.windows and a2.search == a.search


def test_plan_cache_rejects_structurally_corrupt_spill(tmp_path):
    """A spill that parses as JSON but is internally inconsistent
    (truncated timeline arrays) must be treated as corrupt: recomputed,
    not served."""
    import json as _json

    from repro.plan.cache import PlanCache as _PC, plan_key as _pk

    tiles = tiles_from([(1.0, 2.0, 10), (2.0, 1.0, 12), (1.5, 1.5, 8)])
    a = _PC(persist_dir=tmp_path)
    p1 = a.get_or_plan(tiles, 50)
    path = tmp_path / f"{_pk(tiles, 50)}.json"
    d = _json.loads(path.read_text())
    d["timeline"]["exec_end"] = d["timeline"]["exec_end"][:-1]   # truncate
    path.write_text(_json.dumps(d))
    b = _PC(persist_dir=tmp_path)
    p2 = b.get_or_plan(tiles, 50)                  # replans, no crash
    assert b.stats()["disk_errors"] >= 1
    assert b.stats()["disk_hits"] == 0
    assert p2.windows == p1.windows
    assert len(p2.timeline.exec_end) == len(tiles)
    # out-of-range windows are rejected the same way
    d = _json.loads(path.read_text())
    d["windows"] = [5] * len(d["windows"])
    path.write_text(_json.dumps(d))
    c = _PC(persist_dir=tmp_path)
    p3 = c.get_or_plan(tiles, 50)
    assert c.stats()["disk_errors"] >= 1
    assert p3.windows == p1.windows


def test_plan_cache_lru_eviction():
    cache = PlanCache(max_entries=2)
    t1 = tiles_from([(1.0, 1.0, 1)])
    t2 = tiles_from([(2.0, 1.0, 1)])
    t3 = tiles_from([(3.0, 1.0, 1)])
    cache.get_or_plan(t1, 10)
    cache.get_or_plan(t2, 10)
    cache.get_or_plan(t3, 10)          # evicts t1
    assert cache.stats()["entries"] == 2
    cache.get_or_plan(t2, 10)          # still resident
    assert cache.stats()["hits"] == 1
    cache.get_or_plan(t1, 10)          # re-planned
    assert cache.stats()["misses"] == 4


def test_simulate_model_uses_shared_cache():
    from repro.plan import PLAN_CACHE

    layers = sim.resnet_gemm_layers(18)
    sim.simulate_model(PU_2X, layers)
    before = PLAN_CACHE.stats()["hits"]
    sim.simulate_model(PU_2X, layers)   # identical workload: cache hit
    assert PLAN_CACHE.stats()["hits"] == before + 1


# ----------------------------------------------------------- search -------


@settings(max_examples=25, deadline=None)
@given(
    tiles=tile_lists(),
    cap=st.integers(40, 150),
    strategy=st.sampled_from(["beam", "anneal"]),
    seed=st.integers(0, 3),
)
def test_search_never_worse_than_heuristic_seed(tiles, cap, strategy, seed):
    """Property: beam/anneal schedules never carry more stall than the
    heuristic seed schedule they start from."""
    heur = plan(tiles, cap)
    cfg = SearchConfig(
        strategy=strategy, seed=seed, anneal_steps=200, beam_rounds=4
    )
    searched = plan(tiles, cap, search=cfg)
    assert searched.feasible == heur.feasible
    if heur.feasible:
        assert searched.total_stall <= heur.total_stall + 1e-12
        assert searched.baseline_stall == heur.baseline_stall
        assert searched.search == cfg.descriptor()


def test_search_deterministic_by_seed():
    tiles = sim.model_tiles(PU_2X, sim.resnet_gemm_layers(18))
    cap = int(PU_2X.fast_mem_bytes * 0.25)
    cfg = SearchConfig(strategy="anneal", seed=7, anneal_steps=300)
    a = plan(tiles, cap, search=cfg)
    b = plan(tiles, cap, search=cfg)
    assert a.windows == b.windows
    assert a.total_stall == b.total_stall


def test_search_improves_resnet50_under_pressure():
    """Acceptance: annealing beats the one-shot heuristic on the
    memory-pressured ResNet-50 workload the plan bench records."""
    tiles = sim.model_tiles(PU_2X, sim.resnet_gemm_layers(50))
    cap = int(PU_2X.fast_mem_bytes * 0.2)
    heur = plan(tiles, cap)
    ann = plan(
        tiles, cap,
        search=SearchConfig(strategy="anneal", seed=0, anneal_steps=1500),
    )
    assert ann.stall_reduction >= 1.5 * heur.stall_reduction
    # the searched schedule is still a valid residency-bounded plan
    assert ann.peak_memory() <= cap


def test_unknown_search_strategy_rejected():
    with pytest.raises(ValueError):
        SearchConfig(strategy="genetic")


# ------------------------------------------------- load-bound early exit --


def test_load_bound_workload_skips_adaptive_scan():
    """Every load dwarfs every execution window: the adaptive phase must
    detect it, try nothing, and stay bit-identical to the reference
    (which scans and also finds no candidate)."""
    tiles = tiles_from([(5.0, 0.5, 10)] * 12)
    got = plan(tiles, capacity=1000)
    ref = sched.reference_two_phase(tiles, capacity=1000)
    assert got.skipped_load_bound
    assert_same_schedule(ref.adaptive, got.to_two_phase().adaptive)
    assert got.windows == got.baseline_windows
    # exhaustive mode has candidates (partial concealment): no skip
    ex = plan(tiles, capacity=1000, exhaustive=True)
    assert not ex.skipped_load_bound


def test_compute_bound_workload_not_skipped():
    # tile 2 stalls (3 s load behind a 2 s window) but window 0 (8 s
    # exec) can conceal it: candidates exist, so no load-bound exit
    tiles = tiles_from([(0.5, 8.0, 10), (0.5, 2.0, 10), (3.0, 2.0, 10)])
    p = plan(tiles, capacity=1000)
    assert not p.skipped_load_bound
    assert p.relocations()   # and the heuristic actually fixes it


# --------------------------------------------------------- IR shape -------


def test_execution_plan_summary_and_relocations():
    tiles = tiles_from([(1.0, 6.0, 10), (1.0, 1.0, 10), (4.0, 1.0, 10)])
    p = plan(tiles, capacity=100)
    s = p.summary()
    assert s["tiles"] == 3
    assert s["adaptive_stall_s"] <= s["baseline_stall_s"]
    assert s["relocations"] == len(p.relocations())
    assert p.relocations()  # this workload relocates tile 2's load
    j, frm, to = p.relocations()[0]
    assert j == 2 and to < frm