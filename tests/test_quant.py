"""INT8 power-of-two quantization: the PU arithmetic (paper SS V)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import quant


def test_roundtrip_error_bound(key):
    x = jax.random.normal(key, (128, 128)) * 3.0
    t = quant.quantize(x)
    err = jnp.max(jnp.abs(t.dequantize() - x))
    # quantization error <= half a quantization step
    step = jnp.exp2(t.exp.astype(jnp.float32))
    assert float(err) <= float(step) / 2 + 1e-7


def test_exponent_is_minimal():
    x = jnp.asarray([100.0, -50.0])
    e = quant.pow2_exponent(x)
    # 100/2**e <= 127 and 100/2**(e-1) > 127
    assert 100.0 / 2.0 ** float(e) <= 127.0
    assert 100.0 / 2.0 ** (float(e) - 1) > 127.0


@settings(max_examples=40, deadline=None)
@given(
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_within_int8_range(scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    t = quant.quantize(x)
    q = np.asarray(t.q)
    assert q.min() >= quant.INT8_MIN and q.max() <= quant.INT8_MAX


@settings(max_examples=50, deadline=None)
@given(
    v=st.integers(-(2**27), 2**27),
    s=st.integers(0, 14),
)
def test_shift_round_matches_float_round(v, s):
    """shift_round == round-half-away-from-zero of v / 2**s."""
    got = int(quant.shift_round(jnp.asarray(v, jnp.int32), s))
    want = int(np.sign(v) * np.floor(abs(v) / 2.0**s + 0.5))
    assert got == want


def test_shift_round_negative_shift_multiplies():
    assert int(quant.shift_round(jnp.asarray(3, jnp.int32), -2)) == 12


def test_requantize_path_consistent(key):
    """W_q X_q int32 accumulator requantized == float product quantized."""
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (16, 32))
    x = jax.random.normal(k2, (32, 8))
    wq, xq = quant.quantize(w), quant.quantize(x)
    acc = wq.q.astype(jnp.int32) @ xq.q.astype(jnp.int32)
    acc_exp = quant.quantized_linear_exponents(wq.exp, xq.exp)
    out_exp = quant.pow2_exponent(w @ x)
    y = quant.requantize_i32(acc, acc_exp, out_exp)
    y_float = jnp.clip(
        jnp.round((w @ x) / jnp.exp2(out_exp.astype(jnp.float32))),
        quant.INT8_MIN, quant.INT8_MAX,
    )
    # quantized-arithmetic result tracks the float result within 2 ulp on
    # the output grid (1 ulp from each input quantization)
    diff = np.abs(np.asarray(y, np.int32) - np.asarray(y_float, np.int32))
    assert diff.max() <= 12  # loose analytic bound for 32-deep dot products


def test_qtensor_is_pytree(key):
    t = quant.quantize(jax.random.normal(key, (4, 4)))
    leaves = jax.tree.leaves(t)
    assert len(leaves) == 2
    t2 = jax.tree.map(lambda x: x, t)
    assert isinstance(t2, quant.QTensor)
    np.testing.assert_array_equal(np.asarray(t.q), np.asarray(t2.q))


def test_fake_quant_is_idempotent(key):
    x = jax.random.normal(key, (32,))
    y = quant.fake_quant(x)
    z = quant.fake_quant(y)
    np.testing.assert_allclose(np.asarray(y), np.asarray(z), rtol=0, atol=1e-7)
