"""Multi-pod dry-run integration: lower+compile one real cell per step
kind on the production 256-device mesh (placeholder devices, subprocess).
Marked slow; the full 40-cell matrix runs via `python -m repro.launch.dryrun
--all` and is recorded in EXPERIMENTS.md."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")


def run_dryrun(arch: str, shape: str, multi_pod=False, timeout=1200) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape]
    if multi_pod:
        cmd.append("--multi-pod")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}\nstdout:\n{r.stdout[-2000:]}"
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    art = ROOT / "experiments" / "dryrun" / f"{arch}__{shape}__{mesh}.json"
    return json.loads(art.read_text())


@pytest.mark.slow
def test_dryrun_train_cell():
    rec = run_dryrun("olmo-1b", "train_4k")
    assert rec["status"] == "ok"
    assert rec["devices"] == 256
    r = rec["roofline"]
    assert r["compute_s"] > 0 and r["memory_s"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
    assert 0 < r["useful_flops_ratio"] <= 1.5
    # memory must fit a 16 GiB v5e generously at smoke scale
    assert rec["memory"]["total_per_device"] < 16 * 2**30


@pytest.mark.slow
def test_dryrun_decode_cell():
    rec = run_dryrun("olmo-1b", "decode_32k")
    assert rec["status"] == "ok"
    assert rec["kind"] == "decode"
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_dryrun_multipod_cell():
    rec = run_dryrun("olmo-1b", "train_4k", multi_pod=True)
    assert rec["status"] == "ok"
    assert rec["devices"] == 512


@pytest.mark.slow
def test_dryrun_skip_rule():
    rec = run_dryrun("starcoder2-15b", "long_500k")
    assert rec["status"] == "skipped"
    assert "sub-quadratic" in rec["reason"]
