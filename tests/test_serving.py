"""Serving engine: continuous batching, determinism, streaming plan, AIMC
round refresh, cache-lane isolation."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.aimc import AIMCNoiseModel
from repro.core.pu import host_offload_config
from repro.models import api as model_api
from repro.runtime.serving import ServeConfig, ServingEngine, scatter_cache


def _engine(arch="olmo-1b", **kw):
    cfg = smoke_variant(get_config(arch))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    defaults = dict(max_batch=2, max_len=64, max_new_tokens=6, seed=0)
    defaults.update(kw)
    return cfg, ServingEngine(cfg, params, ServeConfig(**defaults))


def _prompts(cfg, n, length=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, length).astype(np.int32) for _ in range(n)]


def test_completes_all_requests():
    cfg, eng = _engine()
    for p in _prompts(cfg, 5):
        eng.submit(p)
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 6 for r in done)
    stats = eng.stats()
    assert stats["completed"] == 5.0 and stats["tokens"] == 30.0


def test_greedy_is_deterministic():
    cfg, e1 = _engine()
    _, e2 = _engine()
    ps = _prompts(cfg, 3)
    for p in ps:
        e1.submit(p.copy())
        e2.submit(p.copy())
    d1 = e1.run_until_drained()
    d2 = e2.run_until_drained()
    for a, b in zip(d1, d2):
        assert a.out_tokens == b.out_tokens


def test_batching_preserves_per_request_results():
    """A request served alone == the same request served amid others
    (cache lanes are isolated)."""
    cfg, alone = _engine(max_batch=1)
    prompt = _prompts(cfg, 1, seed=5)[0]
    alone.submit(prompt.copy())
    ref_tokens = alone.run_until_drained()[0].out_tokens

    _, crowded = _engine(max_batch=2)
    other = _prompts(cfg, 1, seed=9)[0]
    crowded.submit(prompt.copy())
    crowded.submit(other)
    done = {r.uid: r for r in crowded.run_until_drained()}
    assert done[0].out_tokens == ref_tokens


def test_more_requests_than_slots_queue():
    cfg, eng = _engine(max_batch=2)
    for p in _prompts(cfg, 7):
        eng.submit(p)
    done = eng.run_until_drained()
    assert len(done) == 7


def test_aimc_changes_generations():
    cfg, clean = _engine()
    _, noisy = _engine(aimc=AIMCNoiseModel(prog_noise_scale=0.5))
    ps = _prompts(cfg, 2)
    for p in ps:
        clean.submit(p.copy())
        noisy.submit(p.copy())
    d_clean = clean.run_until_drained()
    d_noisy = noisy.run_until_drained()
    assert any(
        a.out_tokens != b.out_tokens for a, b in zip(d_clean, d_noisy)
    )
    assert noisy.niu is not None


def test_streaming_plan_attached():
    cfg, eng = _engine(stream_pu=host_offload_config())
    assert eng.streaming_plan is not None
    assert eng.streaming_plan.schedule.feasible
    for p in _prompts(cfg, 2):
        eng.submit(p)
    eng.run_until_drained()
    assert "stream_tiles" in eng.stats()


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-1.2b"])
def test_ssm_families_serve(arch):
    cfg, eng = _engine(arch)
    for p in _prompts(cfg, 3):
        eng.submit(p)
    done = eng.run_until_drained()
    assert len(done) == 3


def test_scatter_cache_writes_one_lane(key):
    full = (jnp.zeros((2, 4, 8, 2, 3)), jnp.zeros((2, 4, 8, 2, 3)))
    one = (jnp.ones((2, 1, 5, 2, 3)), 2 * jnp.ones((2, 1, 5, 2, 3)))
    out = scatter_cache(full, one, slot=2, length=5)
    a = np.asarray(out[0])
    assert a[:, 2, :5].min() == 1.0          # written lane
    assert a[:, [0, 1, 3]].max() == 0.0      # untouched lanes
    assert a[:, 2, 5:].max() == 0.0          # beyond length zero
