"""Serving engine: continuous batching, determinism, streaming plan, AIMC
round refresh, cache-lane isolation."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.aimc import AIMCNoiseModel
from repro.core.pu import host_offload_config, tpu_v5e_config
from repro.models import api as model_api
from repro.runtime.serving import ServeConfig, ServingEngine, scatter_cache


def _engine(arch="olmo-1b", **kw):
    cfg = smoke_variant(get_config(arch))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    defaults = dict(max_batch=2, max_len=64, max_new_tokens=6, seed=0)
    defaults.update(kw)
    return cfg, ServingEngine(cfg, params, ServeConfig(**defaults))


def _prompts(cfg, n, length=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, length).astype(np.int32) for _ in range(n)]


def test_completes_all_requests():
    cfg, eng = _engine()
    for p in _prompts(cfg, 5):
        eng.submit(p)
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 6 for r in done)
    stats = eng.stats()
    assert stats["completed"] == 5.0 and stats["tokens"] == 30.0


def test_greedy_is_deterministic():
    cfg, e1 = _engine()
    _, e2 = _engine()
    ps = _prompts(cfg, 3)
    for p in ps:
        e1.submit(p.copy())
        e2.submit(p.copy())
    d1 = e1.run_until_drained()
    d2 = e2.run_until_drained()
    for a, b in zip(d1, d2):
        assert a.out_tokens == b.out_tokens


def test_batching_preserves_per_request_results():
    """A request served alone == the same request served amid others
    (cache lanes are isolated)."""
    cfg, alone = _engine(max_batch=1)
    prompt = _prompts(cfg, 1, seed=5)[0]
    alone.submit(prompt.copy())
    ref_tokens = alone.run_until_drained()[0].out_tokens

    _, crowded = _engine(max_batch=2)
    other = _prompts(cfg, 1, seed=9)[0]
    crowded.submit(prompt.copy())
    crowded.submit(other)
    done = {r.uid: r for r in crowded.run_until_drained()}
    assert done[0].out_tokens == ref_tokens


def test_more_requests_than_slots_queue():
    cfg, eng = _engine(max_batch=2)
    for p in _prompts(cfg, 7):
        eng.submit(p)
    done = eng.run_until_drained()
    assert len(done) == 7


def test_aimc_changes_generations():
    """SS VI: the NIU rewrites served weights with a *fresh* noise
    instance every engine round.  (Random-init smoke models are
    argmax-degenerate -- their top-logit gap can exceed any plausible
    device noise -- so the assertion targets the served weights the
    rounds actually consumed, not sampled token ids.)"""

    def flat(params):
        return np.concatenate(
            [
                np.asarray(l, np.float32).ravel()
                for l in jax.tree_util.tree_leaves(params)
            ]
        )

    cfg, noisy = _engine(aimc=AIMCNoiseModel(prog_noise_scale=0.5))
    assert noisy.niu is not None
    pristine = flat(noisy._pristine)
    noisy.submit(_prompts(cfg, 1)[0])
    noisy.step()
    round1 = flat(noisy.params)
    noisy.step()
    round2 = flat(noisy.params)
    # noise applied to the weights each round, and re-drawn between rounds
    assert not np.allclose(round1, pristine, atol=1e-6)
    assert not np.allclose(round2, round1, atol=1e-6)
    # the pristine HBM region is never mutated (SS VI)
    np.testing.assert_allclose(flat(noisy._pristine), pristine)


def test_streaming_plan_attached():
    cfg, eng = _engine(stream_pu=host_offload_config())
    assert eng.streaming_plan is not None
    assert eng.streaming_plan.schedule.feasible
    for p in _prompts(cfg, 2):
        eng.submit(p)
    eng.run_until_drained()
    assert "stream_tiles" in eng.stats()


def test_multi_pu_partitioned_serving():
    """stream_pus partitions one served model across several PU profiles
    (repro.plan.partition) instead of planning a single-PU stream."""
    cfg, eng = _engine(
        stream_pus=[host_offload_config(), tpu_v5e_config()]
    )
    assert eng.partitioned_plan is not None
    assert eng.streaming_plan is None
    assert eng.partitioned_plan.feasible
    assert len(eng.partitioned_plan.stages) == 2
    for p in _prompts(cfg, 2):
        eng.submit(p)
    eng.run_until_drained()
    s = eng.stats()
    assert s["partition_stages"] == 2.0
    assert s["partition_fps"] > 0
    assert s["partition_latency_s"] >= s["partition_bottleneck_s"]


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-1.2b"])
def test_ssm_families_serve(arch):
    cfg, eng = _engine(arch)
    for p in _prompts(cfg, 3):
        eng.submit(p)
    done = eng.run_until_drained()
    assert len(done) == 3


def test_scatter_cache_writes_one_lane(key):
    full = (jnp.zeros((2, 4, 8, 2, 3)), jnp.zeros((2, 4, 8, 2, 3)))
    one = (jnp.ones((2, 1, 5, 2, 3)), 2 * jnp.ones((2, 1, 5, 2, 3)))
    out = scatter_cache(full, one, slot=2, length=5)
    a = np.asarray(out[0])
    assert a[:, 2, :5].min() == 1.0          # written lane
    assert a[:, [0, 1, 3]].max() == 0.0      # untouched lanes
    assert a[:, 2, 5:].max() == 0.0          # beyond length zero
