"""Runtime sanitizer library (DESIGN.md SS11): TraceCounter /
retrace_guard semantics, the transfer-guard tripwire, and the
lock-order recorder -- plus one end-to-end serve under
``REPRO_SANITIZE=1`` with instrumented executor locks."""
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import sanitize
from repro.analysis.sanitize import (
    LockOrderViolation,
    RetraceError,
    TraceCounter,
    instrument_condition,
    instrument_lock,
    lock_violations,
    require_held,
    reset_lock_monitor,
    retrace_guard,
    transfer_guard,
)


# ---------------------------------------------------------------------------
# TraceCounter / retrace_guard
# ---------------------------------------------------------------------------


def test_trace_counter_bumps_only_at_trace_time():
    tc = TraceCounter(("decode",))
    fn = jax.jit(tc.wrap("decode", lambda x: x + 1))
    fn(jnp.zeros((2,)))
    fn(jnp.ones((2,)))            # same shape: compiled, no re-trace
    assert tc.counts["decode"] == 1
    fn(jnp.zeros((3,)))           # new shape: re-traces
    assert tc.counts["decode"] == 2


def test_trace_counter_jit_is_wrap_plus_jit():
    tc = TraceCounter()
    fn = tc.jit(lambda x: x * 2, kind="decode")
    assert fn(jnp.asarray(2.0)) == 4.0
    assert tc.counts == {"decode": 1}
    assert tc.total() == 1


def test_retrace_guard_passes_when_flat():
    tc = TraceCounter(("decode",))
    fn = jax.jit(tc.wrap("decode", lambda x: x + 1))
    fn(jnp.zeros((2,)))           # warm
    with retrace_guard(tc):
        fn(jnp.ones((2,)))
        fn(jnp.zeros((2,)))


def test_retrace_guard_raises_with_per_kind_delta():
    tc = TraceCounter(("decode",))
    fn = jax.jit(tc.wrap("decode", lambda x: x + 1))
    with pytest.raises(RetraceError, match=r"decode.*1|1.*decode"):
        with retrace_guard(tc):
            fn(jnp.zeros((2,)))


def test_retrace_guard_allowance_and_kind_filter():
    tc = TraceCounter()
    with retrace_guard(tc, max_new_traces=2):
        tc.bump("decode")
        tc.bump("decode")
    with retrace_guard(tc, kinds=("prefill",)):
        tc.bump("decode")         # other kinds don't count


# ---------------------------------------------------------------------------
# transfer_guard
# ---------------------------------------------------------------------------


def test_transfer_guard_arms_jax_guard():
    # CPU d2h is zero-copy, so the raise path only fires on
    # accelerators; what we can assert everywhere is that the block
    # arms jax's device->host guard and restores it after
    before = jax.config.jax_transfer_guard_device_to_host
    with transfer_guard(active=True):
        assert jax.config.jax_transfer_guard_device_to_host == "disallow"
    assert jax.config.jax_transfer_guard_device_to_host == before


def test_transfer_guard_inactive_is_noop():
    with transfer_guard(active=False):
        assert jax.config.jax_transfer_guard_device_to_host is None
        np.asarray(jnp.arange(4))     # always fine when off


def test_transfer_guard_follows_env(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    with transfer_guard():
        assert jax.config.jax_transfer_guard_device_to_host is None
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    with transfer_guard():
        assert jax.config.jax_transfer_guard_device_to_host == "disallow"


# ---------------------------------------------------------------------------
# lock-order recorder
# ---------------------------------------------------------------------------


@pytest.fixture(autouse=True)
def _fresh_monitor():
    reset_lock_monitor()
    yield
    reset_lock_monitor()


def test_instrument_lock_inactive_returns_plain_lock():
    lock = instrument_lock("X", active=False)
    assert isinstance(lock, type(threading.Lock()))
    cond = instrument_condition("Y", active=False)
    assert isinstance(cond, threading.Condition)


def test_consistent_order_records_no_violation():
    a = instrument_lock("A", active=True)
    b = instrument_lock("B", active=True)
    for _ in range(3):
        with a:
            with b:
                pass
    assert lock_violations() == []


def test_inverted_order_is_reported():
    a = instrument_lock("A", active=True)
    b = instrument_lock("B", active=True)
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    vs = lock_violations()
    assert len(vs) == 1
    v = vs[0]
    assert v.kind == "order" and {v.first, v.second} == {"A", "B"}
    assert v.site       # file:line of the second acquisition


def test_cross_thread_inversion_is_reported():
    # the registry is process-wide: thread 1 takes A->B, thread 2 B->A
    a = instrument_lock("A", active=True)
    b = instrument_lock("B", active=True)

    def order(first, second):
        with first:
            with second:
                pass

    t1 = threading.Thread(target=order, args=(a, b))
    t1.start(); t1.join()
    t2 = threading.Thread(target=order, args=(b, a))
    t2.start(); t2.join()
    assert [v.kind for v in lock_violations()] == ["order"]


def test_require_held_records_unguarded_access():
    a = instrument_lock("A", active=True)
    with a:
        require_held(a)
    assert lock_violations() == []
    require_held(a, site="here")
    vs = lock_violations()
    assert [v.kind for v in vs] == ["unguarded"]
    assert vs[0].first == "A" and vs[0].site == "here"


def test_require_held_noop_for_plain_locks():
    require_held(threading.Lock())
    assert lock_violations() == []


def test_condition_wrapper_wait_notify():
    cond = instrument_condition("C", active=True)
    hits = []

    def waiter():
        with cond:
            cond.wait_for(lambda: bool(hits), timeout=5.0)
            hits.append("woke")

    t = threading.Thread(target=waiter)
    t.start()
    with cond:
        hits.append("go")
        cond.notify_all()
    t.join(timeout=5.0)
    assert hits == ["go", "woke"]
    assert lock_violations() == []


# ---------------------------------------------------------------------------
# end-to-end: staged serve with the sanitizers armed
# ---------------------------------------------------------------------------


def test_staged_engine_serves_clean_under_sanitize(monkeypatch):
    """REPRO_SANITIZE=1 end-to-end: the multi-PU staged engine builds
    with instrumented locks, serves mixed traffic with the decode block
    under the transfer guard, and the lock monitor records nothing."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize.enabled()
    reset_lock_monitor()

    from repro.configs import get_config, smoke_variant
    from repro.core.pu import host_offload_config, tpu_v5e_config
    from repro.models import api as model_api
    from repro.runtime.serving import ServeConfig, ServingEngine

    cfg = smoke_variant(get_config("olmo-1b"))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(
        cfg, params,
        ServeConfig(
            max_batch=2, max_len=64, max_new_tokens=4, seed=0,
            stream_pus=[host_offload_config(), tpu_v5e_config()],
            stage_decode=True, decode_microbatches=2,
        ),
    )
    rng = np.random.default_rng(0)
    for n in (5, 9):
        eng.submit(rng.integers(0, cfg.vocab, n).astype(np.int32))
    done = eng.run_until_drained()
    assert len(done) == 2
    assert all(len(r.out_tokens) > 0 for r in done)
    assert lock_violations() == []
    assert eng.trace_counts is eng.tracing.counts   # live alias