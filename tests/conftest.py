"""Shared fixtures.  NOTE: no XLA device-count flags here -- smoke tests
must see the real single CPU device; multi-device tests spawn subprocesses
with their own XLA_FLAGS (see test_multidevice.py / test_dryrun_integration).
"""
import numpy as np
import pytest

import jax

# Property tests use hypothesis; the pinned container has no wheel for it.
# Install the in-repo fallback runner iff the real package is missing.
from repro._compat import hypothesis_fallback

hypothesis_fallback.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
