"""Cycle-approximate PU simulator: reproduces the paper's evaluation
(SS IV-V): Table I throughput, Fig. 5(a) latencies, the 98% efficiency
claim, and the WRB out-of-order benefit."""
import math

import pytest

from repro.core.pu import PU_1X, PU_2X
from repro.core import simulator as sim
from repro.core import wrb


# ------------------------------------------------------------- Table I ----

PAPER = {
    18: {"fps": 1237.7, "fps_per_tops": 268.6},
    50: {"fps": 584.9, "fps_per_tops": 126.9},
}
TOL = 0.06  # simulator within 6% of measured hardware


@pytest.mark.parametrize("variant", [18, 50])
def test_table1_fleet_throughput(variant):
    layers = sim.resnet_gemm_layers(variant)
    s1 = sim.simulate_model(PU_1X, layers)
    s2 = sim.simulate_model(PU_2X, layers)
    fleet = sim.FleetSim(sims=[("pu1x", s1, 5), ("pu2x", s2, 5)])
    assert fleet.tops == pytest.approx(4.608, rel=1e-3)       # paper's note 1
    assert fleet.fps == pytest.approx(PAPER[variant]["fps"], rel=TOL)
    assert fleet.fps_per_tops == pytest.approx(
        PAPER[variant]["fps_per_tops"], rel=TOL
    )


def test_resnet50_latency_matches_paper():
    """Paper SS V: ResNet-50 latency 25.3 ms (PU_1x) / 12.9 ms (PU_2x)."""
    layers = sim.resnet_gemm_layers(50)
    lat1 = sim.simulate_model(PU_1X, layers).frame_s_scheduled * 1e3
    lat2 = sim.simulate_model(PU_2X, layers).frame_s_scheduled * 1e3
    assert lat1 == pytest.approx(25.3, rel=0.08)
    assert lat2 == pytest.approx(12.9, rel=0.08)


@pytest.mark.parametrize("variant", [18, 50])
def test_efficiency_near_98_percent(variant):
    """Paper SS V: 'up to 98% performance efficiency'."""
    layers = sim.resnet_gemm_layers(variant)
    for pu in (PU_1X, PU_2X):
        eff = sim.simulate_model(pu, layers).efficiency
        assert 0.95 <= eff <= 1.0


def test_pu2x_twice_pu1x_throughput():
    layers = sim.resnet_gemm_layers(50)
    f1 = sim.simulate_model(PU_1X, layers).fps_scheduled
    f2 = sim.simulate_model(PU_2X, layers).fps_scheduled
    assert f2 / f1 == pytest.approx(2.0, rel=0.05)


# ------------------------------------------------- layer table structure --


def test_resnet_gemm_macs_scale():
    """ResNet-50 ~4.1 GMACs, ResNet-18 ~1.8 GMACs (ImageNet literature),
    with the paper's conv1-as-GEMM padding (147->160)."""
    m18 = sum(l.macs for l in sim.resnet_gemm_layers(18))
    m50 = sum(l.macs for l in sim.resnet_gemm_layers(50))
    assert 1.6e9 < m18 < 2.1e9
    assert 3.6e9 < m50 < 4.4e9


def test_first_layer_padded_to_160():
    l0 = sim.resnet_gemm_layers(18)[0]
    assert l0.m == 160      # 147 padded to 160 bytes (SS V)


def test_wrb_rate_condition_flagged():
    """R_g >= R_SA/ceil(M/C_SA) (SS V) is checked per layer."""
    ls = sim.simulate_layer(PU_2X, sim.GemmLayer("t", n=64, m=64, p=10), r_g=8)
    # ceil(64/8)=8 cycles per wave; 64/8=8 <= r_g=8 -> ok
    assert ls.wrb_rate_ok
    ls2 = sim.simulate_layer(PU_2X, sim.GemmLayer("t", n=64, m=8, p=10), r_g=8)
    # 1 cycle per wave; rate 64 > 8 -> backpressure possible
    assert not ls2.wrb_rate_ok


# ----------------------------------------------------------------- WRB ----


def test_wrb_out_of_order_never_slower():
    cfg = wrb.WRBConfig()
    for interval in (2, 4, 8, 16):
        in_order, ooo = wrb.ooo_benefit(cfg, n_waves=64, wave_interval=interval)
        assert ooo.cycles <= in_order.cycles
        assert ooo.efficiency >= in_order.efficiency - 1e-9


def test_wrb_ooo_benefit_exists_for_fast_producer():
    """When waves arrive faster than the drain rate, OOO admission removes
    head-of-line blocking (the paper's 'minimizing the idle state')."""
    cfg = wrb.WRBConfig(capacity_waves=4)
    in_order, ooo = wrb.ooo_benefit(cfg, n_waves=128, wave_interval=2)
    assert ooo.producer_stall_cycles < in_order.producer_stall_cycles


def test_wrb_zero_waves():
    s = wrb.simulate_wrb(wrb.WRBConfig(), 0, 4)
    assert s.cycles == 0 and s.efficiency == 1.0
