"""INT8 KV cache with power-of-two scales (SSPerf optimization): numeric
quality and structural correctness."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.models import api as model_api
from repro.models.transformer import kv_dequantize, kv_quantize


def test_kv_roundtrip_error_bound(key):
    x = jax.random.normal(key, (2, 16, 4, 32)) * 3.0
    q, e = kv_quantize(x)
    assert q.dtype == jnp.int8 and e.dtype == jnp.int8
    back = kv_dequantize(q, e, jnp.float32)
    # error <= half a step of each row's power-of-two grid
    step = jnp.exp2(e.astype(jnp.float32))[..., None]
    assert bool(jnp.all(jnp.abs(back - x) <= step / 2 + 1e-6))


def test_kv_quant_zero_rows_safe(key):
    x = jnp.zeros((1, 4, 2, 8))
    q, e = kv_quantize(x)
    back = kv_dequantize(q, e, jnp.float32)
    np.testing.assert_array_equal(np.asarray(back), 0.0)


@pytest.mark.parametrize("arch", ["olmo-1b", "mixtral-8x7b"])
def test_quantized_decode_tracks_bf16_path(arch):
    cfg0 = smoke_variant(get_config(arch))
    cfgq = dataclasses.replace(cfg0, kv_quant=True)
    api = model_api.get_api(cfg0)
    params = api.init_params(cfg0, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg0.vocab, (2, 10)), jnp.int32)

    # same params, both cache flavors, token-by-token decode
    def run(cfg):
        cache = api.init_cache(cfg, 2, 24)
        lg = None
        for i in range(10):
            lg, cache = api.decode_step(
                cfg, params, cache, toks[:, i : i + 1], jnp.int32(i)
            )
        return np.asarray(lg, np.float32)

    l0, lq = run(cfg0), run(cfgq)
    assert np.max(np.abs(l0 - lq)) < 0.25, np.max(np.abs(l0 - lq))
    # greedy decisions preserved
    assert (np.argmax(l0, -1) == np.argmax(lq, -1)).all()


def test_quant_cache_structure():
    cfgq = dataclasses.replace(smoke_variant(get_config("olmo-1b")), kv_quant=True)
    api = model_api.get_api(cfgq)
    cache = api.init_cache(cfgq, 2, 16)
    assert len(cache) == 4
    assert cache[0].dtype == jnp.int8 and cache[2].dtype == jnp.int8
    axes = api.cache_axes(cfgq)
    assert len(axes) == 4
    assert len(axes[2]) == cache[2].ndim


def test_quant_cache_prefill_roundtrip():
    cfgq = dataclasses.replace(smoke_variant(get_config("olmo-1b")), kv_quant=True)
    api = model_api.get_api(cfgq)
    params = api.init_params(cfgq, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfgq.vocab, (1, 8)), jnp.int32)
    logits, cache = api.prefill(cfgq, params, {"tokens": toks})
    assert len(cache) == 4
    # decode continues from the quantized prefill cache
    # (prefill cache length == prompt length; pad into a longer buffer)
    full = api.init_cache(cfgq, 1, 32)
    full = tuple(
        jax.lax.dynamic_update_slice(f, c.astype(f.dtype), (0,) * f.ndim)
        for f, c in zip(full, cache)
    )
    l2, _ = api.decode_step(
        cfgq, params, full, jnp.argmax(logits, -1)[:, None].astype(jnp.int32),
        jnp.int32(8),
    )
    assert bool(jnp.all(jnp.isfinite(l2.astype(jnp.float32))))
