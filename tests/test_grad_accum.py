"""Gradient accumulation: accum=k must match accum=1 (same global batch)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.configs.base import ShapeConfig
from repro.launch.mesh import single_device_mesh
from repro.models import api as model_api
from repro.optim import AdamWConfig, adamw_init
from repro.parallel.sharding import RULES_FSDP_TP
from repro.runtime.steps import make_train_step


def _run(accum):
    cfg = smoke_variant(get_config("olmo-1b"))
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    mesh = single_device_mesh()
    step_fn, specs, in_sh, out_sh = make_train_step(
        cfg, shape, mesh, RULES_FSDP_TP,
        AdamWConfig(lr=1e-3, clip_norm=None),   # clip depends on grad norm
        accum_steps=accum,
    )
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = model_api.make_concrete(
        model_api.batch_struct(cfg, shape), vocab=cfg.vocab
    )
    with mesh:
        p2, o2, m = jax.jit(step_fn)(params, opt, batch)
    return p2, float(m["loss"])


def test_accum_matches_single_shot():
    p1, l1 = _run(1)
    p2, l2 = _run(2)
    p4, l4 = _run(4)
    assert abs(l1 - l2) < 5e-3 and abs(l1 - l4) < 5e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=2e-4, rtol=2e-3,
        )


def test_accum_requires_divisibility():
    cfg = smoke_variant(get_config("olmo-1b"))
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    with pytest.raises(AssertionError):
        make_train_step(
            cfg, shape, single_device_mesh(), RULES_FSDP_TP, None, accum_steps=3
        )
