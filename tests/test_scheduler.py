"""Two-phase weight-transfer scheduler: paper semantics + invariants.

Property tests (hypothesis) assert the invariants any valid schedule must
satisfy; example tests pin the paper's §III semantics (zero-stall
condition, stall formula, Fig. 4 relocation behaviour).
"""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pu import PU_1X, PU_2X, TileCost, tpu_v5e_config
from repro.core import scheduler as sched


def tiles_from(lists):
    return [TileCost(load_s=l, exec_s=e, mem_bytes=m) for l, e, m in lists]


# ---------------------------------------------------------------- paper ---


def test_zero_stall_when_load_fits_exec_window():
    """l_i <= e_{i-1} and memory available => zero stall (SS III)."""
    tiles = tiles_from([(1.0, 5.0, 10)] + [(4.0, 5.0, 10)] * 5)
    s = sched.baseline_schedule(tiles, capacity=100)
    assert s.feasible
    assert s.total_stall == pytest.approx(0.0)


def test_stall_equals_load_minus_exec():
    """l_i > e_{i-1} => pipeline waits l_i - e_{i-1} (SS III)."""
    tiles = tiles_from([(1.0, 2.0, 10), (6.0, 2.0, 10)])
    s = sched.baseline_schedule(tiles, capacity=100)
    # tile1 load starts when tile0 exec starts (window 0), runs 6s;
    # tile0 exec ends at 2 => stall = 6 - 2 = 4
    assert s.tiles[1].stall == pytest.approx(4.0)


def test_stall_with_memory_limit_is_full_load():
    """When memory is the limiter the wait approaches l_i (SS III)."""
    # capacity fits exactly one tile: next load can only start after the
    # current tile's execution releases its memory.
    tiles = tiles_from([(1.0, 2.0, 100), (3.0, 2.0, 100)])
    s = sched.baseline_schedule(tiles, capacity=100)
    assert s.feasible
    # tile1 load begins at tile0 exec END (release), so stall = full l_1
    assert s.tiles[1].stall == pytest.approx(3.0)


def test_preload_first_tile():
    """Paper SS V: first tile pre-loaded 'to avoid an initial delay'."""
    tiles = tiles_from([(5.0, 2.0, 10), (1.0, 2.0, 10)])
    s = sched.baseline_schedule(tiles, capacity=100, preload_first=True)
    # pre-load completes at t=0: no initial delay, no stall on tile 0
    assert s.tiles[0].exec_start == pytest.approx(0.0)
    assert s.tiles[0].stall == pytest.approx(0.0)
    assert s.tiles[0].window == -1


def test_adaptive_relocates_stall_to_earlier_window():
    """Fig. 4: a stalled load moved into an earlier window disappears."""
    # tile2's load (4s) doesn't fit tile1's exec (1s) but fits tile0's (6s).
    tiles = tiles_from([(1.0, 6.0, 10), (1.0, 1.0, 10), (4.0, 1.0, 10)])
    res = sched.two_phase(tiles, capacity=100)
    assert res.baseline.total_stall > 0
    assert res.adaptive.total_stall == pytest.approx(0.0)
    assert res.stall_reduction == pytest.approx(1.0)
    # the relocated tile's window moved earlier
    assert res.adaptive.tiles[2].window < res.baseline.tiles[2].window


def test_adaptive_respects_memory_when_relocating():
    """A relocation that would overflow memory must be rejected."""
    cap = 25
    tiles = tiles_from(
        [(1.0, 6.0, 10), (1.0, 1.0, 10), (4.0, 1.0, 10)]
    )
    # with capacity 25, loading tile2 (10) during tile0's window would
    # have tiles 0+1+2 resident = 30 > 25 => relocation impossible.
    res = sched.two_phase(tiles, capacity=cap)
    assert res.adaptive.peak_memory() <= cap
    # stall not fully removable
    assert res.adaptive.total_stall > 0


def test_infeasible_single_tile_too_large():
    tiles = tiles_from([(1.0, 1.0, 200)])
    s = sched.baseline_schedule(tiles, capacity=100)
    assert not s.feasible


def test_time_memory_ratios_shapes():
    tiles = tiles_from([(1.0, 2.0, 30), (3.0, 2.0, 40), (1.0, 2.0, 50)])
    res = sched.two_phase(tiles, capacity=100)
    assert len(res.time_ratios()) == 2
    assert len(res.memory_ratios()) == 2
    # memory ratio definition: (m_i + m_{i+1}) / cap
    assert res.memory_ratios()[0] == pytest.approx(0.7)
    assert res.memory_ratios()[1] == pytest.approx(0.9)


# ------------------------------------------------------------ invariants --


@st.composite
def tile_lists(draw):
    n = draw(st.integers(1, 12))
    tiles = []
    for _ in range(n):
        tiles.append(
            TileCost(
                load_s=draw(st.floats(0.01, 10, allow_nan=False)),
                exec_s=draw(st.floats(0.01, 10, allow_nan=False)),
                mem_bytes=draw(st.integers(1, 50)),
            )
        )
    return tiles


@settings(max_examples=60, deadline=None)
@given(tiles=tile_lists(), cap=st.integers(50, 200))
def test_schedule_invariants(tiles, cap):
    res = sched.two_phase(tiles, capacity=cap)
    for s in (res.baseline, res.adaptive):
        if not s.feasible:
            continue
        # memory never exceeds capacity
        assert s.peak_memory() <= cap
        prev_end = 0.0
        loads = sorted((t.load_start, t.load_end) for t in s.tiles)
        # loads serialized on one channel
        for (a0, a1), (b0, b1) in zip(loads, loads[1:]):
            assert b0 >= a1 - 1e-9
        for t in s.tiles:
            # execution strictly in order, after its own load
            assert t.exec_start >= t.load_end - 1e-9
            assert t.exec_start >= prev_end - 1e-9
            # stall formula
            assert t.stall == pytest.approx(max(0.0, t.exec_start - prev_end))
            prev_end = t.exec_end


@settings(max_examples=60, deadline=None)
@given(tiles=tile_lists(), cap=st.integers(50, 200))
def test_adaptive_never_worse_than_baseline(tiles, cap):
    res = sched.two_phase(tiles, capacity=cap)
    if res.baseline.feasible:
        assert res.adaptive.feasible
        assert res.adaptive.total_stall <= res.baseline.total_stall + 1e-9


@settings(max_examples=40, deadline=None)
@given(tiles=tile_lists())
def test_infinite_memory_baseline_matches_closed_form(tiles):
    """With unbounded memory the baseline stall has a closed form:
    sum_i max(0, l_i - e_{i-1} - accumulated_slack)."""
    cap = 10**9
    s = sched.baseline_schedule(tiles, capacity=cap)
    assert s.feasible
    # simulate the closed form: load channel serialized, window = i-1
    t_chan = -tiles[0].load_s
    exec_end = 0.0
    exec_start_prev = 0.0
    total_stall = 0.0
    for i, t in enumerate(tiles):
        open_t = 0.0 if i == 0 else exec_start_prev
        if i == 0:
            open_t = -t.load_s
        start = max(open_t, t_chan)
        ld_end = start + t.load_s if i > 0 else 0.0
        if i == 0:
            ld_end = 0.0
            t_chan = 0.0
        else:
            t_chan = ld_end
        es = max(exec_end, ld_end)
        total_stall += es - exec_end
        exec_start_prev = es
        exec_end = es + t.exec_s
    assert s.total_stall == pytest.approx(total_stall, rel=1e-6, abs=1e-9)


def test_utilization_definition():
    tiles = tiles_from([(1.0, 4.0, 10), (8.0, 4.0, 10)])
    s = sched.baseline_schedule(tiles, capacity=100)
    busy = sum(t.exec_end - t.exec_start for t in s.tiles)
    assert s.utilization == pytest.approx(busy / s.makespan)
    assert 0 < s.utilization <= 1


# ------------------------------------------------------------ PU costing --


def test_pu_tile_costing_matches_paper_dims():
    """PU_2x: R_SA=64, C_SA=8 -> a 64xM tile takes ceil(M/8) URAM entries."""
    pu = PU_2X
    m = 1000
    assert pu.tile_bytes(m) == math.ceil(m / 8) * 8 * 64
    # load time = bytes / (16B * 600MHz)
    assert pu.load_time(m) == pytest.approx(pu.tile_bytes(m) / (16 * 600e6))
    # exec: P waves x ceil(M/8) cycles at 600 MHz
    assert pu.exec_time(m, p=49) == pytest.approx(49 * math.ceil(m / 8) / 600e6)


def test_pu1x_half_compute_of_pu2x():
    assert PU_1X.peak_ops_per_s == pytest.approx(PU_2X.peak_ops_per_s / 2)


def test_tpu_profile_peak_matches():
    pu = tpu_v5e_config()
    assert pu.peak_ops_per_s == pytest.approx(197e12, rel=1e-6)


def test_gemm_tiles_cover_weight_matrix():
    pu = PU_2X
    tiles = pu.gemm_tiles(n=200, m=300, p=10)
    assert len(tiles) == math.ceil(200 / 64)
    assert all(t.mem_bytes == pu.tile_bytes(300) for t in tiles)
