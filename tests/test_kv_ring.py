"""Ring-buffer SWA KV cache: decode through a window-sized ring matches
decode through the full-length cache (only the window is ever visible)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.models import api as model_api


def _cfgs():
    base = smoke_variant(get_config("mixtral-8x7b"))     # pure SWA (window 64)
    base = dataclasses.replace(base, n_experts=0, top_k=0)  # dense: no
    # capacity-coupling so full vs ring are exactly comparable
    ring = dataclasses.replace(base, kv_ring=True)
    return base, ring


def _decode_seq(cfg, api, params, toks, max_len):
    cache = api.init_cache(cfg, toks.shape[0], max_len)
    step = jax.jit(
        lambda p, c, t, i: api.decode_step(cfg, p, c, t, i)
    )
    lg = None
    for i in range(toks.shape[1]):
        lg, cache = step(params, cache, toks[:, i : i + 1], jnp.int32(i))
    return np.asarray(lg, np.float32), cache


def test_ring_cache_is_window_sized():
    _, ring = _cfgs()
    api = model_api.get_api(ring)
    cache = api.init_cache(ring, 2, 256)
    assert cache[0].shape[2] == ring.window          # 64, not 256


def test_ring_decode_matches_full_before_wrap():
    base, ring = _cfgs()
    api = model_api.get_api(base)
    params = api.init_params(base, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    s = ring.window // 2                              # no wrap yet
    toks = jnp.asarray(rng.integers(0, base.vocab, (1, s)), jnp.int32)
    l_full, _ = _decode_seq(base, api, params, toks, s + 8)
    l_ring, _ = _decode_seq(ring, api, params, toks, s + 8)
    np.testing.assert_allclose(l_ring, l_full, atol=1e-4, rtol=1e-3)


def test_ring_decode_matches_full_after_wrap():
    """Past the window the ring overwrites old slots; logits must still
    match the full cache (those positions are masked out anyway)."""
    base, ring = _cfgs()
    api = model_api.get_api(base)
    params = api.init_params(base, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    s = ring.window * 2 + 9                           # wraps twice
    toks = jnp.asarray(rng.integers(0, base.vocab, (1, s)), jnp.int32)
    l_full, _ = _decode_seq(base, api, params, toks, s + 8)
    l_ring, _ = _decode_seq(ring, api, params, toks, s + 8)
    np.testing.assert_allclose(l_ring, l_full, atol=2e-2, rtol=2e-2)
    assert (np.argmax(l_ring, -1) == np.argmax(l_full, -1)).all()


def test_ring_prefill_then_decode():
    base, ring = _cfgs()
    api = model_api.get_api(base)
    params = api.init_params(base, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    s = ring.window + 17
    toks = jnp.asarray(rng.integers(0, base.vocab, (1, s)), jnp.int32)
    logits, cache = api.prefill(ring, params, {"tokens": toks})
    assert cache[0].shape[2] == ring.window
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    l2, _ = api.decode_step(ring, params, cache, nxt, jnp.int32(s))
    # reference: full-cache prefill + decode
    logits_f, cache_f = api.prefill(base, params, {"tokens": toks})
    l2_f, _ = api.decode_step(base, params, cache_f, nxt, jnp.int32(s))
    np.testing.assert_allclose(
        np.asarray(l2, np.float32), np.asarray(l2_f, np.float32),
        atol=5e-2, rtol=5e-2,   # bf16 accumulation-order noise
    )
    assert (np.argmax(np.asarray(l2), -1) == np.argmax(np.asarray(l2_f), -1)).all()


def test_ring_with_kv_quant_composes():
    base, ring = _cfgs()
    both = dataclasses.replace(ring, kv_quant=True)
    api = model_api.get_api(both)
    params = api.init_params(base, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    s = ring.window + 12
    toks = jnp.asarray(rng.integers(0, base.vocab, (1, s)), jnp.int32)
    l_b, _ = _decode_seq(base, api, params, toks, s + 8)
    l_q, cache = _decode_seq(both, api, params, toks, s + 8)
    assert len(cache) == 4 and cache[0].shape[2] == ring.window
    assert (np.argmax(l_q, -1) == np.argmax(l_b, -1)).all()


def test_ring_refused_for_global_layers():
    """gemma3 (local:global) must NOT shrink the cache."""
    cfg = dataclasses.replace(smoke_variant(get_config("gemma3-12b")), kv_ring=True)
    api = model_api.get_api(cfg)
    cache = api.init_cache(cfg, 1, 256)
    assert cache[0].shape[2] == 256
