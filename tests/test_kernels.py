"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes, blocks, and epilogue options; hypothesis fuzzing on
shapes and data."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import int8_gemm, ops, ref


def _rand_int8(rng, shape):
    return jnp.asarray(rng.integers(-128, 128, shape, dtype=np.int8))


# ---------------------------------------------------------------- GEMM ----


@pytest.mark.parametrize(
    "n,m,p",
    [
        (1, 1, 1),
        (7, 13, 5),
        (64, 64, 64),
        (128, 128, 128),
        (100, 200, 72),        # non-divisible by block
        (129, 257, 130),       # just over block boundaries
        (256, 64, 512),
    ],
)
def test_gemm_matches_ref(rng, n, m, p):
    w = _rand_int8(rng, (n, m))
    x = _rand_int8(rng, (m, p))
    y = int8_gemm(w, x)
    yr = ref.int8_gemm_ref(w, x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    assert y.dtype == jnp.int8


@pytest.mark.parametrize("shift", [-2, 0, 1, 4, 9, 15])
@pytest.mark.parametrize("relu", [False, True])
def test_gemm_epilogue_shift_relu(rng, shift, relu):
    w = _rand_int8(rng, (48, 96))
    x = _rand_int8(rng, (96, 32))
    bias = jnp.asarray(rng.integers(-5000, 5000, (48,), dtype=np.int32))
    y = int8_gemm(w, x, bias, shift=shift, relu=relu)
    yr = ref.int8_gemm_ref(w, x, bias, shift=shift, relu=relu)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_gemm_residual_fusion(rng):
    w = _rand_int8(rng, (64, 64))
    x = _rand_int8(rng, (64, 48))
    res = _rand_int8(rng, (64, 48))
    y = int8_gemm(w, x, shift=8, residual=res, relu=True)
    yr = ref.int8_gemm_ref(w, x, shift=8, residual=res, relu=True)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    # ReLU output must be non-negative
    assert int(np.asarray(y).min()) >= 0


@pytest.mark.parametrize("bn,bp,bm", [(32, 32, 32), (128, 128, 128), (64, 128, 32)])
def test_gemm_block_shapes(rng, bn, bp, bm):
    """Result must be block-shape independent (pure tiling)."""
    w = _rand_int8(rng, (96, 80))
    x = _rand_int8(rng, (80, 56))
    y = int8_gemm(w, x, shift=6, block_n=bn, block_p=bp, block_m=bm)
    yr = ref.int8_gemm_ref(w, x, shift=6)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 150),
    m=st.integers(1, 150),
    p=st.integers(1, 150),
    shift=st.integers(0, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_hypothesis(n, m, p, shift, seed):
    rng = np.random.default_rng(seed)
    w = _rand_int8(rng, (n, m))
    x = _rand_int8(rng, (m, p))
    bias = jnp.asarray(rng.integers(-100, 100, (n,), dtype=np.int32))
    y = int8_gemm(w, x, bias, shift=shift, block_n=64, block_p=64, block_m=64)
    yr = ref.int8_gemm_ref(w, x, bias, shift=shift)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_gemm_accumulator_no_overflow_regime(rng):
    """Worst-case int8 x int8 over M=512 stays within int32 (asserted by
    exact agreement with the int32 oracle)."""
    w = jnp.full((8, 512), -128, jnp.int8)
    x = jnp.full((512, 8), -128, jnp.int8)
    y = int8_gemm(w, x, shift=16)
    yr = ref.int8_gemm_ref(w, x, shift=16)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


# -------------------------------------------------------------- IM2COL ----


@pytest.mark.parametrize(
    "h,w,c,k,stride,pad",
    [
        (8, 8, 3, 3, 1, 1),
        (8, 8, 4, 3, 2, 1),
        (16, 16, 8, 5, 2, 2),
        (7, 9, 2, 3, 1, 0),
        (224, 224, 3, 7, 2, 3),     # ResNet conv1
        (4, 4, 1, 1, 1, 0),
    ],
)
def test_im2col_matches_ref(rng, h, w, c, k, stride, pad):
    img = _rand_int8(rng, (h, w, c))
    got = ops.im2col(img, k, stride, pad)
    want = ref.im2col_ref(img, k, stride, pad)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(3, 24),
    w=st.integers(3, 24),
    c=st.integers(1, 8),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    pad=st.integers(0, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_hypothesis(h, w, c, k, stride, pad, seed):
    if h + 2 * pad < k or w + 2 * pad < k:
        return
    rng = np.random.default_rng(seed)
    img = _rand_int8(rng, (h, w, c))
    got = ops.im2col(img, k, stride, pad)
    want = ref.im2col_ref(img, k, stride, pad)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_im2col_dtype_sweep(rng):
    for dtype in (jnp.int8, jnp.float32, jnp.bfloat16):
        img = jnp.asarray(rng.standard_normal((6, 6, 2)), dtype)
        got = ops.im2col(img, 3, 1, 1)
        want = ref.im2col_ref(img, 3, 1, 1)
        np.testing.assert_array_equal(
            np.asarray(got, np.float32), np.asarray(want, np.float32)
        )


# ------------------------------------------------------- conv-as-GEMM -----


@pytest.mark.parametrize(
    "h,cin,cout,k,stride,pad,relu",
    [
        (8, 3, 16, 3, 1, 1, True),
        (8, 4, 8, 3, 2, 1, False),
        (9, 2, 4, 1, 1, 0, True),
        (10, 3, 6, 1, 2, 0, False),   # k=1 s=2: the PU's strided linear path
        (12, 2, 4, 5, 2, 2, True),
    ],
)
def test_conv_as_gemm_vs_xla_conv(rng, h, cin, cout, k, stride, pad, relu):
    img = _rand_int8(rng, (h, h, cin))
    w4d = _rand_int8(rng, (k, k, cin, cout))
    bias = jnp.asarray(rng.integers(-300, 300, (cout,), dtype=np.int32))
    got = ops.conv2d_int8(img, w4d, bias, k=k, stride=stride, pad=pad, shift=7, relu=relu)
    want = ref.conv2d_int8_ref(img, w4d, bias, stride=stride, pad=pad, shift=7, relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_residual_matches_ref(rng):
    img = _rand_int8(rng, (8, 8, 4))
    w4d = _rand_int8(rng, (3, 3, 4, 4))
    res = _rand_int8(rng, (8, 8, 4))
    got = ops.conv2d_int8(img, w4d, k=3, stride=1, pad=1, shift=8, relu=True, residual=res)
    want = ref.conv2d_int8_ref(img, w4d, stride=1, pad=1, shift=8, relu=True, residual=res)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
