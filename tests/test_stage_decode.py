"""True per-stage decode (ROADMAP item): every pipeline stage runs its
model-layer slice with real activations in the executor's handoff
queues.  Model-level slicing identities per family, the decode-range
attachment, staggered-admission serving parity for K in {2, 3} against
the single-PU device loop, and the K > num-layers guard."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.sanitize import retrace_guard
from repro.configs import get_config, smoke_variant
from repro.core.pu import host_offload_config, tpu_v5e_config
from repro.models import api as model_api
from repro.plan.partition import PartitionedPlan
from repro.runtime.serving import (
    ServeConfig,
    ServingEngine,
    attach_decode_ranges,
    model_gemms,
    plan_partitioned_streaming,
)
from repro.runtime.stage_decode import StagedDecodeRunner

_PARAMS = {}


def _cfg(arch, **overrides):
    cfg = smoke_variant(get_config(arch))
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def _params(cfg):
    key = (cfg.family, cfg.n_layers)
    if key not in _PARAMS:
        api = model_api.get_api(cfg)
        _PARAMS[key] = api.init_params(cfg, jax.random.PRNGKey(0))
    return _PARAMS[key]


def _prompts(cfg, n, lo=4, hi=24, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, cfg.vocab, int(l)).astype(np.int32)
        for l in rng.integers(lo, hi, n)
    ]


def _pus(k):
    return [
        host_offload_config() if i % 2 == 0 else tpu_v5e_config()
        for i in range(k)
    ]


# ---------------------------------------------------------------------------
# model-level slicing identities
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch",
    ["olmo-1b", "whisper-medium", "mamba2-780m", "zamba2-1.2b", "mixtral-8x7b"],
)
def test_staged_composition_is_decode_step(arch):
    """embed -> stage slices -> unembed composes bit-identically to the
    fused decode_step, and the stage cache slices concatenate back to
    the fused new cache, for every family (hybrid slices group-aligned)."""
    cfg = _cfg(arch)
    api = model_api.get_api(cfg)
    params = _params(cfg)
    cache = api.init_cache(cfg, 2, 32)
    toks = jnp.asarray([[3], [7]], jnp.int32)
    pos = jnp.asarray([2, 9], jnp.int32)
    logits, new_cache = api.decode_step(cfg, params, cache, toks, pos)

    pts = api.decode_slice_points(cfg)
    mid = pts[len(pts) // 2]
    h = api.decode_embed(cfg, params, toks, pos)
    slices = []
    for r in ((0, mid), (mid, cfg.n_layers)):
        h, sc = api.decode_stage(
            cfg, api.slice_params(cfg, params, r), h,
            api.slice_cache(cfg, cache, r), pos,
        )
        slices.append(sc)
    np.testing.assert_array_equal(
        np.asarray(api.decode_unembed(cfg, params, h)), np.asarray(logits)
    )
    merged = jax.tree.map(lambda *ls: jnp.concatenate(ls, axis=0), *slices)
    for a, b in zip(jax.tree.leaves(new_cache), jax.tree.leaves(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_empty_slice_is_identity():
    cfg = _cfg("olmo-1b")
    api = model_api.get_api(cfg)
    params = _params(cfg)
    cache = api.init_cache(cfg, 2, 16)
    h = jnp.ones((2, 1, cfg.d_model), jnp.float32)
    out, sc = api.decode_stage(
        cfg, api.slice_params(cfg, params, (1, 1)), h,
        api.slice_cache(cfg, cache, (1, 1)), jnp.asarray([0, 0]),
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(h))
    assert all(l.shape[0] == 0 for l in jax.tree.leaves(sc))


def test_hybrid_rejects_group_misaligned_ranges():
    """Zamba2 smoke (every=2, 5 layers): a boundary inside a group would
    strand the group's shared-attention KV on another stage."""
    cfg = _cfg("zamba2-1.2b")
    api = model_api.get_api(cfg)
    assert api.decode_slice_points(cfg) == (0, 2, 4, 5)
    with pytest.raises(ValueError, match="group-aligned"):
        api.slice_params(cfg, _params(cfg), (0, 3))
    with pytest.raises(ValueError, match="group-aligned"):
        api.slice_cache(cfg, api.init_cache(cfg, 1, 8), (1, 4))


# ---------------------------------------------------------------------------
# decode-range attachment (StagePlan carries what the slicers consume)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [2, 3])
def test_attached_ranges_tile_all_layers(k):
    cfg = _cfg("olmo-1b", n_layers=4)
    pplan = plan_partitioned_streaming(cfg, _pus(k), batch_tokens=4)
    ranges = [s.decode_layers for s in pplan.stages]
    cursor = 0
    for start, stop in ranges:
        assert start == cursor and stop >= start
        cursor = stop
    assert cursor == cfg.n_layers
    pts = set(model_api.get_api(cfg).decode_slice_points(cfg))
    assert all(a in pts and b in pts for a, b in ranges)


def test_raw_partition_has_no_decode_ranges():
    from repro.plan import partition_gemms

    pplan = partition_gemms(
        [("a", 64, 64, 8), ("b", 64, 64, 8)], _pus(2)
    )
    with pytest.raises(ValueError, match="no decode layer range"):
        pplan.stages[0].decode_layers
    cfg = _cfg("olmo-1b")
    with pytest.raises(ValueError):
        StagedDecodeRunner(
            cfg, model_api.get_api(cfg), _params(cfg), pplan
        )


def test_hybrid_ranges_snap_to_group_boundaries():
    cfg = _cfg("zamba2-1.2b")
    pplan = plan_partitioned_streaming(cfg, _pus(2), batch_tokens=4)
    pts = set(model_api.get_api(cfg).decode_slice_points(cfg))
    for s in pplan.stages:
        a, b = s.decode_layers
        assert a in pts and b in pts


# ---------------------------------------------------------------------------
# executor: real activations through the handoff queues
# ---------------------------------------------------------------------------


def test_runner_round_matches_fused_decode_and_keeps_clock():
    cfg = _cfg("olmo-1b", n_layers=4)
    api = model_api.get_api(cfg)
    params = _params(cfg)
    pplan = plan_partitioned_streaming(cfg, _pus(2), batch_tokens=2)
    runner = StagedDecodeRunner(cfg, api, params, pplan)
    cache = api.init_cache(cfg, 2, 32)
    runner.load_cache(cache)
    toks = jnp.asarray([[5], [11]], jnp.int32)
    pos = jnp.asarray([4, 8], jnp.int32)
    logits = runner.decode_round(toks, pos)
    # the fused reference is jitted, like the engine's decode block (the
    # eager path fuses the bf16 unembed differently at the float level)
    want, want_cache = jax.jit(
        lambda p, c, t, q: api.decode_step(cfg, p, c, t, q)
    )(params, cache, toks, pos)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(want))
    for a, b in zip(
        jax.tree.leaves(runner.export_cache()), jax.tree.leaves(want_cache)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the handoffs carried real compute AND the virtual clock still
    # reproduces the plan's single-frame recurrence
    assert runner.last_report.real_stage_compute
    assert runner.clock_ok
    assert runner.last_report.frame_done_t[0] == pytest.approx(
        float(pplan.pipeline_events(1)[-1, 0])
    )


def test_cache_slices_roundtrip_through_runner():
    cfg = _cfg("zamba2-1.2b")
    api = model_api.get_api(cfg)
    pplan = plan_partitioned_streaming(cfg, _pus(2), batch_tokens=2)
    runner = StagedDecodeRunner(cfg, api, _params(cfg), pplan)
    cache = jax.tree.map(
        lambda s: jax.random.normal(
            jax.random.PRNGKey(1), s.shape, jnp.float32
        ).astype(s.dtype),
        api.init_cache(cfg, 2, 16),
    )
    runner.load_cache(cache)
    for a, b in zip(
        jax.tree.leaves(runner.export_cache()), jax.tree.leaves(cache)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# serving parity: staged multi-PU rounds vs the single-PU device loop
# ---------------------------------------------------------------------------


def _engine(cfg, params, **kw):
    defaults = dict(max_batch=3, max_len=64, max_new_tokens=5, seed=0)
    defaults.update(kw)
    return ServingEngine(cfg, params, ServeConfig(**defaults))


@pytest.mark.parametrize("arch", ["olmo-1b", "whisper-medium"])
@pytest.mark.parametrize("k", [2, 3])
def test_staged_serving_bit_identical_to_single_pu(arch, k):
    """Acceptance: --multi-pu greedy token streams are bit-identical to
    the single-PU device loop under staggered admissions, queueing and
    slot reuse, while per-stage decode actually executes layer slices."""
    cfg = _cfg(arch, n_layers=4)
    params = _params(cfg)
    single = _engine(cfg, params)
    staged = _engine(cfg, params, stream_pus=_pus(k))
    assert staged._staged is not None
    wave0 = _prompts(cfg, 3, seed=11)
    wave1 = _prompts(cfg, 2, seed=13)
    for e in (single, staged):
        for p in wave0:
            e.submit(p.copy())
        e.step()                      # wave0 in flight...
        for p in wave1:
            e.submit(p.copy())        # ...wave1 admitted staggered
    ds = {r.uid: r.out_tokens for r in single.run_until_drained()}
    dt = {r.uid: r.out_tokens for r in staged.run_until_drained()}
    assert ds == dt
    s = staged.stats()
    assert s["stage_decode"] == 1.0
    assert s["stage_decode_rounds"] > 0
    assert s["stage_decode_clock_ok"] == 1.0
    # the stages really split the model: every layer is owned exactly once
    owned = sum(
        int(s[f"stage{i}_decode_layers"]) for i in range(k)
        if f"stage{i}_decode_layers" in s
    )
    assert owned == cfg.n_layers


def test_staged_serving_warmup_then_no_retraces():
    cfg = _cfg("olmo-1b", n_layers=4)
    params = _params(cfg)
    eng = _engine(cfg, params, stream_pus=_pus(2), max_len=96)
    eng.warmup()
    with retrace_guard(eng.tracing):
        for p in _prompts(cfg, 6, lo=4, hi=30, seed=3):
            eng.submit(p)
        done = eng.run_until_drained()
    assert len(done) == 6


def test_k_exceeds_num_layers_guard():
    """K=3 stages over a 2-layer model: the snapped ranges leave at
    least one stage empty (an identity passthrough) -- serving must
    still drain with streams bit-identical to the single-PU loop."""
    cfg = _cfg("olmo-1b")        # smoke: 2 layers
    assert cfg.n_layers == 2
    params = _params(cfg)
    single = _engine(cfg, params)
    staged = _engine(cfg, params, stream_pus=_pus(3))
    ranges = staged._staged.ranges
    assert len(ranges) == 3
    assert any(a == b for a, b in ranges)          # an empty stage exists
    assert sum(b - a for a, b in ranges) == 2      # still tiles all layers
    for e in (single, staged):
        for p in _prompts(cfg, 4, seed=21):
            e.submit(p.copy())
    ds = {r.uid: r.out_tokens for r in single.run_until_drained()}
    dt = {r.uid: r.out_tokens for r in staged.run_until_drained()}
    assert ds == dt


def test_stage_decode_escape_hatch():
    cfg = _cfg("olmo-1b")
    params = _params(cfg)
    eng = _engine(cfg, params, stream_pus=_pus(2), stage_decode=False)
    assert eng._staged is None
    assert eng.partitioned_plan is not None
    for p in _prompts(cfg, 3, seed=5):
        eng.submit(p)
    done = eng.run_until_drained()
    assert len(done) == 3
    assert "stage_decode" not in eng.stats()


def test_staged_temperature_stream_is_seed_deterministic():
    cfg = _cfg("olmo-1b", n_layers=4)
    params = _params(cfg)
    e1 = _engine(cfg, params, stream_pus=_pus(2), temperature=0.8)
    e2 = _engine(cfg, params, stream_pus=_pus(2), temperature=0.8)
    for p in _prompts(cfg, 3, seed=8):
        e1.submit(p.copy())
        e2.submit(p.copy())
    d1 = e1.run_until_drained()
    d2 = e2.run_until_drained()
    for a, b in zip(d1, d2):
        assert a.out_tokens == b.out_tokens


# ---------------------------------------------------------------------------
# decode partition never starves a stage when the slice grid allows
# ---------------------------------------------------------------------------


_FAMILY_ARCHS = (
    "olmo-1b",          # lm
    "whisper-medium",   # encdec
    "mamba2-780m",      # ssm
    "zamba2-1.2b",      # hybrid
    "mixtral-8x7b",     # moe
    "internvl2-26b",    # vlm
)


@pytest.mark.parametrize("arch", _FAMILY_ARCHS)
@pytest.mark.parametrize("k", [2, 3])
def test_every_stage_owns_a_layer(arch, k):
    """Regression for the degenerate decode partition (stage_layers
    [2, 0] in the serve bench): whenever the family's slice grid has
    enough interior points for K stages, the snapped ranges must leave
    every stage at least one layer.  Smoke configs whose 2-layer grid
    cannot host K=3 get a 4-layer override -- the K-too-large case
    keeps its own guard test above."""
    cfg = _cfg(arch)
    api = model_api.get_api(cfg)
    interior = [
        p for p in api.decode_slice_points(cfg) if 0 < p < cfg.n_layers
    ]
    if len(interior) < k - 1:
        cfg = _cfg(arch, n_layers=4)
        api = model_api.get_api(cfg)
        interior = [
            p for p in api.decode_slice_points(cfg) if 0 < p < cfg.n_layers
        ]
        assert len(interior) >= k - 1, (arch, k)
    pplan = plan_partitioned_streaming(cfg, _pus(k), batch_tokens=4)
    ranges = [s.decode_layers for s in pplan.stages]
    assert all(b > a for a, b in ranges), (arch, k, ranges)
    assert sum(b - a for a, b in ranges) == cfg.n_layers


# ---------------------------------------------------------------------------
# overlapped schedule: lane-group microbatching + cross-round pipelining
# ---------------------------------------------------------------------------


def _ref_streams(cfg, params, waves, **kw):
    """Single-PU device-loop streams for the same staggered traffic."""
    eng = _engine(cfg, params, **kw)
    for i, wave in enumerate(waves):
        for p in wave:
            eng.submit(p.copy())
        if i + 1 < len(waves):
            eng.step()
    return {r.uid: r.out_tokens for r in eng.run_until_drained()}


@pytest.mark.parametrize("k", [2, 3])
@pytest.mark.parametrize("m", [1, 2, 4])
def test_overlapped_decode_bit_identical_across_m(k, m):
    """Acceptance: the overlapped staged schedule (M lane groups,
    cross-round pipelining, persistent session / coalesced block) keeps
    greedy streams bit-identical to the fused single-PU loop for
    K in {2,3} x M in {1,2,4}, under staggered admissions landing
    between rounds.  M=1 pins the serial reference schedule."""
    cfg = _cfg("olmo-1b", n_layers=4)
    params = _params(cfg)
    waves = [_prompts(cfg, 4, seed=31), _prompts(cfg, 3, seed=33)]
    kw = dict(max_batch=4, max_len=64, max_new_tokens=6, seed=0)
    ref = _ref_streams(cfg, params, waves, **kw)
    staged = _engine(
        cfg, params, stream_pus=_pus(k), decode_microbatches=m, **kw
    )
    for i, wave in enumerate(waves):
        for p in wave:
            staged.submit(p.copy())
        if i + 1 < len(waves):
            staged.step()
    got = {r.uid: r.out_tokens for r in staged.run_until_drained()}
    assert got == ref
    s = staged.stats()
    assert s["stage_decode_microbatches"] == float(m)
    assert s["stage_decode_clock_ok"] == 1.0


@pytest.mark.parametrize("m", [2, 4])
def test_overlapped_decode_eos_midstream(m):
    """A lane hitting eos mid-block goes inactive inside its lane group
    without perturbing the other groups' streams."""
    cfg = _cfg("olmo-1b", n_layers=4)
    params = _params(cfg)
    waves = [_prompts(cfg, 4, seed=41)]
    kw = dict(max_batch=4, max_len=64, max_new_tokens=8, seed=0)
    free = _ref_streams(cfg, params, waves, **kw)
    # pick a token some stream emits mid-way: stopping on it exercises
    # the early-termination path inside a block for that lane only
    eos = next(
        toks[len(toks) // 2] for toks in free.values() if len(toks) >= 3
    )
    ref = _ref_streams(cfg, params, waves, eos_token=eos, **kw)
    assert ref != free                       # eos actually cut a stream
    staged = _engine(
        cfg, params, stream_pus=_pus(2), decode_microbatches=m,
        eos_token=eos, **kw
    )
    for p in waves[0]:
        staged.submit(p.copy())
    got = {r.uid: r.out_tokens for r in staged.run_until_drained()}
    assert got == ref


@pytest.mark.parametrize("m", [2, 4])
def test_overlapped_decode_no_retraces_after_warmup(m):
    cfg = _cfg("olmo-1b", n_layers=4)
    params = _params(cfg)
    eng = _engine(
        cfg, params, stream_pus=_pus(2), decode_microbatches=m,
        max_batch=4, max_len=96, max_new_tokens=5,
    )
    eng.warmup()
    with retrace_guard(eng.tracing):
        for i, wave in enumerate(
            [_prompts(cfg, 4, seed=51), _prompts(cfg, 2, seed=53)]
        ):
            for p in wave:
                eng.submit(p)
            if i == 0:
                eng.step()
        done = eng.run_until_drained()
    assert len(done) == 6


def test_coalesced_block_matches_threaded_executor():
    """The single-device coalesced fast path and the threaded session
    executor run the same overlapped schedule: identical streams, and
    the coalesced analytic virtual account reproduces the threaded
    session's executed account (busy and span -- the same equality
    clock_ok checks per frame)."""
    cfg = _cfg("olmo-1b", n_layers=4)
    params = _params(cfg)
    waves = [_prompts(cfg, 4, seed=61), _prompts(cfg, 2, seed=63)]
    kw = dict(
        max_batch=4, max_len=64, max_new_tokens=6, seed=0,
        stream_pus=_pus(2), decode_microbatches=2,
    )
    results = {}
    for mode in ("coalesced", "threaded"):
        eng = _engine(cfg, params, **kw)
        assert eng._staged.coalesce       # single-device sim: auto-on
        if mode == "threaded":
            eng._staged.coalesce = False
        for i, wave in enumerate(waves):
            for p in wave:
                eng.submit(p.copy())
            if i == 0:
                eng.step()
        streams = {r.uid: r.out_tokens for r in eng.run_until_drained()}
        s = eng.stats()
        assert s["stage_decode_clock_ok"] == 1.0
        results[mode] = (streams, s)
    assert results["coalesced"][0] == results["threaded"][0]
    assert results["coalesced"][1]["stage_decode_bubble"] == pytest.approx(
        results["threaded"][1]["stage_decode_bubble"], rel=1e-6
    )
    assert results["coalesced"][1]["stage_decode_rounds"] == (
        results["threaded"][1]["stage_decode_rounds"]
    )


def test_staged_tuner_knee_avoids_degenerate_depth():
    """On an imbalance-dominated plan (host_offload vs tpu_v5e stage
    times ~25:1) no M reaches the target-bubble band; the knee rule
    must then pick the *shallowest* M within a quarter of the bubble
    spread instead of the deepest split (which buys no bubble but
    multiplies per-frame overhead)."""
    from repro.runtime.autotune import AutotuneConfig, tune_staged_decode

    cfg = _cfg("olmo-1b")
    pplan = plan_partitioned_streaming(cfg, _pus(2), batch_tokens=4)
    tune = tune_staged_decode(
        pplan, 4, AutotuneConfig(target_bubble=0.10)
    )
    assert not tune.within_tolerance          # imbalance floor ~0.48
    ms = [t["m"] for t in tune.trials]
    assert max(ms) >= 4                       # the deep split was probed
    assert tune.n_groups < max(ms)            # ...and rejected
    bubbles = {t["m"]: t["bubble"] for t in tune.trials}
    b_min, b_max = min(bubbles.values()), max(bubbles.values())
    knee = b_min + 0.25 * (b_max - b_min)
    assert bubbles[tune.n_groups] <= knee
    assert all(
        m >= tune.n_groups for m, b in bubbles.items() if b <= knee
    )
