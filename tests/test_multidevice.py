"""Multi-device behaviour (pipeline parallelism, compressed collectives,
sharded train step).  Each test runs in a subprocess with its own
XLA_FLAGS so the main test process keeps a single CPU device."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_py(code: str, devices: int = 4, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}\nstdout:\n{r.stdout[-2000:]}"
    return r.stdout


def test_pipeline_matches_sequential_forward_and_grad():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.parallel.pipeline import pipeline_apply, sequential_apply

        mesh = make_mesh((4,), ("stage",))
        L, B, D = 8, 8, 16
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.1,
                  "b": jnp.zeros((L, D))}
        x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
        layer_fn = lambda p, h: jnp.tanh(h @ p["w"] + p["b"])
        ref = sequential_apply(layer_fn, params, x)
        with mesh:
            out = jax.jit(lambda p, x: pipeline_apply(layer_fn, p, x, mesh, 4))(params, x)
        assert jnp.allclose(out, ref, atol=1e-5), float(jnp.max(jnp.abs(out - ref)))

        g1 = jax.grad(lambda p: jnp.sum(pipeline_apply(layer_fn, p, x, mesh, 4) ** 2))(params)
        g2 = jax.grad(lambda p: jnp.sum(sequential_apply(layer_fn, p, x) ** 2))(params)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            assert jnp.allclose(a, b, atol=1e-6)
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_pipeline_microbatch_counts():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.parallel.pipeline import pipeline_apply, sequential_apply
        mesh = make_mesh((2,), ("stage",))
        L, B, D = 4, 12, 8
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.2}
        x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
        fn = lambda p, h: jnp.tanh(h @ p["w"])
        ref = sequential_apply(fn, params, x)
        for mb in (2, 3, 6, 12):
            with mesh:
                got = pipeline_apply(fn, params, x, mesh, mb)
            assert jnp.allclose(got, ref, atol=1e-5), mb
        print("MB_OK")
    """)
    assert "MB_OK" in out


def test_stage_submeshes_split_devices():
    """Partitioned streaming composes with tensor sharding: a (2,2)
    data/model mesh splits into 2 disjoint stage submeshes that keep the
    model axis, and an odd split falls back to sharing the full mesh."""
    out = run_py("""
        import jax
        from repro.launch.mesh import make_mesh, stage_submeshes

        mesh = make_mesh((2, 2), ("data", "model"))
        subs, shared = stage_submeshes(mesh, 2)
        assert not shared
        assert len(subs) == 2
        assert all(m.axis_names == ("data", "model") for m in subs)
        assert all(m.devices.shape == (1, 2) for m in subs)
        ids = [sorted(d.id for d in m.devices.ravel()) for m in subs]
        assert ids[0] + ids[1] == sorted(d.id for d in jax.devices())

        # 4 devices into 3 stages cannot split: shared fallback
        subs3, shared3 = stage_submeshes(mesh, 3)
        assert shared3 and len(subs3) == 3

        # flat fallback: leading axis indivisible but total divides
        flat = make_mesh((1, 4), ("data", "model"))
        subs2, shared2 = stage_submeshes(flat, 2)
        assert not shared2
        assert all(m.axis_names == ("model",) for m in subs2)
        print("SUBMESH_OK")
    """)
    assert "SUBMESH_OK" in out


def test_int8_psum_mean():
    out = run_py("""
        import functools
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.parallel.compression import int8_psum

        mesh = make_mesh((4,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))

        f = shard_map(
            lambda v: int8_psum(v[0], "data")[None],
            mesh=mesh, in_specs=P("data"), out_specs=P("data"),
        )
        got = f(x)          # each row: mean of all rows, compressed
        want = jnp.mean(x, axis=0)
        err = jnp.max(jnp.abs(got - want[None]))
        rel = float(err / (jnp.max(jnp.abs(want)) + 1e-9))
        assert rel < 0.05, rel
        print("PSUM_OK", rel)
    """)
    assert "PSUM_OK" in out


def test_sharded_train_step_2x2():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, smoke_variant
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.parallel.sharding import RULES_FSDP_TP
        from repro.runtime.steps import make_train_step
        from repro.models import api as model_api
        from repro.optim import adamw_init

        cfg = smoke_variant(get_config('olmo-1b'))
        shape = ShapeConfig('t', seq_len=64, global_batch=4, kind='train')
        mesh = make_mesh((2, 2), ("data", "model"))
        step_fn, specs, in_sh, out_sh = make_train_step(cfg, shape, mesh, RULES_FSDP_TP)
        api = model_api.get_api(cfg)
        with mesh:
            params = jax.jit(lambda k: api.init_params(cfg, k), out_shardings=in_sh[0])(jax.random.PRNGKey(0))
            opt = jax.jit(adamw_init, out_shardings=in_sh[1])(params)
            batch = model_api.make_concrete(model_api.batch_struct(cfg, shape), vocab=cfg.vocab)
            batch = {k: jax.device_put(v, in_sh[2][k]) for k, v in batch.items()}
            step = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh)
            p2, o2, m = step(params, opt, batch)
        assert np.isfinite(float(m['loss']))
        print('SHARDED_OK', float(m['loss']))
    """)
    assert "SHARDED_OK" in out


def test_moe_local_dispatch_close_to_global():
    """shard_map local dispatch (used when T > E*F) tracks the global
    oracle: same routing, per-group capacity (slightly different drops)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, smoke_variant
        from repro.launch.mesh import make_mesh
        from repro.parallel.sharding import RULES_ZERO3_DP, activation_sharding_ctx
        from repro.models import mlp as mlp_mod

        cfg = smoke_variant(get_config('granite-moe-3b-a800m'))
        p = mlp_mod.moe_params(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        y_ref, aux_ref = mlp_mod._moe_apply_global(cfg, p, x)

        mesh = make_mesh((2, 2), ("data", "model"))
        def call(p, x):
            with activation_sharding_ctx(mesh, RULES_ZERO3_DP):
                # force the local path regardless of the T>E*F cost model
                # (batch rows over 'data', sequence over 'model')
                return mlp_mod._moe_apply_local(
                    cfg, p, x, mesh, (("data",), ("model",))
                )
        with mesh:
            y_loc, aux_loc = jax.jit(call)(p, x)
        err = float(jnp.max(jnp.abs(y_ref - y_loc)))
        assert err < 0.05, err           # capacity-drop differences only
        assert abs(float(aux_ref) - float(aux_loc)) < 0.1
        # gradients finite and close in norm
        g = jax.grad(lambda p: jnp.sum(call(p, x)[0]**2))(p)
        for leaf in jax.tree.leaves(g):
            assert bool(jnp.all(jnp.isfinite(leaf)))
        print('MOE_LOCAL_OK', err)
    """)
    assert "MOE_LOCAL_OK" in out


def test_sharded_equals_single_device():
    """The same train step on a 2x2 mesh and on 1 device produces the same
    loss (GSPMD is semantics-preserving)."""
    code_template = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, smoke_variant
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.parallel.sharding import RULES_FSDP_TP
        from repro.runtime.steps import make_train_step
        from repro.models import api as model_api
        from repro.optim import adamw_init

        cfg = smoke_variant(get_config('olmo-1b'))
        shape = ShapeConfig('t', seq_len=32, global_batch=4, kind='train')
        mesh = make_mesh(MESH_SHAPE, MESH_AXES)
        step_fn, specs, in_sh, out_sh = make_train_step(cfg, shape, mesh, RULES_FSDP_TP)
        api = model_api.get_api(cfg)
        with mesh:
            params = jax.jit(lambda k: api.init_params(cfg, k), out_shardings=in_sh[0])(jax.random.PRNGKey(0))
            opt = jax.jit(adamw_init, out_shardings=in_sh[1])(params)
            batch = model_api.make_concrete(model_api.batch_struct(cfg, shape), vocab=cfg.vocab)
            batch = {k: jax.device_put(v, in_sh[2][k]) for k, v in batch.items()}
            step = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh)
            p2, o2, m = step(params, opt, batch)
        print('LOSS=%.6f' % float(m['loss']))
    """
    o1 = run_py(
        code_template.replace("MESH_SHAPE", "(1,)").replace("MESH_AXES", '("data",)'),
        devices=1,
    )
    o4 = run_py(
        code_template.replace("MESH_SHAPE", "(2, 2)").replace("MESH_AXES", '("data", "model")'),
        devices=4,
    )
    l1 = float(o1.split("LOSS=")[1].split()[0])
    l4 = float(o4.split("LOSS=")[1].split()[0])
    assert abs(l1 - l4) < 0.03, (l1, l4)   # bf16 reduction-order tolerance
