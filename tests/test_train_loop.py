"""Fault-tolerant train loop: convergence, crash/restart exactness,
straggler detection."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.configs.base import ShapeConfig
from repro.launch.mesh import single_device_mesh
from repro.optim import AdamWConfig
from repro.parallel.sharding import RULES_FSDP_TP
from repro.runtime.train_loop import (
    SimulatedCrash,
    StragglerDetector,
    TrainLoop,
    TrainLoopConfig,
)

SHAPE = ShapeConfig("t", seq_len=64, global_batch=4, kind="train")


def _loop(tmp_path, **kw):
    cfg = smoke_variant(get_config("olmo-1b"))
    mesh = single_device_mesh()
    defaults = dict(
        steps=10, ckpt_every=5, ckpt_dir=str(tmp_path / "ckpt"),
        log_every=0, seed=0,
    )
    defaults.update(kw)
    return TrainLoop(
        cfg, SHAPE, mesh, RULES_FSDP_TP,
        TrainLoopConfig(**defaults),
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100),
    )


def test_loss_decreases(tmp_path):
    loop = _loop(tmp_path, steps=30, ckpt_every=30)
    out = loop.run()
    losses = [r.loss for r in loop.records]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    assert out["final_step"] == 30


def test_crash_and_resume_bitwise_identical(tmp_path):
    """A crashed-and-resumed run must equal an uninterrupted run exactly:
    steps are deterministic and the checkpoint stores exact state."""
    # uninterrupted reference
    ref = _loop(tmp_path / "a", steps=10, ckpt_every=5).run()

    # crashed at step 7 (after ckpt at 5), then resumed
    crash = _loop(tmp_path / "b", steps=10, ckpt_every=5, crash_at_step=7)
    with pytest.raises(SimulatedCrash):
        crash.run()
    resumed = _loop(tmp_path / "b", steps=10, ckpt_every=5).run()

    assert resumed["final_step"] == ref["final_step"] == 10
    for a, b in zip(
        jax.tree.leaves(ref["params"]), jax.tree.leaves(resumed["params"])
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_skips_completed_steps(tmp_path):
    l1 = _loop(tmp_path, steps=5, ckpt_every=5)
    l1.run()
    l2 = _loop(tmp_path, steps=5, ckpt_every=5)
    out = l2.run()
    # nothing to do: resume lands at step 5 == steps
    assert out["final_step"] == 5
    assert len(l2.records) == 0


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(factor=2.0, window=10)
    for i in range(10):
        assert not det.observe(i, 0.1)
    assert det.observe(10, 0.5)          # 5x median
    assert det.events == [10]
    assert not det.observe(11, 0.11)


def test_straggler_detector_adapts_to_drift():
    """A slow ramp must not trip the detector (median tracks it)."""
    det = StragglerDetector(factor=3.0, window=10)
    t = 0.1
    for i in range(50):
        assert not det.observe(i, t)
        t *= 1.02
