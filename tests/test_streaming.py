"""Weight-streaming executor: the scheduler's plan driving real (tiled)
compute, with runtime residency assertions -- the software twin of the
paper's URAM allocator."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.pu import PUConfig, PU_2X, TileCost, host_offload_config
from repro.core.streaming import (
    StreamingExecutor,
    StreamingPlan,
    WeightTile,
    gemm_sequence_tiles,
    plan_streaming,
)
from repro.kernels import ref
from repro.plan import plan as plan_tiles
from repro.runtime.serving import model_gemms, plan_model_streaming


TINY_PU = PUConfig(
    name="tiny",
    r_sa=4,
    c_sa=4,
    fast_clock_hz=1e6,
    fast_mem_bytes=512,
    weight_bw_bytes_per_s=1e6,
    act_bw_bytes_per_s=1e6,
)


def test_gemm_sequence_tiling_covers_rows():
    tiles = gemm_sequence_tiles([("a", 10, 8, 3), ("b", 4, 8, 3)], TINY_PU)
    # 10 rows -> 3 tiles of <=4 rows; 4 rows -> 1 tile
    assert len(tiles) == 4
    assert sum(t.n for t in tiles if t.name.startswith("a")) == 10
    assert tiles[0].layer_index == 0 and tiles[-1].layer_index == 1


def test_executor_runs_plan_and_respects_capacity(rng):
    gemms = [(f"g{i}", 8, 16, 4) for i in range(6)]
    tiles = gemm_sequence_tiles(gemms, TINY_PU)
    plan = plan_streaming(tiles, TINY_PU)
    assert plan.schedule.feasible

    weights = {
        t.name: jnp.asarray(rng.integers(-127, 128, (t.n, t.m), dtype=np.int8))
        for t in tiles
    }
    x = jnp.asarray(rng.integers(-127, 128, (16, 4), dtype=np.int8))

    ex = StreamingExecutor(plan, fetch=lambda name: weights[name])
    outs = ex.run([lambda w: ref.int8_gemm_ref(w, x, shift=8) for _ in tiles])

    assert ex.peak_resident_bytes <= TINY_PU.fast_mem_bytes
    assert len(ex.fetches) == len(tiles)
    # compute matches the unstreamed reference tile by tile
    for t, o in zip(tiles, outs):
        np.testing.assert_array_equal(
            np.asarray(o), np.asarray(ref.int8_gemm_ref(weights[t.name], x, shift=8))
        )


def test_executor_streamed_equals_resident_gemm(rng):
    """Row-tiled streamed GEMM == one big GEMM (the paper's tiling is
    exact, not approximate)."""
    n, m, p = 16, 32, 8
    w = jnp.asarray(rng.integers(-127, 128, (n, m), dtype=np.int8))
    x = jnp.asarray(rng.integers(-127, 128, (m, p), dtype=np.int8))
    pu = PUConfig(
        name="t", r_sa=4, c_sa=4, fast_clock_hz=1e6,
        fast_mem_bytes=4096, weight_bw_bytes_per_s=1e6, act_bw_bytes_per_s=1e6,
    )
    tiles = gemm_sequence_tiles([("w", n, m, p)], pu)
    plan = plan_streaming(tiles, pu)
    rows = {t.name: int(t.name.split("rows")[1]) for t in tiles}
    ex = StreamingExecutor(
        plan, fetch=lambda name: w[rows[name] : rows[name] + 4]
    )
    outs = ex.run([lambda wt: ref.int8_gemm_ref(wt, x, shift=6) for _ in tiles])
    got = jnp.concatenate(outs, axis=0)
    want = ref.int8_gemm_ref(w, x, shift=6)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_executor_fetches_follow_plan_issue_order():
    """The load channel is serial: fetches must follow the plan's issue
    queue (sorted by (window, tile)), including adaptive relocations that
    move a later tile's load ahead of earlier tiles' loads."""
    # tile3's 4s load cannot hide in tile2's 1s window but fits tile0's
    # 6s window: the adaptive phase relocates it, putting tile3's load
    # *before* tile2's on the channel.
    costs = [
        TileCost(load_s=1.0, exec_s=6.0, mem_bytes=10),
        TileCost(load_s=1.0, exec_s=1.0, mem_bytes=10),
        TileCost(load_s=1.0, exec_s=1.0, mem_bytes=10),
        TileCost(load_s=4.0, exec_s=1.0, mem_bytes=10),
    ]
    p = plan_tiles(costs, capacity=100)
    assert list(p.windows) == [-1, 0, 1, 0]

    wtiles = [
        WeightTile(name=f"t{i}", layer_index=i, n=1, m=1, p=1)
        for i in range(4)
    ]
    pu = PUConfig(name="x", fast_mem_bytes=100)
    splan = StreamingPlan(tiles=wtiles, plan=p, pu=pu)
    assert splan.issue_order() == [0, 1, 3, 2]

    ex = StreamingExecutor(splan, fetch=lambda name: name)
    outs = ex.run([lambda w: w for _ in wtiles])
    # fetched strictly in plan issue order, executed in index order
    assert ex.fetches == ["t0", "t1", "t3", "t2"]
    assert ex.fetches == [name for name, _ in splan.prefetch_order()]
    assert outs == ["t0", "t1", "t2", "t3"]
    assert ex.peak_resident_bytes <= pu.fast_mem_bytes


def test_infeasible_plan_raises(rng):
    tiles = [WeightTile(name="big", layer_index=0, n=4, m=4096, p=1)]
    plan = plan_streaming(tiles, TINY_PU)   # 4096-entry tile >> 512 B
    assert not plan.schedule.feasible
    ex = StreamingExecutor(plan, fetch=lambda n: None)
    with pytest.raises(AssertionError):
        ex.run([lambda w: None])


# -------------------------------------------------- LM-scale planning -----


@pytest.mark.parametrize("arch", ["olmo-1b", "mixtral-8x7b", "mamba2-780m"])
def test_lm_streaming_plan_feasible(arch):
    cfg = get_config(arch)
    plan = plan_model_streaming(cfg, host_offload_config(), batch_tokens=16)
    assert plan.schedule.feasible
    s = plan.summary()
    assert s["tiles"] > 0
    assert s["adaptive_stall_s"] <= s["baseline_stall_s"] + 1e-12


def test_moe_plans_only_topk_experts():
    cfg = get_config("mixtral-8x7b")
    gemms = model_gemms(cfg, batch_tokens=8)
    expert_ups = [g for g in gemms if "expert" in g[0] and g[0].endswith("up")]
    assert len(expert_ups) == cfg.n_layers * cfg.top_k   # not n_experts


def test_streaming_plan_prefetch_order_valid():
    cfg = get_config("olmo-1b")
    plan = plan_model_streaming(cfg, host_offload_config(), batch_tokens=8)
    order = plan.prefetch_order()
    assert len(order) == len(plan.tiles)
    # windows must reference earlier tiles only
    name_to_idx = {t.name: i for i, t in enumerate(plan.tiles)}
    for name, window in order:
        assert window < name_to_idx[name]
