"""Elastic scaling: a checkpoint written under one mesh restores onto a
different mesh (different device count) with identical values -- the
restart path for fleet resizes."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_py(code: str, devices: int, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r.stdout


TRAIN = """
    import jax, numpy as np
    from repro.configs import get_config, smoke_variant
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_mesh
    from repro.optim import AdamWConfig
    from repro.parallel.sharding import RULES_FSDP_TP
    from repro.runtime.train_loop import TrainLoop, TrainLoopConfig

    cfg = smoke_variant(get_config('olmo-1b'))
    shape = ShapeConfig('t', seq_len=32, global_batch=4, kind='train')
    mesh = make_mesh(MESH_SHAPE, MESH_AXES)
    loop = TrainLoop(cfg, shape, mesh, RULES_FSDP_TP,
        TrainLoopConfig(steps=STEPS, ckpt_every=4, ckpt_dir=CKPT, log_every=0),
        opt_cfg=AdamWConfig(lr=1e-3))
    out = loop.run()
    p = jax.tree.leaves(out['params'])[0]
    print('STEP=%d SUM=%.6f' % (out['final_step'],
          float(sum(float(abs(np.asarray(l)).sum()) for l in jax.tree.leaves(out['params'])))))
"""


def test_restore_onto_different_mesh(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    # phase 1: train 4 steps on a 4-device (2,2) mesh, checkpoint at 4
    o1 = run_py(
        TRAIN.replace("MESH_SHAPE", "(2, 2)")
             .replace("MESH_AXES", '("data", "model")')
             .replace("STEPS", "4")
             .replace("CKPT", repr(ckpt)),
        devices=4,
    )
    # phase 2: resume on a SINGLE device to step 8
    o2 = run_py(
        TRAIN.replace("MESH_SHAPE", "(1,)")
             .replace("MESH_AXES", '("data",)')
             .replace("STEPS", "8")
             .replace("CKPT", repr(ckpt)),
        devices=1,
    )
    # reference: uninterrupted 8 steps on the 4-device mesh
    ckpt_ref = str(tmp_path / "ref")
    o3 = run_py(
        TRAIN.replace("MESH_SHAPE", "(2, 2)")
             .replace("MESH_AXES", '("data", "model")')
             .replace("STEPS", "8")
             .replace("CKPT", repr(ckpt_ref)),
        devices=4,
    )
    s2 = float(o2.split("SUM=")[1].split()[0])
    s3 = float(o3.split("SUM=")[1].split()[0])
    assert "STEP=8" in o2 and "STEP=8" in o3
    # elastic resume tracks the uninterrupted run (bf16 reduction-order tol)
    assert abs(s2 - s3) / max(abs(s3), 1e-9) < 5e-3, (s2, s3)
