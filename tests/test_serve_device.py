"""Device-resident serving round (DESIGN.md SS7): fused sample-append
decode blocks, bucketed batched prefill, per-slot KV positions, admission
terminal conditions, trace-count guards, and greedy bit-identity against
the legacy host-loop engine."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.sanitize import retrace_guard
from repro.configs import get_config, smoke_variant
from repro.models import api as model_api
from repro.runtime.serving import (
    ServeConfig,
    ServingEngine,
    default_prefill_buckets,
    scatter_cache_lanes,
)

_PARAMS = {}


def _engine(arch="olmo-1b", **kw):
    cfg = smoke_variant(get_config(arch))
    if arch not in _PARAMS:
        api = model_api.get_api(cfg)
        _PARAMS[arch] = api.init_params(cfg, jax.random.PRNGKey(0))
    defaults = dict(max_batch=2, max_len=64, max_new_tokens=6, seed=0)
    defaults.update(kw)
    return cfg, ServingEngine(cfg, _PARAMS[arch], ServeConfig(**defaults))


def _prompts(cfg, n, length=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, length).astype(np.int32) for _ in range(n)]


def _mixed_prompts(cfg, n, lo=4, hi=24, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, cfg.vocab, int(l)).astype(np.int32)
        for l in rng.integers(lo, hi, n)
    ]


# ---------------------------------------------------------------------------
# satellite: per-slot KV positions (staggered admissions)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("host_sampling", [True, False])
def test_staggered_admission_kv_positions(host_sampling):
    """A request admitted mid-flight of a longer one must decode exactly
    as if served alone: each lane writes KV at its *own* position.  (The
    pre-PR engine passed max(slot_pos) for every lane, so a later admit
    wrote its KV at the earlier slot's position.)"""
    cfg, alone = _engine(max_batch=1)
    late = _prompts(cfg, 1, length=9, seed=5)[0]
    alone.submit(late.copy())
    ref = alone.run_until_drained()[0].out_tokens

    _, eng = _engine(max_batch=2, host_sampling=host_sampling)
    early = _prompts(cfg, 1, length=14, seed=9)[0]
    eng.submit(early.copy())
    eng.step()                       # early request decodes alone first
    eng.submit(late.copy())          # admitted at a *different* position
    done = {r.uid: r for r in eng.run_until_drained()}
    assert done[1].out_tokens == ref


# ---------------------------------------------------------------------------
# satellite: admission-time terminal conditions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("host_sampling", [True, False])
def test_admit_completes_single_token_budget(host_sampling):
    """max_new_tokens=1 finishes at admission: the prefill-sampled token
    is the whole generation and the slot is never occupied."""
    cfg, eng = _engine(host_sampling=host_sampling)
    eng.submit(_prompts(cfg, 1)[0], max_new_tokens=1)
    eng.step()
    assert len(eng.completed) == 1
    assert len(eng.completed[0].out_tokens) == 1
    assert eng.active == 0


@pytest.mark.parametrize("host_sampling", [True, False])
def test_admit_completes_on_eos_first_token(host_sampling):
    """A request whose first (greedy) token is eos completes at
    admission instead of wasting a decode round."""
    cfg, probe = _engine()
    prompt = _prompts(cfg, 1, seed=3)[0]
    probe.submit(prompt.copy())
    first = probe.run_until_drained()[0].out_tokens[0]

    _, eng = _engine(host_sampling=host_sampling, eos_token=first)
    eng.submit(prompt.copy())
    eng.step()
    assert len(eng.completed) == 1
    assert eng.completed[0].out_tokens == [first]
    assert eng.active == 0


# ---------------------------------------------------------------------------
# bucketed batched prefill
# ---------------------------------------------------------------------------


def test_default_bucket_ladder():
    assert default_prefill_buckets(96) == (16, 32, 64, 96)
    assert default_prefill_buckets(64) == (16, 32, 64)


def test_bucketed_prefill_flag_per_family():
    """Attention-backed families keep bucketed admission; recurrent
    families are exact-length.  (Regression: the encdec flag was once
    silently dropped in a ModelAPI refactor, disabling admission
    batching and warmup's bucket ladder for the whole family.)"""
    want = {
        "olmo-1b": True, "mixtral-8x7b": True, "internvl2-26b": True,
        "whisper-medium": True, "mamba2-780m": False, "zamba2-1.2b": False,
    }
    for arch, flag in want.items():
        cfg = smoke_variant(get_config(arch))
        assert model_api.get_api(cfg).supports_bucketed_prefill is flag, arch


def test_bucketed_prefill_matches_isolated_dense():
    """Right-padded batched prefill is exactly the lane-isolated prefill
    for dense models: logits at each row's last real token and the cache
    up to each row's length are bit-identical."""
    cfg = smoke_variant(get_config("olmo-1b"))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens, S = [7, 12, 16], 16
    rows = []
    iso = []
    for ln in lens:
        p = rng.integers(0, cfg.vocab, ln).astype(np.int32)
        rows.append(np.pad(p, (0, S - ln)))
        iso.append(api.prefill(cfg, params, {"tokens": jnp.asarray(p[None])}))
    logits, cache = api.prefill(
        cfg, params,
        {"tokens": jnp.asarray(np.stack(rows)),
         "lengths": jnp.asarray(lens, jnp.int32)},
    )
    for i, ln in enumerate(lens):
        np.testing.assert_array_equal(
            np.asarray(logits)[i], np.asarray(iso[i][0])[0]
        )
        for leaf_b, leaf_i in zip(
            jax.tree.leaves(cache), jax.tree.leaves(iso[i][1])
        ):
            np.testing.assert_array_equal(
                np.asarray(leaf_b[:, i, :ln]), np.asarray(leaf_i[:, 0, :ln])
            )


def test_bucketed_prefill_matches_isolated_moe():
    """MoE routing shares expert capacity across the token batch, so
    batched prefill is equivalent only up to the capacity coupling
    (documented in DESIGN.md SS7): logits stay close, but greedy
    decisions can legitimately move between near-tied candidates."""
    cfg = smoke_variant(get_config("mixtral-8x7b"))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens, S = [9, 14], 16
    rows, iso = [], []
    for ln in lens:
        p = rng.integers(0, cfg.vocab, ln).astype(np.int32)
        rows.append(np.pad(p, (0, S - ln)))
        iso.append(
            np.asarray(
                api.prefill(cfg, params, {"tokens": jnp.asarray(p[None])})[0]
            )[0]
        )
    logits, _ = api.prefill(
        cfg, params,
        {"tokens": jnp.asarray(np.stack(rows)),
         "lengths": jnp.asarray(lens, jnp.int32)},
    )
    logits = np.asarray(logits)
    for i in range(len(lens)):
        np.testing.assert_allclose(logits[i], iso[i], atol=0.15, rtol=0.1)


def test_ring_configs_refuse_lengths_and_fall_back():
    """kv_ring prefill re-lays out the whole sequence; bucketed lengths
    must be rejected at the model layer and gated off in the engine."""
    base = smoke_variant(get_config("mixtral-8x7b"))
    ring = dataclasses.replace(base, kv_ring=True, n_experts=0, top_k=0)
    api = model_api.get_api(ring)
    params = api.init_params(ring, jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 16), jnp.int32)
    with pytest.raises(ValueError):
        api.prefill(
            ring, params,
            {"tokens": toks, "lengths": jnp.asarray([9], jnp.int32)},
        )
    eng = ServingEngine(
        ring, params, ServeConfig(max_batch=2, max_len=64, max_new_tokens=4)
    )
    assert not eng.bucketed_prefill
    eng.submit(np.zeros(9, np.int32))
    done = eng.run_until_drained()
    assert len(done) == 1 and len(done[0].out_tokens) == 4


def test_vlm_short_prompt_served_via_bucket_padding():
    """Prompts shorter than the vision patch count only fit because the
    bucket pads them (the lane-isolated path cannot embed 8 patches into
    a 4-token sequence)."""
    cfg, eng = _engine("internvl2-26b")
    assert eng.bucketed_prefill
    eng.submit(_prompts(cfg, 1, length=4)[0])
    done = eng.run_until_drained()
    assert len(done) == 1 and len(done[0].out_tokens) == 6


# ---------------------------------------------------------------------------
# trace-count guards
# ---------------------------------------------------------------------------


def test_mixed_lengths_in_bucket_share_one_prefill_trace():
    cfg, eng = _engine(max_batch=2)
    eng.submit(_prompts(cfg, 1, length=9, seed=0)[0])
    eng.submit(_prompts(cfg, 1, length=13, seed=1)[0])
    eng.step()      # both admitted in one round, same 16-bucket
    assert eng.trace_counts["prefill"] == 1
    eng.run_until_drained()
    assert eng.trace_counts["prefill"] == 1


def test_warmup_makes_mixed_traffic_retrace_free():
    cfg, eng = _engine(max_batch=4, max_len=96, max_new_tokens=8)
    eng.warmup()
    with retrace_guard(eng.tracing):
        for p in _mixed_prompts(cfg, 10, lo=4, hi=40, seed=7):
            eng.submit(p)
        done = eng.run_until_drained()
    assert len(done) == 10


# ---------------------------------------------------------------------------
# greedy bit-identity: device-resident loop vs the legacy host loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["olmo-1b", "gemma3-12b", "whisper-medium"])
def test_device_loop_bit_identical_to_host_loop(arch):
    """Property (seeded scenarios): under greedy sampling, the fused
    device-resident engine emits exactly the host-loop engine's token
    streams -- including staggered admissions, queueing, and slot reuse.
    (MoE configs are excluded: expert capacity is shared across the
    batch, so *any* admission regrouping legitimately perturbs logits --
    see DESIGN.md SS7.)"""
    for seed in range(3):
        rng = np.random.default_rng(100 + seed)
        cfg, host = _engine(arch, max_batch=3, max_len=96, host_sampling=True)
        _, dev = _engine(arch, max_batch=3, max_len=96)
        n0, n1 = int(rng.integers(2, 5)), int(rng.integers(1, 4))
        wave0 = _mixed_prompts(cfg, n0, lo=4, hi=30, seed=200 + seed)
        wave1 = _mixed_prompts(cfg, n1, lo=4, hi=30, seed=300 + seed)
        if cfg.family == "vlm":
            wave0 = [np.pad(p, (0, cfg.vision_patches)) for p in wave0]
            wave1 = [np.pad(p, (0, cfg.vision_patches)) for p in wave1]
        for e in (host, dev):
            for p in wave0:
                e.submit(p.copy())
            e.step()                      # wave0 in flight...
            for p in wave1:
                e.submit(p.copy())        # ...wave1 admitted staggered
        dh = {r.uid: r.out_tokens for r in host.run_until_drained()}
        dd = {r.uid: r.out_tokens for r in dev.run_until_drained()}
        assert dh == dd


def test_block_decode_advances_rounds_in_fused_steps():
    """A lone request with budget N takes its N-1 decode rounds in fused
    pow2 blocks: far fewer host syncs than rounds."""
    cfg, eng = _engine(max_new_tokens=9)
    eng.submit(_prompts(cfg, 1)[0])
    steps = 0
    while eng.pending or eng.active:
        eng.step()
        steps += 1
    assert len(eng.completed[0].out_tokens) == 9
    assert eng.rounds == 8                 # 8 decode rounds after prefill
    assert steps <= 2                      # 8 -> one block of 8 (+ admit)


def test_device_temperature_sampling_serves():
    cfg, eng = _engine(temperature=0.8, max_new_tokens=5)
    for p in _prompts(cfg, 3):
        eng.submit(p)
    done = eng.run_until_drained()
    assert len(done) == 3
    for r in done:
        assert len(r.out_tokens) == 5
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_device_temperature_is_seed_deterministic():
    cfg, e1 = _engine(temperature=0.8)
    _, e2 = _engine(temperature=0.8)
    ps = _prompts(cfg, 3)
    for p in ps:
        e1.submit(p.copy())
        e2.submit(p.copy())
    d1 = e1.run_until_drained()
    d2 = e2.run_until_drained()
    for a, b in zip(d1, d2):
        assert a.out_tokens == b.out_tokens


@pytest.mark.parametrize(
    "arch", ["mamba2-780m", "zamba2-1.2b", "whisper-medium", "granite-moe-3b-a800m"]
)
def test_all_families_drain_on_device_path(arch):
    cfg, eng = _engine(arch, max_batch=2)
    for p in _mixed_prompts(cfg, 5, lo=4, hi=20, seed=2):
        eng.submit(p)
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 6 for r in done)
    s = eng.stats()
    assert s["device_resident"] == 1.0
    assert s["tokens"] == 30.0


@pytest.mark.parametrize("host_sampling", [True, False])
def test_oversized_generation_budget_clamped(host_sampling):
    """max_new_tokens >= max_len must not crash admission or silently
    drop the prompt head to nothing: the budget clamps to max_len - 2
    and at least one prompt token survives truncation."""
    cfg, eng = _engine(max_len=32, host_sampling=host_sampling)
    eng.submit(_prompts(cfg, 1, length=40)[0], max_new_tokens=60)
    done = eng.run_until_drained()
    assert len(done) == 1
    assert 1 <= len(done[0].out_tokens) <= 30


# ---------------------------------------------------------------------------
# satellite: recurrent families must reject bucketed lengths loudly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-1.2b"])
def test_recurrent_prefill_rejects_lengths(arch):
    """ssm/hybrid prefill used to silently drop batch['lengths']: a
    caller padding prompts would push the pad tail through the conv/SSD
    state and serve corrupted prefills.  Now it raises."""
    cfg = smoke_variant(get_config(arch))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.zeros((1, 16), jnp.int32),
        "lengths": jnp.asarray([9], jnp.int32),
    }
    with pytest.raises(ValueError, match="bucketed prefill"):
        api.prefill(cfg, params, batch)
    # lengths=None passes through untouched
    logits, _ = api.prefill(
        cfg, params, {"tokens": jnp.zeros((1, 16), jnp.int32),
                      "lengths": None},
    )
    assert logits.shape == (1, cfg.vocab)


# ---------------------------------------------------------------------------
# satellite: ring-budget boundary (prompt of length max_len - 1)
# ---------------------------------------------------------------------------


def test_prompt_max_len_minus_one_keeps_full_context():
    """keep = max_len - max_new: a length max_len - 1 prompt with a
    1-token budget fits whole (no decode write ever lands past the
    ring).  The old ``- 1`` clamp silently dropped its first token --
    the reference engine with a roomier cache exposes the difference."""
    cfg, big = _engine(max_len=128)
    prompt = _prompts(cfg, 1, length=63, seed=17)[0]
    big.submit(prompt.copy(), max_new_tokens=1)
    ref = big.run_until_drained()[0].out_tokens

    _, tight = _engine(max_len=64)
    tight.submit(prompt.copy(), max_new_tokens=1)
    assert tight.run_until_drained()[0].out_tokens == ref


@pytest.mark.parametrize("host_sampling", [True, False])
def test_budget_boundary_emits_full_generation(host_sampling):
    """At keep = max_len - max_new exactly, all max_new tokens emit and
    every KV write stays in bounds (the last lands at max_len - 2)."""
    max_len, max_new = 64, 6
    cfg, eng = _engine(
        max_len=max_len, max_new_tokens=max_new, host_sampling=host_sampling
    )
    prompt = _prompts(cfg, 1, length=max_len - 1, seed=23)[0]
    eng.submit(prompt.copy())
    done = eng.run_until_drained()
    assert len(done) == 1
    assert len(done[0].out_tokens) == max_new


def test_budget_boundary_host_device_parity():
    max_len, max_new = 64, 6
    cfg, host = _engine(
        max_len=max_len, max_new_tokens=max_new, host_sampling=True
    )
    _, dev = _engine(max_len=max_len, max_new_tokens=max_new)
    prompt = _prompts(cfg, 1, length=max_len - 1, seed=29)[0]
    host.submit(prompt.copy())
    dev.submit(prompt.copy())
    assert (
        host.run_until_drained()[0].out_tokens
        == dev.run_until_drained()[0].out_tokens
    )


# ---------------------------------------------------------------------------
# satellite: eos_token == 0 is a real stop token on both paths
# ---------------------------------------------------------------------------


def _zeroed_engine(host_sampling, eos_token):
    """All-zero params make every logit equal, so greedy argmax always
    emits token 0 -- the only way to force the id-0 boundary case."""
    cfg = smoke_variant(get_config("olmo-1b"))
    api = model_api.get_api(cfg)
    params = jax.tree.map(
        jnp.zeros_like, api.init_params(cfg, jax.random.PRNGKey(0))
    )
    eng = ServingEngine(
        cfg, params,
        ServeConfig(
            max_batch=2, max_len=64, max_new_tokens=6,
            host_sampling=host_sampling, eos_token=eos_token,
        ),
    )
    return cfg, eng


@pytest.mark.parametrize("host_sampling", [True, False])
def test_eos_token_zero_stops_generation(host_sampling):
    """eos_token=0 must terminate (the guards read ``>= 0``); with
    all-equal logits greedy emits 0 immediately, so the request
    completes at admission with exactly one token."""
    cfg, eng = _zeroed_engine(host_sampling, eos_token=0)
    eng.submit(_prompts(cfg, 1)[0])
    done = eng.run_until_drained()
    assert len(done) == 1
    assert done[0].out_tokens == [0]
    assert eng.active == 0


@pytest.mark.parametrize("host_sampling", [True, False])
def test_negative_eos_disables_stopping(host_sampling):
    """eos_token=-1 ("never stop") must NOT treat the emitted 0s as
    terminal: the full budget runs."""
    cfg, eng = _zeroed_engine(host_sampling, eos_token=-1)
    eng.submit(_prompts(cfg, 1)[0])
    done = eng.run_until_drained()
    assert done[0].out_tokens == [0] * 6


# ---------------------------------------------------------------------------
# satellite: the MoE host/device greedy divergence, narrowed
# ---------------------------------------------------------------------------


def test_moe_divergence_is_exactly_padded_batched_admission():
    """PR 4 documented mixtral's host/device greedy divergence as
    "shared expert capacity".  Narrowed: with *exact-length* prompts
    admitted one per round (no bucket padding, no admission grouping)
    the device engine is bit-identical to the host loop even for MoE --
    decode itself and isolated admission are exact.  The divergence is
    entirely the capacity term's dependence on the padded/grouped
    prefill token count, asserted on the capacity function below."""
    cfg = smoke_variant(get_config("mixtral-8x7b"))
    api = model_api.get_api(cfg)
    if "mixtral-8x7b" not in _PARAMS:
        _PARAMS["mixtral-8x7b"] = api.init_params(cfg, jax.random.PRNGKey(0))
    params = _PARAMS["mixtral-8x7b"]
    S = 12
    mk = lambda host: ServingEngine(
        cfg, params,
        ServeConfig(
            max_batch=3, max_len=64, max_new_tokens=5,
            host_sampling=host, max_decode_block=1,
            prefill_buckets=(S,),          # exact-length: zero padding
        ),
    )
    host, dev = mk(True), mk(False)
    prompts = _prompts(cfg, 4, length=S, seed=31)
    for p in prompts:                      # one admission per round on
        host.submit(p.copy())              # both engines: same grouping
        dev.submit(p.copy())
        host.step()
        dev.step()
    dh = {r.uid: r.out_tokens for r in host.run_until_drained()}
    dd = {r.uid: r.out_tokens for r in dev.run_until_drained()}
    assert dh == dd

    # ...and the mechanism: expert capacity is a function of the total
    # token count, so right-padding 12 -> 16 changes routing capacity
    from repro.models.mlp import moe_capacity

    assert moe_capacity(cfg, S) != moe_capacity(cfg, 16)


def test_scatter_cache_lanes_drops_out_of_bounds_rows():
    full = (jnp.zeros((2, 4, 8, 2, 3)),)
    one = (jnp.ones((2, 2, 5, 2, 3)),)
    out = scatter_cache_lanes(full, one, jnp.asarray([1, 4]))  # 4 = OOB dummy
    a = np.asarray(out[0])
    assert a[:, 1, :5].min() == 1.0
    assert a[:, [0, 2, 3]].max() == 0.0
