"""Paper-faithful INT8 ResNet path: conv-as-GEMM through the Pallas kernels
with power-of-two scaling, and agreement with the float reference."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.quant import quantize
from repro.models import resnet


@pytest.mark.parametrize("variant", [18, 50])
def test_conv_specs_consistent(variant):
    specs = resnet.resnet_conv_specs(variant)
    names = [s.name for s in specs]
    assert len(names) == len(set(names))
    # every residual_from reference resolves
    for s in specs:
        if s.residual_from and s.residual_from != "block_in":
            assert s.residual_from in names
    # conv counts: 18 -> 17 convs + downsamples; 50 -> 49 + downsamples
    n_main = sum(1 for s in specs if not s.name.endswith("down"))
    assert n_main == (17 if variant == 18 else 49)


def test_int8_forward_runs_small_image(key):
    """Full int8 graph on a reduced image (28x28) -- the dataflow is size-
    agnostic; ImageNet-size runs in the benchmark harness."""
    params = resnet.init_params(18, key, num_classes=10)
    img = jnp.asarray(
        np.random.default_rng(0).integers(-64, 64, (28, 28, 3), dtype=np.int8)
    )
    logits = resnet.forward_int8(18, params, img)
    assert logits.shape == (10,)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(jnp.max(jnp.abs(logits))) > 0   # non-degenerate


def test_int8_tracks_float_reference(key):
    """Top-1 agreement between the int8 path and the float reference on
    random inputs (power-of-two quantization is coarse; require the int8
    logits to correlate strongly with the float logits)."""
    params = resnet.init_params(18, key, num_classes=10)
    rng = np.random.default_rng(1)
    agree = 0
    corrs = []
    for i in range(3):
        img8 = jnp.asarray(rng.integers(-100, 100, (28, 28, 3), dtype=np.int8))
        li = np.asarray(resnet.forward_int8(18, params, img8), np.float32)
        lf = np.asarray(
            resnet.forward_float(18, params, img8.astype(jnp.float32)), np.float32
        )
        corrs.append(np.corrcoef(li, lf)[0, 1])
        agree += int(np.argmax(li) == np.argmax(lf))
    assert np.mean(corrs) > 0.7, corrs


def test_maxpool_int8(key):
    x = jnp.asarray(
        np.random.default_rng(0).integers(-128, 128, (8, 8, 4), dtype=np.int8)
    )
    y = resnet._maxpool_int8(x)
    assert y.shape == (4, 4, 4)
    # max-pool output >= any input in its window
    xf = np.asarray(x, np.int32)
    yf = np.asarray(y, np.int32)
    assert yf[0, 0, 0] == xf[:2, :2, 0].max()   # corner window (pad=-128)
