"""RPL002 bad fixture: eager host ops inside the decode round and in a
helper it calls."""
import numpy as np


class Runner:
    def _tick(self, state):
        # reachable helper: np.asarray pulls device data to the host
        return np.asarray(state["pos"])

    def decode_round(self, tokens, pos):
        n = int(pos[0])          # host sync per round
        host_pos = self._tick({"pos": pos})
        return tokens, n, host_pos
