"""RPL004 good fixture: every shared write happens under the lock."""
import threading


class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self._count = 0
        self._thread = threading.Thread(target=self._run)

    def _run(self):
        with self._lock:
            self._count += 1

    def bump(self):
        with self._lock:
            self._count += 1
