"""RPL001 bad fixture: PR 6's bug class, reconstructed.

A scan-block's inputs are donated (`donate_argnums=(1, 2)`), then the
caller reads the donated cache/state objects after the call -- in the
real engine this forced XLA to re-specialize layouts and recompile the
block on every barrier (20-69 ms each)."""
import jax


def _block_impl(params, cache, state, n_rounds):
    return cache, state


class Engine:
    def __init__(self, params):
        self.params = params
        self.cache = {"k": None}
        self.state = {"tokens": None}
        self._block = jax.jit(
            _block_impl, static_argnums=3, donate_argnums=(1, 2)
        )

    def step(self, n_rounds):
        out_cache, out_state = self._block(
            self.params, self.cache, self.state, n_rounds
        )
        # BUG: self.cache / self.state were donated -- their buffers
        # are gone; these eager reads force a layout re-specialization
        emitted = self.cache["k"]
        flags = self.state["tokens"]
        return out_cache, out_state, emitted, flags
