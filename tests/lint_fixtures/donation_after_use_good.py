"""RPL001 good fixture: reassign the donated names at the call, then
read the *new* buffers (the device-resident idiom)."""
import jax


def _block_impl(params, cache, state, n_rounds):
    return cache, state


class Engine:
    def __init__(self, params):
        self.params = params
        self.cache = {"k": None}
        self.state = {"tokens": None}
        self._block = jax.jit(
            _block_impl, static_argnums=3, donate_argnums=(1, 2)
        )

    def step(self, n_rounds):
        self.cache, self.state = self._block(
            self.params, self.cache, self.state, n_rounds
        )
        emitted = self.cache["k"]
        flags = self.state["tokens"]
        return emitted, flags
