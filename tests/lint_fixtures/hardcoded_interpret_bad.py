"""RPL003 bad fixture: a kernel entry point pins interpret=True and a
pallas_call passes a literal, bypassing default_interpret()."""


def pallas_call(fn, interpret=False):
    return fn


def my_kernel(x, interpret=True):
    return pallas_call(lambda ref: ref, interpret=True)
