"""RPL004 bad fixture: a threaded class mutates shared state outside
its lock."""
import threading


class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self._count = 0
        self._thread = threading.Thread(target=self._run)

    def _run(self):
        self._count += 1      # BUG: races with bump()

    def bump(self):
        with self._lock:
            self._count += 1
