"""RPL005 good fixture: the config argument is static."""
import jax


def step(cfg, params, batch):
    return params


step_jit = jax.jit(step, static_argnums=(0,))
