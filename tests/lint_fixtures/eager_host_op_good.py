"""RPL002 good fixture: the round stays on device; the host sync lives
at the block boundary (a function *not* reachable from the round)."""
import numpy as np


class Runner:
    def _tick(self, state):
        return state["pos"] + 1

    def decode_round(self, tokens, pos):
        pos = self._tick({"pos": pos})
        return tokens, pos

    def drain_block(self, state):
        # block-boundary sync: not a decode-round root, not called
        # from one
        return np.asarray(state["out"])
