"""RPL005 bad fixture: a config object is traced instead of being
marked static (every distinct config retraces, and hashing fails for
mutable configs)."""
import jax


def step(cfg, params, batch):
    return params


step_jit = jax.jit(step)
