"""RPL003 good fixture: interpret defaults to None and resolves
through the backend-aware default at call time."""


def pallas_call(fn, interpret=None):
    return fn


def default_interpret():
    return False


def my_kernel(x, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return pallas_call(lambda ref: ref, interpret=interpret)
