"""Per-architecture smoke tests: every assigned arch instantiates a reduced
same-family config, runs forward/train/decode on CPU, asserts shapes and
finiteness -- plus decode-vs-prefill consistency and attention-variant
semantics."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.configs.base import ShapeConfig
from repro.models import api as model_api


SMOKE_TRAIN = ShapeConfig("smoke_train", seq_len=32, global_batch=2, kind="train")
SMOKE_PREFILL = ShapeConfig("smoke_prefill", seq_len=32, global_batch=2, kind="prefill")


def _setup(arch):
    cfg = smoke_variant(get_config(arch))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, api, params


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch):
    cfg, api, params = _setup(arch)
    batch = model_api.make_concrete(
        model_api.batch_struct(cfg, SMOKE_TRAIN), vocab=cfg.vocab
    )
    loss = api.train_loss(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: api.train_loss(cfg, p, batch))(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_and_decode_shapes(arch):
    cfg, api, params = _setup(arch)
    batch = model_api.make_concrete(
        model_api.batch_struct(cfg, SMOKE_PREFILL), vocab=cfg.vocab
    )
    logits, cache = api.prefill(cfg, params, batch)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    toks = jnp.ones((2, 1), jnp.int32)
    logits2, cache2 = api.decode_step(cfg, params, cache, toks, jnp.int32(32))
    assert logits2.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-780m", "zamba2-1.2b"])
def test_decode_matches_prefill_next_token(arch):
    """Greedy next-token from (prefill S) == argmax from (prefill S-1 +
    decode 1 step): the cache path computes the same function."""
    cfg, api, params = _setup(arch)
    rng = np.random.default_rng(0)
    s = 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, s)), jnp.int32)

    logits_full, _ = api.prefill(cfg, params, {"tokens": tokens})

    cache = api.init_cache(cfg, 1, 64)
    logits_pre, cache = _prefill_into(api, cfg, params, tokens[:, : s - 1], cache)
    logits_dec, _ = api.decode_step(
        cfg, params, cache, tokens[:, s - 1 :], jnp.int32(s - 1)
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=0.06, atol=0.08,   # bf16 accumulation differences
    )


def _prefill_into(api, cfg, params, tokens, cache):
    """Token-by-token decode as a prefill substitute (exercises the cache)."""
    logits = None
    for i in range(tokens.shape[1]):
        logits, cache = api.decode_step(
            cfg, params, cache, tokens[:, i : i + 1], jnp.int32(i)
        )
    return logits, cache


def test_sliding_window_masks_long_range():
    """A window-w arch must ignore tokens > w behind; verify by perturbing a
    distant token and asserting the last-token logits are unchanged.

    Uses a dense variant of the SWA config: with MoE the expert-capacity
    limit couples *all* tokens (a displaced token changes other tokens'
    slots), so masking must be tested without routing in the way."""
    import dataclasses

    cfg = smoke_variant(get_config("mixtral-8x7b"))
    cfg = dataclasses.replace(cfg, n_experts=0, top_k=0)
    assert cfg.window and not cfg.is_moe
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    # receptive field grows by ~window per layer: put the perturbed token
    # beyond n_layers * window so NO path reaches the last position.
    s = cfg.n_layers * cfg.window + 24
    toks = rng.integers(0, cfg.vocab, (1, s)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, 0] = (toks2[0, 0] + 7) % cfg.vocab      # outside the window
    l1, _ = api.prefill(cfg, params, {"tokens": jnp.asarray(toks)})
    l2, _ = api.prefill(cfg, params, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l2, np.float32), atol=1e-5
    )


def test_global_layers_see_past_window():
    """gemma3's every-Nth global layer must NOT be windowed: perturbing a
    distant token must change the output."""
    cfg = smoke_variant(get_config("gemma3-12b"))
    assert cfg.global_every
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    s = cfg.window + 24
    toks = rng.integers(0, cfg.vocab, (1, s)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, 0] = (toks2[0, 0] + 7) % cfg.vocab
    l1, _ = api.prefill(cfg, params, {"tokens": jnp.asarray(toks)})
    l2, _ = api.prefill(cfg, params, {"tokens": jnp.asarray(toks2)})
    assert float(np.abs(np.asarray(l1) - np.asarray(l2)).max()) > 1e-6


def test_moe_routes_to_topk():
    """Granite MoE: aux (load-balance) loss finite and > 0; logits vary
    with expert params."""
    cfg = smoke_variant(get_config("granite-moe-3b-a800m"))
    assert cfg.is_moe and cfg.top_k >= 1
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = model_api.make_concrete(
        model_api.batch_struct(cfg, SMOKE_TRAIN), vocab=cfg.vocab
    )
    loss = api.train_loss(cfg, params, batch)
    assert bool(jnp.isfinite(loss))


def test_whisper_uses_encoder_frames():
    """encdec: changing the stub frames must change decoder logits
    (cross-attention is live)."""
    cfg = smoke_variant(get_config("whisper-medium"))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    f1 = jnp.asarray(rng.standard_normal((1, cfg.encoder_frames, cfg.d_model)), jnp.bfloat16)
    f2 = f1 + 1.0
    l1, _ = api.prefill(cfg, params, {"tokens": toks, "frames": f1})
    l2, _ = api.prefill(cfg, params, {"tokens": toks, "frames": f2})
    assert float(np.abs(np.asarray(l1, np.float32) - np.asarray(l2, np.float32)).max()) > 1e-6


def test_vlm_uses_patch_embeds():
    cfg = smoke_variant(get_config("internvl2-26b"))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 16)), jnp.int32)
    p1 = jnp.asarray(rng.standard_normal((1, cfg.vision_patches, cfg.d_model)), jnp.bfloat16)
    l1, _ = api.prefill(cfg, params, {"tokens": toks, "patch_embeds": p1})
    l2, _ = api.prefill(cfg, params, {"tokens": toks, "patch_embeds": p1 + 1.0})
    assert float(np.abs(np.asarray(l1, np.float32) - np.asarray(l2, np.float32)).max()) > 1e-6


def test_mamba2_chunked_prefill_matches_recurrent_decode():
    """SSD chunked scan (prefill) and recurrent step (decode) implement the
    same recurrence."""
    cfg = smoke_variant(get_config("mamba2-780m"))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    s = 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, s)), jnp.int32)
    logits_full, _ = api.prefill(cfg, params, {"tokens": toks})
    # recurrent: decode token by token
    cache = api.init_cache(cfg, 1, s + 8)
    logits = None
    for i in range(s):
        logits, cache = api.decode_step(
            cfg, params, cache, toks[:, i : i + 1], jnp.int32(i)
        )
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=0.08, atol=0.12,
    )


def test_param_counts_match_full_configs():
    """Full (unreduced) configs report param counts in the right ballpark
    (catches config transcription errors)."""
    expect = {
        "olmo-1b": (1.0e9, 1.6e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "mixtral-8x7b": (42e9, 50e9),
        "starcoder2-15b": (13e9, 17e9),
        "gemma3-12b": (10e9, 14e9),
        "nemotron-4-15b": (14e9, 18e9),
        "whisper-medium": (0.6e9, 1.1e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "granite-moe-3b-a800m": (2.5e9, 4.0e9),
        "internvl2-26b": (18e9, 27e9),  # backbone (ViT is a stub)
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"


def test_moe_active_params_below_total():
    cfg = get_config("mixtral-8x7b")
    assert cfg.active_param_count() < cfg.param_count()
    # Mixtral: ~13B active of ~47B
    assert 11e9 < cfg.active_param_count() < 15e9
