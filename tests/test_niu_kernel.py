"""NIU Pallas kernel (paper SS VI as a hardware block): oracle agreement,
determinism, and noise statistics."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.niu import niu_refresh, niu_refresh_ref


def _q(rng, shape):
    return jnp.asarray(rng.integers(-127, 128, shape, dtype=np.int8))


@pytest.mark.parametrize(
    "r,c,br,bc",
    [
        (256, 256, 256, 256),
        (300, 200, 256, 256),     # padded
        (64, 512, 32, 128),
        (100, 100, 64, 64),
    ],
)
def test_kernel_matches_oracle(rng, r, c, br, bc):
    q = _q(rng, (r, c))
    got = niu_refresh(q, jnp.int32(-4), 7, block_r=br, block_c=bc)
    want = niu_refresh_ref(q, jnp.int32(-4), 7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_deterministic_per_seed(rng):
    q = _q(rng, (128, 128))
    a = niu_refresh(q, jnp.int32(-3), 42)
    b = niu_refresh(q, jnp.int32(-3), 42)
    c = niu_refresh(q, jnp.int32(-3), 43)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (np.asarray(a) != np.asarray(c)).any()


def test_noise_statistics(rng):
    """Perturbation std in q-units ~ scale*(0.25|q| + 0.05 qmax)."""
    q = jnp.full((512, 512), 64, jnp.int8)
    out = niu_refresh(
        q, jnp.int32(0), 1, prog_noise_scale=0.1, read_noise_scale=0.0
    )
    err = np.asarray(out, np.int32) - 64
    # w_max is the tile's own max (64 here); rounding adds var 1/12
    expected = np.sqrt((0.1 * (0.25 * 64 + 0.05 * 64)) ** 2 + 1 / 12)
    assert err.std() == pytest.approx(expected, rel=0.1)
    assert abs(err.mean()) < 0.1


def test_zero_noise_is_identity(rng):
    q = _q(rng, (96, 96))
    out = niu_refresh(
        q, jnp.int32(-2), 5,
        prog_noise_scale=0.0, read_noise_scale=0.0, drift=1.0,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(q))


def test_drift_shrinks(rng):
    q = jnp.full((64, 64), 100, jnp.int8)
    out = niu_refresh(
        q, jnp.int32(0), 3,
        prog_noise_scale=0.0, read_noise_scale=0.0, drift=0.8,
    )
    np.testing.assert_array_equal(np.asarray(out), 80)


def test_saturation(rng):
    """Large read noise saturates to int8 range, never wraps."""
    q = _q(rng, (64, 64))
    out = np.asarray(
        niu_refresh(q, jnp.int32(0), 9, prog_noise_scale=2.0, read_noise_scale=1.0)
    )
    assert out.min() >= -128 and out.max() <= 127
