"""Data pipeline: determinism, exact restartability, elastic sharding."""
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticLMDataset, TokenFileDataset, build_dataset, shard_batch


CFG = DataConfig(seq_len=64, global_batch=8, vocab=512, seed=3)


def test_batch_is_pure_function_of_step():
    ds1, ds2 = SyntheticLMDataset(CFG), SyntheticLMDataset(CFG)
    for step in (0, 5, 1000):
        a, b = ds1.batch(step), ds2.batch(step)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


def test_different_steps_different_batches():
    ds = SyntheticLMDataset(CFG)
    assert not np.array_equal(ds.batch(0)["tokens"], ds.batch(1)["tokens"])


def test_labels_are_next_tokens():
    ds = SyntheticLMDataset(CFG)
    b = ds.batch(0)
    assert b["tokens"].shape == (8, 64)
    assert b["labels"].shape == (8, 64)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < CFG.vocab


def test_bigram_structure_present():
    """Even positions follow the deterministic bigram map -- the learnable
    structure that makes train-loss decrease meaningful."""
    ds = SyntheticLMDataset(CFG)
    b = ds.batch(0)
    full = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    hits = 0
    total = 0
    for i in range(1, 64, 2):   # positions where the follow-rule applied
        pred = (full[:, i] * 31 + 7) % CFG.bigram_period % CFG.vocab
        hits += int((full[:, i + 1] == pred).sum())
        total += full.shape[0]
    assert hits / total > 0.9


def test_shard_batch_partitions_rows():
    ds = SyntheticLMDataset(CFG)
    b = ds.batch(0)
    parts = [shard_batch(b, i, 4) for i in range(4)]
    recon = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(recon, b["tokens"])


def test_elastic_reshard_same_stream():
    """2 hosts vs 4 hosts see the same global data for the same step."""
    ds = SyntheticLMDataset(CFG)
    b = ds.batch(11)
    two = np.concatenate([shard_batch(b, i, 2)["tokens"] for i in range(2)])
    four = np.concatenate([shard_batch(b, i, 4)["tokens"] for i in range(4)])
    np.testing.assert_array_equal(two, four)


def test_token_file_dataset(tmp_path):
    tokens = np.arange(10_000, dtype=np.int32) % 400
    f = tmp_path / "tokens.bin"
    tokens.tofile(f)
    cfg = DataConfig(seq_len=32, global_batch=4, vocab=512, kind="token_file", path=str(f))
    ds = build_dataset(cfg)
    b = ds.batch(0)
    assert b["tokens"].shape == (4, 32)
    # labels shifted by one
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    # determinism
    b2 = TokenFileDataset(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])
