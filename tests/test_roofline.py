"""Roofline analysis: HLO collective parsing (trip-count aware), jaxpr FLOP
counting, and the three-term computation."""
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import roofline as rl
from repro.analysis.jaxpr_cost import count_flops, step_flops


# --------------------------------------------------- collective parsing ---

HLO_FLAT = """
HloModule test

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256] parameter(0)
  %ag = f32[256,256] all-gather(%a), dimensions={0}
  %ar = f32[128,256] all-reduce(%a), to_apply=%add
  ROOT %out = f32[128,256] add(%ar, %a)
}
"""


def test_parse_flat_collectives():
    stats = rl.parse_collectives(HLO_FLAT)
    assert stats.op_bytes["all-gather"] == 256 * 256 * 4
    assert stats.op_bytes["all-reduce"] == 128 * 256 * 4
    assert stats.op_counts["all-gather"] == 1
    # ring all-reduce wire estimate 2x
    assert stats.wire_bytes == pytest.approx(
        256 * 256 * 4 + 2 * 128 * 256 * 4
    )


HLO_WHILE = """
HloModule scanny

%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64] get-tuple-element(%p), index=1
  %rs = f32[64] reduce-scatter(%x), dimensions={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64]) tuple(%ni, %rs)
}

%cond (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[64]) -> f32[64] {
  %x = f32[64] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[64]) tuple(%zero, %x)
  %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[64] get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_multiplies_collectives():
    stats = rl.parse_collectives(HLO_WHILE)
    # reduce-scatter of 64 f32 = 256 B, x12 trips
    assert stats.op_bytes["reduce-scatter"] == pytest.approx(12 * 256)
    assert stats.op_counts["reduce-scatter"] == 12


HLO_ASYNC = """
HloModule asyncy

ENTRY %main (x: f32[32]) -> f32[32] {
  %x = f32[32] parameter(0)
  %ags = (f32[32], f32[64]) all-gather-start(%x), dimensions={0}
  %agd = f32[64] all-gather-done(%ags)
  ROOT %o = f32[32] slice(%agd), slice={[0:32]}
}
"""


def test_async_start_done_counted_once():
    stats = rl.parse_collectives(HLO_ASYNC)
    # start carries (input, output) tuple = (128 + 256)/2 = 192 halved;
    # done must not double count
    assert stats.op_counts["all-gather"] == 1
    assert stats.op_bytes["all-gather"] == pytest.approx((32 * 4 + 64 * 4) / 2)


def test_shape_bytes_dtypes():
    assert rl._shape_bytes("bf16", "128,256") == 128 * 256 * 2
    assert rl._shape_bytes("s8", "64") == 64
    assert rl._shape_bytes("f32", "") == 4      # scalar


# ------------------------------------------------------- FLOP counting ----


def test_flops_matmul_exact():
    f = lambda a, b: a @ b
    specs = (
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 32), jnp.float32),
    )
    flops = step_flops(f, specs)
    assert flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_flops_scan_multiplies():
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    specs = (jax.ShapeDtypeStruct((32, 32), jnp.float32),)
    flops = step_flops(f, specs)
    assert flops >= 7 * 2 * 32**3
    assert flops < 7 * 2 * 32**3 * 1.1


def test_flops_grad_includes_backward():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    g = jax.grad(loss)
    specs = (
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32),
    )
    fwd = step_flops(loss, specs)
    bwd = step_flops(g, specs)
    assert bwd > 1.8 * fwd      # backward ~2x forward for matmul chains


# ------------------------------------------------------------- terms ------


class _Mem:
    argument_size_in_bytes = 8 * 2**30
    output_size_in_bytes = 2 * 2**30
    temp_size_in_bytes = 1 * 2**30
    alias_size_in_bytes = 0


def test_roofline_terms_and_dominance():
    colls = rl.CollectiveStats(
        op_bytes={"all-reduce": 1e12}, op_counts={"all-reduce": 2}
    )
    terms = rl.roofline(
        jaxpr_flops_global=256 * 1e15,
        mem_stats=_Mem(),
        collectives=colls,
        model_flops_global=256 * 0.5e15,
        n_devices=256,
    )
    assert terms.compute_s == pytest.approx(1e15 / rl.PEAK_FLOPS)
    assert terms.memory_s == pytest.approx(
        (8 + 2 + 2 * 1) * 2**30 / rl.HBM_BW
    )
    assert terms.collective_s == pytest.approx(1e12 / rl.LINK_BW)
    assert terms.dominant == "collective"
    assert terms.useful_flops_ratio == pytest.approx(0.5)
    assert 0 < terms.roofline_fraction <= 1.0


def test_model_flops_kinds():
    from repro.configs import get_config
    from repro.configs.base import SHAPES_BY_NAME

    cfg = get_config("olmo-1b")
    train = rl.model_flops_global(cfg, SHAPES_BY_NAME["train_4k"])
    prefill = rl.model_flops_global(cfg, SHAPES_BY_NAME["prefill_32k"])
    decode = rl.model_flops_global(cfg, SHAPES_BY_NAME["decode_32k"])
    n = cfg.active_param_count()
    assert train == pytest.approx(6 * n * 256 * 4096)
    assert prefill == pytest.approx(2 * n * 32 * 32768)
    assert decode == pytest.approx(2 * n * 128)
