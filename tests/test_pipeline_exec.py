"""Stage-parallel streaming runtime: a PartitionedPlan as a runnable
artifact.  Ordering invariants (per-stage prefetch honors plan issue
order), functional determinism vs the scan reference, stall parity with
the single-PU executor, pipeline dynamics vs the analytic model, and
the serving/FleetSim integrations."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.pu import PU_1X, PU_2X, PUConfig, TileCost, host_offload_config, tpu_v5e_config
from repro.core import simulator as sim
from repro.core.streaming import gemm_sequence_tiles, plan_streaming
from repro.models import api as model_api
from repro.parallel.pipeline import bubble_fraction, sequential_apply
from repro.plan import partition_gemms, partition_layers
from repro.runtime.pipeline_exec import (
    StagePipelineExecutor,
    execute_partitioned_plan,
)
from repro.runtime.serving import ServeConfig, ServingEngine


BIG_PU = PUConfig(
    name="big", r_sa=8, c_sa=8, fast_clock_hz=1e6,
    fast_mem_bytes=1 << 24, weight_bw_bytes_per_s=1e9,
    act_bw_bytes_per_s=1e9,
)


def _linear_chain_partition(ws, pus, load_s=0.1):
    """One tile per layer; layer i is a (D, D) weight matrix ws[i]."""
    layers = [(f"l{i}", w) for i, w in enumerate(ws)]
    return partition_layers(
        layers,
        pus,
        latency_s=lambda pu, l: 1.0,
        tiles_of=lambda pu, l: [
            TileCost(load_s=load_s, exec_s=1.0, mem_bytes=l[1].nbytes)
        ],
        name_of=lambda l: l[0],
        act_bytes_of=lambda l: l[1].shape[0],
        use_cache=False,
    )


# ------------------------------------------------- ordering invariants ----


def test_prefetch_never_overtakes_issue_order():
    """The relocation workload from test_streaming: tile 3's load moves
    into tile 0's window, so issue order is [0, 1, 3, 2] -- every frame's
    fetch sequence must follow it, never inference order."""
    costs = [
        TileCost(load_s=1.0, exec_s=6.0, mem_bytes=10),
        TileCost(load_s=1.0, exec_s=1.0, mem_bytes=10),
        TileCost(load_s=1.0, exec_s=1.0, mem_bytes=10),
        TileCost(load_s=4.0, exec_s=1.0, mem_bytes=10),
    ]
    pu = PUConfig(name="t", fast_mem_bytes=100)
    pplan = partition_layers(
        list(range(4)),
        [pu],
        latency_s=lambda p, l: 1.0,
        tiles_of=lambda p, l: [costs[l]],
        name_of=lambda l: f"l{l}",
        use_cache=False,
    )
    st = pplan.stages[0]
    assert st.plan.issue_order() == [0, 1, 3, 2]
    rep = execute_partitioned_plan(
        pplan, n_microbatches=3, record_fetch_orders=True
    )
    want = [st.tile_names[i] for i in st.plan.issue_order()]
    assert want == ["l0/t0", "l1/t0", "l3/t0", "l2/t0"]
    assert rep.stages[0].fetch_orders == [want] * 3


def test_multi_stage_fetch_orders_follow_each_plan():
    gemms = [(f"g{i}", 16, 32, 8) for i in range(6)]
    pplan = partition_gemms(gemms, [BIG_PU, BIG_PU])
    rep = execute_partitioned_plan(
        pplan, n_microbatches=4, record_fetch_orders=True
    )
    for k, st in enumerate(pplan.stages):
        want = [st.tile_names[i] for i in st.plan.issue_order()]
        assert rep.stages[k].fetch_orders == [want] * 4
        assert rep.stages[k].peak_resident_bytes <= st.pu.fast_mem_bytes


# ------------------------------------------- functional determinism -------


def test_matches_sequential_apply():
    """Final activations through the K-stage threaded pipeline equal the
    plain sequential scan (parallel.pipeline.sequential_apply)."""
    L, B, D, M = 8, 8, 16, 4
    key = jax.random.PRNGKey(0)
    stacked = jax.random.normal(key, (L, D, D)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
    layer_fn = lambda w, h: jnp.tanh(h @ w)
    ref = sequential_apply(layer_fn, stacked, x)

    ws = [np.asarray(stacked[i]) for i in range(L)]
    pplan = _linear_chain_partition(ws, [BIG_PU, BIG_PU])
    assert [s.n_layers for s in pplan.stages] == [4, 4]

    def fetch(k, i, name):
        st = pplan.stages[k]
        return ws[st.layer_start + i]        # one tile per layer

    def run_tile(k, i, w, carry):
        return np.tanh(carry @ w)

    mbs = np.split(np.asarray(x), M)
    ex = StagePipelineExecutor(pplan, fetch=fetch, run_tile=run_tile)
    rep = ex.run(mbs)
    got = np.concatenate(rep.outputs, axis=0)
    np.testing.assert_allclose(got, np.asarray(ref), atol=1e-5)


def test_outputs_keep_microbatch_order():
    pplan = _linear_chain_partition(
        [np.eye(4, dtype=np.float32)] * 6, [BIG_PU, BIG_PU, BIG_PU]
    )
    rep = execute_partitioned_plan(
        pplan, n_microbatches=5, payloads=["a", "b", "c", "d", "e"]
    )
    assert rep.outputs == ["a", "b", "c", "d", "e"]
    # completion times strictly increase: frames drain in order
    assert all(
        t1 < t2 for t1, t2 in zip(rep.frame_done_t, rep.frame_done_t[1:])
    )


# ------------------------------------------------- stall/bubble parity ----


def test_single_stage_stall_matches_single_pu_executor():
    """A 1-stage partition is exactly the single-PU path: same tiles,
    same plan, stall count no worse (equal)."""
    gemms = [(f"g{i}", 8, 16, 4) for i in range(6)]
    pu = PUConfig(
        name="tiny", r_sa=4, c_sa=4, fast_clock_hz=1e6,
        fast_mem_bytes=512, weight_bw_bytes_per_s=1e6,
        act_bw_bytes_per_s=1e6,
    )
    single = plan_streaming(gemm_sequence_tiles(gemms, pu), pu)
    pplan = partition_gemms(gemms, [pu])
    assert len(pplan.stages) == 1
    M = 3
    rep = execute_partitioned_plan(pplan, n_microbatches=M)
    per_frame = rep.stages[0].stall_s / M
    assert per_frame == pytest.approx(pplan.stages[0].plan.total_stall)
    # no worse than the single-PU executor's plan on identical tiles
    assert per_frame <= single.plan.total_stall + 1e-12
    # single stage never starves and has zero fill bubble
    assert rep.stages[0].starve_s == 0.0
    assert rep.bubble_measured == pytest.approx(0.0, abs=1e-9)


def test_executed_matches_predicted_recurrence():
    """The threaded runtime's virtual event stream must reproduce the
    analytic pipeline recurrence exactly -- stages genuinely overlap."""
    gemms = [(f"g{i}", 16, 32, 8) for i in range(8)]
    pplan = partition_gemms(gemms, [BIG_PU, BIG_PU])
    M = 6
    rep = execute_partitioned_plan(pplan, n_microbatches=M)
    assert rep.makespan_s == pytest.approx(pplan.pipeline_makespan(M))
    assert rep.measured_fps == pytest.approx(pplan.pipeline_fps(M))
    want_done = pplan.pipeline_events(M)[-1]
    np.testing.assert_allclose(rep.frame_done_t, want_done)


# --------------------------------------------- ResNet-50 K=2 criteria -----


def test_resnet50_k2_throughput_and_bubble():
    """The PR's acceptance numbers: K=2 executed throughput >= 1.2x the
    best single-PU executor, bubble within 2x of the GPipe prediction."""
    layers = sim.resnet_gemm_layers(50)
    M = 8
    singles = [
        execute_partitioned_plan(
            sim.simulate_partitioned([pu], layers), n_microbatches=M
        )
        for pu in (PU_1X, PU_2X)
    ]
    best_single_fps = max(r.measured_fps for r in singles)
    rep = execute_partitioned_plan(
        sim.simulate_partitioned([PU_1X, PU_2X], layers), n_microbatches=M
    )
    assert rep.measured_fps >= 1.2 * best_single_fps
    predicted = bubble_fraction(2, M)
    assert rep.bubble_predicted == pytest.approx(predicted)
    assert rep.bubble_measured <= 2.0 * predicted
    assert rep.bubble_measured >= 0.0
    # the stages genuinely overlapped: at some point both were mid-frame
    assert rep.max_concurrent_stages >= 2


# ------------------------------------------------------ M auto-tuning -----


def test_analytic_microbatch_seed():
    from repro.runtime.autotune import analytic_microbatches

    # GPipe floor: (K-1)/(M+K-1) <= target
    assert analytic_microbatches(1, 0.1) == 1
    assert analytic_microbatches(2, 0.1) == 9
    assert analytic_microbatches(4, 0.25) == 9
    m = analytic_microbatches(3, 0.1)
    assert bubble_fraction(3, m) <= 0.1 < bubble_fraction(3, m - 1)
    with pytest.raises(ValueError):
        analytic_microbatches(2, 0.0)


def test_autotune_k2_resnet50_hits_bubble_band():
    """Acceptance: the tuned M lands the *executed* bubble within 10% of
    the requested target on the K=2 ResNet-50 partition, at no
    throughput cost vs the fixed M=8 baseline."""
    from repro.runtime.autotune import AutotuneConfig, tune_pipeline

    layers = sim.resnet_gemm_layers(50)
    pplan = sim.simulate_partitioned([PU_1X, PU_2X], layers)
    res = tune_pipeline(pplan, AutotuneConfig(target_bubble=0.10))
    assert res.within_tolerance
    assert abs(res.bubble_measured - 0.10) <= 0.10 * 0.10 + 1e-12
    fixed = execute_partitioned_plan(pplan, n_microbatches=8)
    assert res.measured_fps >= fixed.measured_fps * 0.999
    # the walk starts from the analytic seed and stays on-grid
    assert res.analytic_m == 9
    assert res.trials[0]["m"] == 9
    assert res.n_microbatches >= res.analytic_m   # executed bubble > floor
    assert res.queue_depth in (2, 3, 4)


def test_autotune_deeper_target_needs_deeper_burst():
    from repro.runtime.autotune import AutotuneConfig, tune_pipeline

    layers = sim.resnet_gemm_layers(50)
    pplan = sim.simulate_partitioned([PU_1X, PU_2X], layers)
    loose = tune_pipeline(pplan, AutotuneConfig(target_bubble=0.25))
    tight = tune_pipeline(pplan, AutotuneConfig(target_bubble=0.08))
    assert tight.n_microbatches > loose.n_microbatches
    assert loose.within_tolerance and tight.within_tolerance


def test_autotune_k1_trivial():
    from repro.runtime.autotune import AutotuneConfig, tune_pipeline

    layers = sim.resnet_gemm_layers(18)
    pplan = sim.simulate_partitioned([PU_2X], layers)
    res = tune_pipeline(pplan, AutotuneConfig(target_bubble=0.10))
    # one stage has no fill bubble at any depth: minimal M suffices
    assert res.n_microbatches == 1
    assert res.bubble_measured == pytest.approx(0.0)
    assert res.within_tolerance


def test_serving_execute_partition_autotunes_by_default():
    cfg, eng = _engine(
        stream_pus=[host_offload_config(), tpu_v5e_config()],
        target_bubble=0.15,
    )
    rep = eng.execute_partition()          # no explicit M: auto-tune
    assert eng.last_autotune is not None
    assert rep.n_microbatches == eng.last_autotune.n_microbatches
    s = eng.stats()
    assert s["partition_autotuned_m"] == rep.n_microbatches
    assert s["partition_autotune_target_bubble"] == pytest.approx(0.15)
    assert s["partition_microbatches"] == rep.n_microbatches
    # this smoke partition is imbalance-dominated (its bubble floor sits
    # far above any reachable fill target), so the tuner must *honestly*
    # report missing the band rather than claim success
    assert s["partition_autotune_within_tolerance"] == 0.0
    assert eng.last_autotune.bubble_measured > 0.15
    # explicit M still pins the depth (legacy behaviour)
    rep8 = eng.execute_partition(n_microbatches=8)
    assert rep8.n_microbatches == 8


# ------------------------------------------------ integration surfaces ----


def test_fleetsim_executed_mode():
    layers = sim.resnet_gemm_layers(18)
    pplan = sim.simulate_partitioned([PU_1X, PU_2X], layers)
    fleet = sim.FleetSim(pipelines=[("k2", pplan, 1)])
    out = fleet.execute_pipelines(n_microbatches=4)
    rec = out["k2"]
    assert rec["measured_fps"] == pytest.approx(rec["predicted_fps"])
    # executed throughput trails the steady-state analytic number only
    # by the fill bubble
    assert 0.5 < rec["measured_vs_analytic"] <= 1.0 + 1e-9
    assert rec["bubble_measured"] >= 0.0


def _engine(arch="olmo-1b", **kw):
    cfg = smoke_variant(get_config(arch))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    defaults = dict(max_batch=2, max_len=64, max_new_tokens=4, seed=0)
    defaults.update(kw)
    return cfg, ServingEngine(cfg, params, ServeConfig(**defaults))


def test_k2_decode_end_to_end_smoke():
    """--multi-pu decode end to end: requests drain AND the partition
    executes through the stage-parallel runtime."""
    cfg, eng = _engine(
        stream_pus=[host_offload_config(), tpu_v5e_config()]
    )
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab, 8).astype(np.int32))
    done = eng.run_until_drained()
    assert len(done) == 3
    rep = eng.execute_partition(n_microbatches=4)
    assert rep.n_stages == 2 and rep.n_microbatches == 4
    s = eng.stats()
    assert s["partition_executed_fps"] > 0
    assert 0.0 < s["partition_executed_vs_analytic"] <= 1.0 + 1e-9
    assert s["partition_bubble_measured"] >= 0.0
    assert s["partition_bubble_predicted"] == pytest.approx(
        bubble_fraction(2, 4)
    )


def test_stream_pus_k1_falls_back_to_single_pu_path():
    cfg, eng = _engine(stream_pus=[host_offload_config()])
    assert eng.partitioned_plan is None
    assert eng.streaming_plan is not None
    assert eng.streaming_plan.schedule.feasible
    with pytest.raises(ValueError):
        eng.execute_partition()


def test_partition_k_exceeds_layers_guard():
    gemms = [("a", 64, 64, 8), ("b", 64, 64, 8)]
    pplan = partition_gemms(gemms, [host_offload_config()] * 5)
    assert len(pplan.stages) == 1
    assert pplan.stages[0].n_layers == 2
    assert pplan.feasible
    assert all(s.n_layers > 0 for s in pplan.stages)


def test_handoff_metadata():
    gemms = [("a", 64, 128, 8), ("b", 32, 64, 8)]
    pplan = partition_gemms(gemms, [BIG_PU, BIG_PU])
    s0, s1 = pplan.stages
    assert s0.handoff_in_bytes == 0
    # stage 1 starts at gemm "b": inbound acts are its (M=64) x (P=8) operand
    assert s1.handoff_in_bytes == 64 * 8
    assert s1.handoff_in_s == pytest.approx(64 * 8 / s1.pu.act_bw_bytes_per_s)
    assert s1.stage_s_with_handoff == pytest.approx(
        s1.stage_s + s1.handoff_in_s
    )
    assert s0.tile_names and len(s0.tile_names) == s0.plan.n
    assert sum(s0.tiles_per_layer) == s0.plan.n


def test_run_tile_error_propagates():
    pplan = _linear_chain_partition(
        [np.eye(4, dtype=np.float32)] * 4, [BIG_PU, BIG_PU]
    )

    def bad_tile(k, i, w, carry):
        if k == 1 and i == 1:
            raise RuntimeError("boom")
        return carry

    ex = StagePipelineExecutor(pplan, run_tile=bad_tile)
    with pytest.raises(RuntimeError, match="boom"):
        ex.run(list(range(3)))


# ------------------------------------------------- plan persistence -------


def test_execution_plan_json_roundtrip():
    from repro.plan import plan as plan_tiles
    from repro.plan.ir import ExecutionPlan

    tiles = [
        TileCost(load_s=1.0, exec_s=6.0, mem_bytes=10),
        TileCost(load_s=1.0, exec_s=1.0, mem_bytes=10),
        TileCost(load_s=4.0, exec_s=1.0, mem_bytes=10),
    ]
    p = plan_tiles(tiles, capacity=25)
    q = ExecutionPlan.from_json_dict(p.to_json_dict())
    assert q.windows == p.windows
    assert q.baseline_windows == p.baseline_windows
    assert q.capacity == p.capacity and q.tiles == p.tiles
    assert q.total_stall == p.total_stall          # bit-identical floats
    np.testing.assert_array_equal(q.timeline.exec_end, p.timeline.exec_end)
    np.testing.assert_array_equal(q.baseline.load_start, p.baseline.load_start)


def test_plan_cache_persists_across_instances(tmp_path):
    from repro.plan.cache import PlanCache

    tiles = [TileCost(1.0, 2.0, 10), TileCost(0.5, 1.5, 12)]
    a = PlanCache(persist_dir=tmp_path)
    p1 = a.get_or_plan(tiles, 50)
    assert a.stats()["disk_hits"] == 0
    # a fresh cache (new process in real life) loads from disk, no replan
    b = PlanCache(persist_dir=tmp_path)
    p2 = b.get_or_plan(tiles, 50)
    assert b.stats() == {
        "entries": 1, "hits": 0, "misses": 1, "disk_hits": 1,
        "disk_errors": 0,
    }
    assert p2.windows == p1.windows
    assert p2.total_stall == p1.total_stall
    np.testing.assert_array_equal(
        p2.timeline.exec_end, p1.timeline.exec_end
    )
    # second lookup in the same cache hits memory, not disk
    b.get_or_plan(tiles, 50)
    assert b.stats()["hits"] == 1 and b.stats()["disk_hits"] == 1


def test_plan_cache_ignores_corrupt_spill(tmp_path):
    from repro.plan.cache import PlanCache, plan_key

    tiles = [TileCost(1.0, 2.0, 10)]
    a = PlanCache(persist_dir=tmp_path)
    p1 = a.get_or_plan(tiles, 50)
    (tmp_path / f"{plan_key(tiles, 50)}.json").write_text("{not json")
    b = PlanCache(persist_dir=tmp_path)
    p2 = b.get_or_plan(tiles, 50)                  # replans, no crash
    assert b.stats()["disk_errors"] >= 1
    assert p2.windows == p1.windows


def test_plan_cache_without_persist_dir_writes_nothing(tmp_path, monkeypatch):
    from repro.plan.cache import PlanCache

    monkeypatch.chdir(tmp_path)
    cache = PlanCache()                            # no persist tier
    cache.get_or_plan([TileCost(1.0, 1.0, 5)], 50)
    assert list(tmp_path.iterdir()) == []


def test_default_persist_dir_resolution(tmp_path, monkeypatch):
    """The shared cache spills at the repo root (tracked markers, so
    fresh clones/CI qualify before experiments/ exists), not elsewhere,
    and the env var overrides both ways."""
    from repro.plan.cache import _default_persist_dir

    monkeypatch.delenv("REPRO_PLAN_CACHE_DIR", raising=False)
    monkeypatch.chdir(tmp_path)
    assert _default_persist_dir() is None          # not a repo root
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "ROADMAP.md").write_text("x")
    # absolute: spills stay at the detected root even if cwd changes later
    assert _default_persist_dir() == tmp_path / "experiments" / "plans"
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", "0")
    assert _default_persist_dir() is None
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path / "p"))
    assert _default_persist_dir() == tmp_path / "p"
