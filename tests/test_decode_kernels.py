"""Fused Pallas decode kernels (kernels/decode.py, DESIGN.md SS10).

Per-op numeric-tolerance tests against the pure-jnp oracles, ring-buffer
decode-attention edge cases parametrized across the XLA reference AND the
kernel (both paths pinned by one suite), blocking invariance, the
interpret-dispatch rule, and engine-level greedy-stream argmax-identity
(fused and staged/coalesced paths) with zero retraces after warmup.
"""
import dataclasses
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.sanitize import retrace_guard
from repro.configs import get_config, smoke_variant
from repro.core.pu import host_offload_config, tpu_v5e_config
from repro.kernels import (
    decode_attention_ref,
    default_interpret,
    fused_decode_attention,
    fused_mlp,
    fused_mlp_ref,
    fused_qkv,
    fused_qkv_ref,
)
from repro.models import api as model_api
from repro.models import attention as attn
from repro.runtime.serving import ServeConfig, ServingEngine

B, D, HQ, HKV, HD, SK, FF = 3, 96, 4, 2, 32, 40, 112
_DT = jnp.bfloat16


def _f32(a):
    return np.asarray(a, np.float32)


def _close(a, b, atol=5e-2):
    np.testing.assert_allclose(_f32(a), _f32(b), atol=atol)


@pytest.fixture(scope="module")
def tensors(rng):
    n = lambda *s: rng.normal(size=s)
    return {
        "x": jnp.asarray(n(B, D), _DT),
        "wq": jnp.asarray(n(D, HQ * HD) * 0.05, jnp.float32),
        "wk": jnp.asarray(n(D, HKV * HD) * 0.05, jnp.float32),
        "wv": jnp.asarray(n(D, HKV * HD) * 0.05, jnp.float32),
        "bq": jnp.asarray(n(HQ * HD) * 0.05, jnp.float32),
        "bk": jnp.asarray(n(HKV * HD) * 0.05, jnp.float32),
        "bv": jnp.asarray(n(HKV * HD) * 0.05, jnp.float32),
        "q": jnp.asarray(n(B, HQ, HD), _DT),
        "k": jnp.asarray(n(B, SK, HKV, HD), _DT),
        "v": jnp.asarray(n(B, SK, HKV, HD), _DT),
        "wo": jnp.asarray(n(HQ * HD, D) * 0.05, jnp.float32),
        "bo": jnp.asarray(n(D) * 0.05, jnp.float32),
        "w_up": jnp.asarray(n(D, FF) * 0.05, jnp.float32),
        "w_gate": jnp.asarray(n(D, FF) * 0.05, jnp.float32),
        "b_up": jnp.asarray(n(FF) * 0.05, jnp.float32),
        "w_down": jnp.asarray(n(FF, D) * 0.05, jnp.float32),
        "b_down": jnp.asarray(n(D) * 0.05, jnp.float32),
        "pos": jnp.asarray([3, 17, 999], jnp.int32),
        "qpos": jnp.asarray([5, 20, 39], jnp.int32),
    }


# ---------------------------------------------------------------------------
# per-op tolerance vs oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bias", [True, False])
@pytest.mark.parametrize("rope", [True, False])
def test_fused_qkv_matches_ref(tensors, bias, rope):
    t = tensors
    args = (
        t["x"], t["wq"], t["wk"], t["wv"],
        t["bq"] if bias else None,
        t["bk"] if bias else None,
        t["bv"] if bias else None,
        t["pos"],
    )
    kw = dict(n_heads=HQ, n_kv_heads=HKV, head_dim=HD, rope=rope, theta=1e4)
    got = fused_qkv(*args, block_m=64, **kw)
    want = fused_qkv_ref(*args, **kw)
    for g, w in zip(got, want):
        _close(g, w, atol=2e-2)


_ATTN_CASES = {
    "full": dict(),
    "valid_len": dict(kv_valid_len="stagger"),
    "window_static": dict(kv_valid_len="stagger", window=7),
    "window_dynamic": dict(kv_valid_len="stagger", window_arr=9),
    "ring": dict(kv_positions="ring"),
    "ring_window": dict(kv_positions="ring", window_arr=9),
    "noncausal": dict(causal=False),
}


def _attn_kwargs(case, rng):
    kw = dict(_ATTN_CASES[case])
    if kw.get("kv_valid_len") == "stagger":
        kw["kv_valid_len"] = jnp.asarray([6, 21, 40], jnp.int32)
    if kw.get("kv_positions") == "ring":
        kw["kv_positions"] = jnp.asarray(
            rng.integers(-1, 45, (B, SK)), jnp.int32
        )
    if "window_arr" in kw:
        kw["window_arr"] = jnp.asarray(kw["window_arr"], jnp.int32)
    return kw


@pytest.mark.parametrize("case", sorted(_ATTN_CASES))
def test_fused_attention_matches_ref(tensors, rng, case):
    t = tensors
    kw = _attn_kwargs(case, rng)
    got = fused_decode_attention(
        t["q"], t["k"], t["v"], t["wo"], t["bo"],
        q_positions=t["qpos"], block_s=16, **kw,
    )
    want = decode_attention_ref(
        t["q"], t["k"], t["v"], t["wo"], t["bo"], q_positions=t["qpos"], **kw
    )
    _close(got, want)


@pytest.mark.parametrize(
    "act,gated,bias",
    [("swiglu", True, True), ("swiglu", True, False),
     ("gelu", False, True), ("sq_relu", False, False)],
)
def test_fused_mlp_matches_ref(tensors, act, gated, bias):
    t = tensors
    args = (
        t["x"], t["w_up"],
        t["w_gate"] if gated else None,
        t["b_up"] if bias else None,
        t["w_down"],
        t["b_down"] if bias else None,
    )
    got = fused_mlp(*args, act=act, block_f=48)
    _close(got, fused_mlp_ref(*args, act=act), atol=2e-2)


def test_blocking_invariance(tensors, rng):
    """Streaming in slabs must match the single-block pass: the kernel
    block size is a VMEM refinement of the plan tile, never a semantic."""
    t = tensors
    kw = _attn_kwargs("ring_window", rng)
    whole = fused_decode_attention(
        t["q"], t["k"], t["v"], t["wo"], t["bo"],
        q_positions=t["qpos"], block_s=SK, **kw,
    )
    split = fused_decode_attention(
        t["q"], t["k"], t["v"], t["wo"], t["bo"],
        q_positions=t["qpos"], block_s=8, **kw,
    )
    _close(split, whole, atol=2e-2)
    margs = (t["x"], t["w_up"], t["w_gate"], t["b_up"], t["w_down"], t["b_down"])
    _close(
        fused_mlp(*margs, act="swiglu", block_f=FF),
        fused_mlp(*margs, act="swiglu", block_f=16),
        atol=2e-2,
    )


# ---------------------------------------------------------------------------
# satellite: ring-buffer edge cases, pinned on the XLA reference AND the
# kernel by the same suite
# ---------------------------------------------------------------------------


def _attn_out(impl, t, k=None, v=None, **kw):
    """One decode-attention + out-projection through either path."""
    k = t["k"] if k is None else k
    v = t["v"] if v is None else v
    if impl == "kernel":
        return fused_decode_attention(
            t["q"], k, v, t["wo"], t["bo"],
            q_positions=t["qpos"], block_s=16, **kw,
        )
    ctx = attn.gqa_attention(
        t["q"][:, None], k, v,
        q_positions=t["qpos"][:, None], causal=kw.pop("causal", True),
        chunk=16, **kw,
    )
    y = ctx.reshape(B, HQ * HD) @ t["wo"].astype(_DT)
    return y + t["bo"].astype(_DT)


@pytest.mark.parametrize("impl", ["xla", "kernel"])
def test_ring_wrap_negative_positions_never_attend(tensors, impl):
    """Full ring wrap with young lanes: never-written slots carry negative
    positions and must not contribute -- poisoning their K/V entries with
    huge values cannot change the output (bitwise)."""
    t = tensors
    cache_len = SK
    decode_pos = jnp.asarray([5, 20, 39], jnp.int32)      # lane 0 wrote 6 slots
    slots = jnp.arange(cache_len, dtype=jnp.int32)
    kvp = decode_pos[:, None] - ((decode_pos[:, None] - slots[None]) % cache_len)
    assert bool(jnp.any(kvp < 0))

    clean = _attn_out(impl, t, kv_positions=kvp)
    poison = jnp.where((kvp < 0)[..., None, None], jnp.asarray(1e4, _DT), t["k"])
    vpois = jnp.where((kvp < 0)[..., None, None], jnp.asarray(1e4, _DT), t["v"])
    dirty = _attn_out(impl, t, k=poison, v=vpois, kv_positions=kvp)
    np.testing.assert_array_equal(_f32(clean), _f32(dirty))


@pytest.mark.parametrize("impl", ["xla", "kernel"])
def test_window_arr_matches_static_window(tensors, impl):
    """A dynamic () window_arr is exactly the static window of the same
    value, on both implementations."""
    t = tensors
    for w in (1, 7, 64):
        stat = _attn_out(impl, t, window=w)
        dyn = _attn_out(impl, t, window_arr=jnp.asarray(w, jnp.int32))
        np.testing.assert_array_equal(_f32(stat), _f32(dyn))


@pytest.mark.parametrize("impl", ["xla", "kernel"])
def test_staggered_valid_len_masks_tail(tensors, impl):
    """Per-lane kv_valid_len at staggered positions: slots past a lane's
    limit must not contribute (poison invariance), including a lane whose
    whole history is a single slot."""
    t = tensors
    vlen = jnp.asarray([1, 21, 40], jnp.int32)
    clean = _attn_out(impl, t, kv_valid_len=vlen)
    tail = jnp.arange(SK)[None] >= vlen[:, None]          # (B, Sk)
    kpois = jnp.where(tail[..., None, None], jnp.asarray(1e4, _DT), t["k"])
    vpois = jnp.where(tail[..., None, None], jnp.asarray(1e4, _DT), t["v"])
    dirty = _attn_out(impl, t, k=kpois, v=vpois, kv_valid_len=vlen)
    np.testing.assert_array_equal(_f32(clean), _f32(dirty))


def test_xla_and_kernel_agree(tensors, rng):
    """The two implementations agree within bf16 reassociation noise on
    every masking mode."""
    for case in sorted(_ATTN_CASES):
        kw = _attn_kwargs(case, rng)
        _close(
            _attn_out("kernel", tensors, **dict(kw)),
            _attn_out("xla", tensors, **dict(kw)),
        )


# ---------------------------------------------------------------------------
# satellite: interpret-dispatch rule
# ---------------------------------------------------------------------------


def test_default_interpret_rule(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
    assert default_interpret() == (jax.default_backend() != "tpu")
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
    assert default_interpret() is True
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "0")
    assert default_interpret() is False


# ---------------------------------------------------------------------------
# engine-level: greedy streams argmax-identical to the XLA path
# ---------------------------------------------------------------------------

_PARAMS = {}


def _setup(arch, **overrides):
    cfg = smoke_variant(get_config(arch))
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    key = (arch, tuple(sorted(overrides)))
    if key not in _PARAMS:
        api = model_api.get_api(cfg)
        _PARAMS[key] = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, _PARAMS[key]


def _stream(cfg, params, prompts, stagger=False, **kw):
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_batch=2, max_len=64, max_new_tokens=5, seed=0, **kw),
    )
    it = iter(prompts)
    eng.submit(next(it).copy())
    if stagger:
        eng.step()                    # first request decodes alone first
    for p in it:
        eng.submit(p.copy())
    return {r.uid: r.out_tokens for r in eng.run_until_drained()}, eng


_ENGINE_VARIANTS = {
    "olmo-1b": {},
    "gemma3-12b": {},                                   # local:global windows
    "olmo-ring": {},                                    # ring KV + window
    "whisper-medium": {},                               # encdec + bias + gelu
    "zamba2-1.2b": {},                                  # hybrid shared block
}


def _arch_setup(name):
    if name == "olmo-ring":
        return _setup("olmo-1b", window=16, kv_ring=True)
    return _setup(name)


@pytest.mark.parametrize("arch", sorted(_ENGINE_VARIANTS))
def test_serve_kernels_argmax_identical(arch):
    """Acceptance: --decode-kernels greedy streams match the XLA path
    exactly under staggered admissions on every smoke family."""
    cfg, params = _arch_setup(arch)
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, cfg.vocab, int(l)).astype(np.int32)
        for l in (9, 14, 6)
    ]
    ref, _ = _stream(cfg, params, prompts, stagger=True)
    got, _ = _stream(cfg, params, prompts, stagger=True, decode_kernels=True)
    assert got == ref


def test_serve_kernels_staged_paths_match():
    """The staged (serial) and coalesced (overlapped lane-group) multi-PU
    decode paths pick the kernels up through the same dispatch layer and
    stay argmax-identical to the XLA single-PU stream."""
    cfg, params = _setup("olmo-1b", n_layers=4)
    rng = np.random.default_rng(4)
    prompts = [
        rng.integers(0, cfg.vocab, int(l)).astype(np.int32)
        for l in (8, 13, 5)
    ]
    pus = [host_offload_config(), tpu_v5e_config()]
    ref, _ = _stream(cfg, params, prompts)
    serial, _ = _stream(
        cfg, params, prompts, decode_kernels=True, stream_pus=pus,
        decode_microbatches=1,
    )
    overlap, _ = _stream(
        cfg, params, prompts, decode_kernels=True, stream_pus=pus,
        decode_microbatches=2,
    )
    assert serial == ref
    assert overlap == ref


def test_serve_kernels_warmup_zero_retraces():
    cfg, params = _setup("olmo-1b")
    eng = ServingEngine(
        cfg, params,
        ServeConfig(
            max_batch=2, max_len=64, max_new_tokens=5, seed=0,
            decode_kernels=True,
        ),
    )
    eng.warmup()
    with retrace_guard(eng.tracing):
        rng = np.random.default_rng(5)
        for l in (6, 11, 3):
            eng.submit(rng.integers(0, cfg.vocab, l).astype(np.int32))
        eng.run_until_drained()
