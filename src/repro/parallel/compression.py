"""Gradient compression: int8 all-reduce with error feedback.

Large-fleet data parallelism is bandwidth-bound on the gradient
all-reduce.  This module compresses gradients to int8 (with power-of-two
scales -- the same quantization grid the paper's PU arithmetic uses, see
``core/quant.py``) before the reduction and carries the quantization error
forward with error feedback (EF-SGD style), which restores convergence to
the uncompressed trajectory asymptotically.

Two surfaces:

- :func:`compress_tree` / :func:`decompress_tree` -- pure functions used by
  the train step when ``compression='int8_ef'``; inside jit, GSPMD reduces
  the *int8* payloads (4x fewer wire bytes than f32, 2x fewer than bf16).
- :func:`int8_psum` -- explicit shard_map collective for when the reduction
  axis is managed manually; reduces in int32 to avoid overflow at up to
  2**23 participants.

Error-feedback state shards exactly like the gradients (ZeRO-style), so the
memory overhead equals one extra copy of the grads in int8 + one in f32.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import INT8_MAX, INT8_MIN


def _pow2_scale(x: jax.Array) -> jax.Array:
    """Per-tensor power-of-two scale covering max|x| (same grid as the PU)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    amax = jnp.maximum(amax, 1e-30)
    e = jnp.ceil(jnp.log2(amax / INT8_MAX))
    return jnp.exp2(e)


def compress_leaf(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(int8 payload, scale, new error) with error feedback."""
    g32 = g.astype(jnp.float32) + err
    s = _pow2_scale(g32)
    q = jnp.clip(jnp.round(g32 / s), INT8_MIN, INT8_MAX).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * s
    return q, s, new_err


def compress_tree(grads: Any, err_state: Any) -> Tuple[Any, Any, Any]:
    """Compress a grad pytree -> (int8 tree, scale tree, new error tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    qs, ss, es = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress_leaf(g, e)
        qs.append(q)
        ss.append(s)
        es.append(ne)
    return (
        jax.tree.unflatten(treedef, qs),
        jax.tree.unflatten(treedef, ss),
        jax.tree.unflatten(treedef, es),
    )


def decompress_tree(q_tree: Any, scale_tree: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree
    )


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_grads(grads: Any, err_state: Any) -> Tuple[Any, Any]:
    """Quantize-dequantize roundtrip with error feedback.

    Inside a pjit'd train step this makes the gradient all-reduce carry
    int8 payloads (GSPMD reduces the quantized tensors); the returned
    gradients are the dequantized view the optimizer consumes.
    """
    q, s, new_err = compress_tree(grads, err_state)
    return decompress_tree(q, s), new_err


def int8_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Explicit compressed all-reduce of one tensor over ``axis_name``.

    Quantizes with a locally agreed power-of-two scale (max over the axis
    so every participant uses the same grid -- one scalar all-reduce),
    reduces int32, and returns the mean in f32.
    """
    n = jax.lax.psum(1, axis_name)
    s_local = _pow2_scale(x)
    s = jax.lax.pmax(s_local, axis_name)           # shared grid
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), INT8_MIN, INT8_MAX)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * s / n
