"""Pipeline parallelism via shard_map + ppermute (GPipe schedule).

The paper scales out by instantiating PUs that process *independent* frames
(pure data parallelism).  At LM scale a 1000+-node fleet also needs layer
pipelining; this module adds it as a composable runner over the same
stacked-layer parameter layout the models already use for scan.

Design (classic shift-register formulation, cf. the shard_map pipelining
pattern):

- The mesh gains a ``stage`` axis of size S; the stacked layer params
  (L, ...) are sharded S-ways along the layer axis, so each device group
  holds L/S contiguous layers.
- The global batch is split into M microbatches.  At step t, stage s runs
  its local layers over microbatch (t - s); between steps, activations
  shift one stage forward via ``ppermute``.  The pipe drains after
  M + S - 1 steps.  Bubble fraction = (S-1)/(M+S-1) -- reported by
  :func:`bubble_fraction` so configs can be sanity-checked.
- Backward happens through autodiff: ppermute's transpose is the reverse
  permute, so one jax.grad over the runner yields the correct interleaved
  backward schedule for free.

The runner is deliberately *model-agnostic*: it takes any
``layer_fn(params_slice, x) -> x`` and works for every architecture family
whose blocks are a scanned stack (all 10 assigned archs).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble overhead: (S-1) / (M+S-1)."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def _stage_index(mesh: Mesh, axis: str) -> jax.Array:
    return jax.lax.axis_index(axis)


def pipeline_apply(
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    stacked_params: Any,          # pytree, leaves (L, ...) stacked over layers
    x: jax.Array,                 # (B, ...) global batch on entry
    mesh: Mesh,
    n_microbatches: int,
    stage_axis: str = "stage",
    layers_per_stage: Optional[int] = None,
) -> jax.Array:
    """Run L stacked layers over x with GPipe pipelining along ``stage_axis``.

    Semantically identical to

        for i in range(L): x = layer_fn(tree_slice(params, i), x)

    but executed with the layer stack split across ``stage_axis`` and
    microbatched activations flowing through ppermute.
    """
    n_stages = mesh.shape[stage_axis]
    n_layers = jax.tree.leaves(stacked_params)[0].shape[0]
    assert n_layers % n_stages == 0, (n_layers, n_stages)
    assert x.shape[0] % n_microbatches == 0, (x.shape, n_microbatches)
    lps = layers_per_stage or n_layers // n_stages

    mb = x.reshape((n_microbatches, x.shape[0] // n_microbatches) + x.shape[1:])

    # shard specs: layers dim over stages; microbatch dim replicated inside
    # (the batch may additionally be sharded over 'data' by the caller's jit).
    param_spec = jax.tree.map(lambda _: P(stage_axis), stacked_params)
    in_spec = (param_spec, P())        # microbatches enter replicated
    out_spec = P()

    def stage_prog(params_local, mb_local):
        """Runs on every stage group; params_local leaves are (L/S, ...)."""
        stage = jax.lax.axis_index(stage_axis)
        n_mb = mb_local.shape[0]
        mb_shape = mb_local.shape[1:]

        def run_local_layers(carry_x):
            def body(h, layer_params):
                return layer_fn(layer_params, h), None
            h, _ = jax.lax.scan(body, carry_x, params_local)
            return h

        steps = n_mb + n_stages - 1
        state = jnp.zeros(mb_shape, mb_local.dtype)   # activation register
        outputs = jnp.zeros_like(mb_local)

        def step_fn(t, carry):
            state, outputs = carry
            # stage 0 ingests microbatch t (when valid)
            take = jnp.clip(t, 0, n_mb - 1)
            injected = jnp.where(
                (stage == 0) & (t < n_mb),
                mb_local[take],
                state,
            )
            h = run_local_layers(injected)
            # last stage writes its finished microbatch (t - (S-1))
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_mb - 1)
            valid_out = (stage == n_stages - 1) & (t >= n_stages - 1)
            outputs = jax.lax.cond(
                valid_out,
                lambda o: jax.lax.dynamic_update_slice(
                    o, h[None].astype(o.dtype), (out_idx,) + (0,) * len(mb_shape)
                ),
                lambda o: o,
                outputs,
            )
            # shift activations forward one stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            state = jax.lax.ppermute(h, stage_axis, perm)
            return (state, outputs)

        state, outputs = jax.lax.fori_loop(0, steps, step_fn, (state, outputs))
        # only the last stage holds real outputs; broadcast them to all
        # stages so the result is replicated (psum over one-hot mask).
        mask = (stage == n_stages - 1).astype(outputs.dtype)
        outputs = jax.lax.psum(outputs * mask, stage_axis)
        return outputs

    runner = shard_map(
        stage_prog,
        mesh=mesh,
        in_specs=in_spec,
        out_specs=out_spec,
        check_rep=False,
    )
    out_mb = runner(stacked_params, mb)
    return out_mb.reshape(x.shape)


def tree_layer_slice(stacked_params: Any, i) -> Any:
    """Dynamic slice of layer i from stacked (L, ...) params."""
    return jax.tree.map(
        lambda p: jax.lax.dynamic_index_in_dim(p, i, 0, keepdims=False),
        stacked_params,
    )


def sequential_apply(
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    stacked_params: Any,
    x: jax.Array,
) -> jax.Array:
    """Reference: the plain scan the pipeline must match bit-for-bit."""
    def body(h, layer_params):
        return layer_fn(layer_params, h), None
    h, _ = jax.lax.scan(body, x, stacked_params)
    return h
