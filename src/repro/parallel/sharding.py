"""Logical-axis sharding: MaxText-style rules mapping logical tensor axes

(e.g. "batch", "d_model", "ff", "heads") onto mesh axes ("pod", "data",
"model").  A rule maps one logical name to one or more mesh axes; axes
missing from the active mesh are dropped (the same config runs single-pod
(data, model) and multi-pod (pod, data, model)), and axes that do not divide
the dimension are dropped at resolve time with a warning counter (GSPMD
would otherwise pad unevenly).

Models call :func:`logical_constraint` on activations and expose a logical
axes pytree for params; the launcher resolves both against the mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LogicalAxis = Union[str, None]
Rules = Tuple[Tuple[str, Tuple[str, ...]], ...]


def _norm(rules) -> Rules:
    out = []
    for name, axes in rules:
        if isinstance(axes, str):
            axes = (axes,)
        out.append((name, tuple(axes)))
    return tuple(out)


# Default: 2D FSDP + TP (+ pod-level DP), sequence parallelism on the
# residual stream.  "batch" shards over pod+data; weight d_model dims shard
# over data (ZeRO-3); ff/heads/vocab shard over model (Megatron TP);
# sequence of the residual stream shards over model (SP) -- GSPMD inserts
# the all-gather / reduce-scatter pairs at the TP boundaries.
RULES_FSDP_TP: Rules = _norm(
    (
        ("batch", ("pod", "data")),
        ("seq", ("model",)),          # sequence parallelism (activations)
        ("kv_seq", ("model",)),       # decode KV cache sharded along length
        ("loss_vocab", ("model",)),   # vocab-parallel chunked loss
        ("loss_embed_d", ()),
        ("d_model", ()),              # activation feature dim: replicated
        ("embed_d", ("data",)),       # weight d_model dim: FSDP
        ("vocab", ("model",)),
        ("ff", ("model",)),
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("qkv_d", ("data",)),         # weight input dim of attn projections
        ("experts", ()),
        ("expert_cap", ("data",)),
        ("layers", ()),
        ("conv", ()),
        ("state", ()),
    )
)

# Pure data parallelism (small models / debug).
RULES_DP_ONLY: Rules = _norm(
    (
        ("batch", ("pod", "data", "model")),
        ("seq", ()), ("kv_seq", ()), ("d_model", ()), ("embed_d", ()),
        ("loss_vocab", ()), ("loss_embed_d", ()),
        ("vocab", ()), ("ff", ()), ("heads", ()), ("kv_heads", ()),
        ("qkv_d", ()), ("experts", ()), ("expert_cap", ()), ("layers", ()),
        ("conv", ()), ("state", ()),
    )
)

# TP-heavy: everything feature-ish on model, batch on pod+data, no FSDP --
# a hillclimb alternative trading parameter all-gathers for activation
# collectives.
RULES_TP_HEAVY: Rules = _norm(
    (
        ("batch", ("pod", "data")),
        ("seq", ()), ("kv_seq", ("model",)),
        ("d_model", ()), ("embed_d", ()),
        ("loss_vocab", ("model",)), ("loss_embed_d", ()),
        ("vocab", ("model",)), ("ff", ("model",)), ("heads", ("model",)),
        ("kv_heads", ("model",)), ("qkv_d", ()),
        ("experts", ()), ("expert_cap", ("data",)), ("layers", ()),
        ("conv", ()), ("state", ()),
    )
)

# ZeRO-3 pure data parallelism: batch over EVERY mesh axis (256/512-way),
# parameters + optimizer state sharded 256-way along their d_model dim,
# activations never feature-sharded.  Hypothesis (EXPERIMENTS.md SSPerf):
# training cells are dominated by TP activation all-reduces (activations
# are (per-device-batch x seq x d_model) and recur every layer); ZeRO-3
# replaces them with per-layer parameter all-gathers, whose bytes are
# batch-independent and ~10x smaller at train_4k scale.
RULES_ZERO3_DP: Rules = _norm(
    (
        ("batch", ("pod", "data", "model")),
        ("seq", ()), ("kv_seq", ()),
        ("d_model", ()),
        ("embed_d", ("data", "model")),   # params/opt sharded 256-way
        ("qkv_d", ("data", "model")),
        # loss-time unembed: replicate ONCE before the chunk scan (the
        # gather is hoisted out of the loop -- SSPerf iteration 3)
        ("loss_vocab", ()), ("loss_embed_d", ()),
        ("vocab", ()), ("ff", ()), ("heads", ()), ("kv_heads", ()),
        ("experts", ()), ("expert_cap", ()), ("layers", ()),
        ("conv", ()), ("state", ()),
    )
)

# zero3_dp variant for MoE: the (experts, capacity, d_model) dispatch
# buffer must stay sharded -- replicating it turns every scatter into a
# full-buffer all-reduce (measured 3.4x WORSE than fsdp_tp on granite;
# see EXPERIMENTS.md SSPerf iteration 2).  Sharding capacity 256-ways makes
# dispatch an all-to-all of the token features instead.
RULES_ZERO3_MOE: Rules = _norm(
    tuple(
        (name, ("data", "model")) if name == "expert_cap" else (name, axes)
        for name, axes in RULES_ZERO3_DP
    )
)

NAMED_RULES = {
    "fsdp_tp": RULES_FSDP_TP,
    "dp_only": RULES_DP_ONLY,
    "tp_heavy": RULES_TP_HEAVY,
    "zero3_dp": RULES_ZERO3_DP,
    "zero3_moe": RULES_ZERO3_MOE,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Rules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def activation_sharding_ctx(mesh: Optional[Mesh], rules: Optional[Rules]):
    """Activate (mesh, rules) for logical_constraint inside model code."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, _norm(rules) if rules else None
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def _axes_for(name: Optional[str], rules: Rules, mesh: Mesh) -> Tuple[str, ...]:
    if name is None:
        return ()
    for rule_name, axes in rules:
        if rule_name == name:
            return tuple(a for a in axes if a in mesh.axis_names)
    return ()


def resolve_spec(
    logical: Sequence[LogicalAxis],
    mesh: Mesh,
    rules: Rules,
    dims: Optional[Sequence[int]] = None,
) -> P:
    """Map a logical axes tuple to a PartitionSpec on ``mesh``.

    When ``dims`` is given, mesh axes whose size does not divide the
    corresponding dim are dropped (keeps lowering legal for any config).
    """
    rules = _norm(rules)
    used = set()
    parts = []
    for i, name in enumerate(logical):
        axes = [a for a in _axes_for(name, rules, mesh) if a not in used]
        if dims is not None and axes:
            keep = []
            size = dims[i]
            for a in axes:
                asize = mesh.shape[a]
                if size % (asize * _prod(mesh.shape[k] for k in keep)) == 0:
                    keep.append(a)
            axes = keep
        for a in axes:
            used.add(a)
        if not axes:
            parts.append(None)
        elif len(axes) == 1:
            parts.append(axes[0])
        else:
            parts.append(tuple(axes))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def _prod(it) -> int:
    out = 1
    for x in it:
        out *= x
    return out


def logical_constraint(x: jax.Array, *logical: LogicalAxis) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op outside ctx)."""
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None or rules is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"{len(logical)} names for rank-{x.ndim} tensor")
    spec = resolve_spec(logical, mesh, rules, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def specs_for_tree(logical_tree, mesh: Mesh, rules: Rules, shapes=None):
    """Resolve a pytree of logical-axes tuples to NamedShardings.

    ``logical_tree`` leaves are tuples of logical names; ``shapes`` (an
    eval_shape pytree of the same structure) enables divisibility checks.
    """
    is_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    if shapes is None:
        return jax.tree.map(
            lambda ax: NamedSharding(mesh, resolve_spec(ax, mesh, rules)),
            logical_tree,
            is_leaf=is_leaf,
        )
    return jax.tree.map(
        lambda ax, sh: NamedSharding(
            mesh, resolve_spec(ax, mesh, rules, dims=sh.shape)
        ),
        logical_tree,
        shapes,
        is_leaf=is_leaf,
    )
