"""Distribution layer: logical-axis sharding rules, pipeline parallelism,

gradient compression.  Models never name mesh axes directly; they annotate
logical axes and the active rule-set maps those onto the mesh (DESIGN.md SS5).
"""
from repro.parallel.sharding import (
    RULES_FSDP_TP,
    RULES_DP_ONLY,
    RULES_TP_HEAVY,
    activation_sharding_ctx,
    logical_constraint,
    resolve_spec,
    specs_for_tree,
)

__all__ = [
    "RULES_FSDP_TP",
    "RULES_DP_ONLY",
    "RULES_TP_HEAVY",
    "activation_sharding_ctx",
    "logical_constraint",
    "resolve_spec",
    "specs_for_tree",
]
