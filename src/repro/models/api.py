"""Uniform model API: one dispatch surface over every architecture family.

``get_api(cfg)`` returns a ModelAPI whose members all share signatures:

    init_params(cfg, key) -> params
    param_axes(cfg)       -> logical-axes pytree matching params
    train_loss(cfg, params, batch) -> scalar
    prefill(cfg, params, batch)    -> (logits, cache)
    decode_step(cfg, params, cache, tokens, pos) -> (logits, cache)
    init_cache(cfg, batch, max_len) -> cache
    cache_axes(cfg)       -> logical-axes pytree matching cache

plus the layer-sliced decode surface consumed by the stage pipeline
(``runtime.stage_decode``): ``slice_params`` / ``slice_cache`` carve a
stage's weights and cache lanes for a contiguous layer range,
``decode_embed`` / ``decode_stage`` / ``decode_unembed`` split one
decode round across stages, and ``decode_slice_points`` declares the
legal stage boundaries (hybrid: shared-block group boundaries only).
``decode_step`` is exactly the one-stage composition of these, so the
fused and staged paths share every per-layer op.

``make_inputs`` / ``abstract_inputs`` build concrete or ShapeDtypeStruct
batches for any (config x assigned shape) cell -- the dry-run, smoke tests
and launchers all share them.

When ``cfg.decode_kernels`` is set, the single-token forward underneath
every decode entry point (``decode_step`` and ``decode_stage`` alike)
dispatches the per-token hot ops -- QKV+RoPE, GQA attention + output
projection, dense MLP -- to the fused Pallas kernels via
``repro.kernels.dispatch``; the API surface is unchanged, so the fused
and staged serving paths pick the kernels up from one place.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, ssm_lm, transformer


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    family: str
    init_params: Callable
    param_axes: Callable
    train_loss: Callable
    prefill: Callable          # (cfg, params, batch) -> (logits, cache)
    decode_step: Callable      # pos: () shared or (B,) per-slot positions
    init_cache: Callable
    cache_axes: Callable
    # --- layer-sliced decode (the stage pipeline's entry points) ----------
    # decode_step decomposes as decode_embed (first stage) -> decode_stage
    # per contiguous layer slice -> decode_unembed (last stage); every
    # family implements decode_step as exactly that one-stage composition,
    # so staged and fused serving share the per-layer math bit for bit.
    slice_params: Callable     # (cfg, params, (start, stop)) -> stage params
    slice_cache: Callable      # (cfg, cache, (start, stop)) -> stage cache
    decode_embed: Callable     # (cfg, params, tokens, pos) -> hidden (B,1,D)
    decode_stage: Callable     # (cfg, sp, hidden, stage_cache, pos)
    decode_unembed: Callable   # (cfg, params, hidden) -> logits (B, V)
    decode_slice_points: Callable  # (cfg) -> allowed stage boundaries
    # attention-backed families accept batch["lengths"] for bucketed
    # right-padded batched prefill (causal masking hides the pad tail);
    # recurrent families (ssm/hybrid) must see exact-length prompts --
    # padded steps would flow through the conv/SSD state.
    supports_bucketed_prefill: bool = False


def _tf_prefill(cfg, params, batch):
    return transformer.prefill(
        cfg, params, batch["tokens"], batch.get("patch_embeds"),
        lengths=batch.get("lengths"),
    )


def _encdec_prefill(cfg, params, batch):
    return encdec.prefill(
        cfg, params, batch["tokens"], batch["frames"],
        lengths=batch.get("lengths"),
    )


def _reject_lengths(family: str, batch):
    """Recurrent families must never see right-padded prompts: every
    padded step would flow through the conv/SSD state, so silently
    dropping a caller's ``lengths`` would serve corrupted prefills."""
    if batch.get("lengths") is not None:
        raise ValueError(
            f"{family} family does not support bucketed prefill: "
            "batch['lengths'] implies right-padded prompts, and padded "
            "steps would flow through the recurrent conv/SSD state "
            "(submit exact-length prompts instead)"
        )


def _hybrid_prefill(cfg, params, batch):
    _reject_lengths("hybrid", batch)
    return hybrid.prefill(cfg, params, batch["tokens"])


def _ssm_prefill(cfg, params, batch):
    _reject_lengths("ssm", batch)
    return ssm_lm.prefill(cfg, params, batch["tokens"])


_TRANSFORMER_API = ModelAPI(
    family="lm",
    init_params=transformer.init_params,
    param_axes=transformer.param_axes,
    train_loss=transformer.train_loss,
    prefill=_tf_prefill,
    decode_step=transformer.decode_step,
    init_cache=transformer.init_cache,
    cache_axes=transformer.cache_axes,
    slice_params=transformer.slice_params,
    slice_cache=transformer.slice_cache,
    decode_embed=transformer.decode_embed,
    decode_stage=transformer.decode_stage,
    decode_unembed=transformer.decode_unembed,
    decode_slice_points=transformer.decode_slice_points,
    supports_bucketed_prefill=True,
)


def get_api(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("lm", "moe", "vlm"):
        return dataclasses.replace(_TRANSFORMER_API, family=fam)
    if fam == "ssm":
        return ModelAPI(
            family=fam,
            init_params=ssm_lm.init_params,
            param_axes=ssm_lm.param_axes,
            train_loss=ssm_lm.train_loss,
            prefill=_ssm_prefill,
            decode_step=ssm_lm.decode_step,
            init_cache=ssm_lm.init_cache,
            cache_axes=ssm_lm.cache_axes,
            slice_params=ssm_lm.slice_params,
            slice_cache=ssm_lm.slice_cache,
            decode_embed=ssm_lm.decode_embed,
            decode_stage=ssm_lm.decode_stage,
            decode_unembed=ssm_lm.decode_unembed,
            decode_slice_points=ssm_lm.decode_slice_points,
        )
    if fam == "hybrid":
        return ModelAPI(
            family=fam,
            init_params=hybrid.init_params,
            param_axes=hybrid.param_axes,
            train_loss=hybrid.train_loss,
            prefill=_hybrid_prefill,
            decode_step=hybrid.decode_step,
            init_cache=hybrid.init_cache,
            cache_axes=hybrid.cache_axes,
            slice_params=hybrid.slice_params,
            slice_cache=hybrid.slice_cache,
            decode_embed=hybrid.decode_embed,
            decode_stage=hybrid.decode_stage,
            decode_unembed=hybrid.decode_unembed,
            decode_slice_points=hybrid.decode_slice_points,
        )
    if fam == "encdec":
        return ModelAPI(
            family=fam,
            init_params=encdec.init_params,
            param_axes=encdec.param_axes,
            train_loss=encdec.train_loss,
            prefill=_encdec_prefill,
            decode_step=encdec.decode_step,
            init_cache=encdec.init_cache,
            cache_axes=encdec.cache_axes,
            slice_params=encdec.slice_params,
            slice_cache=encdec.slice_cache,
            decode_embed=encdec.decode_embed,
            decode_stage=encdec.decode_stage,
            decode_unembed=encdec.decode_unembed,
            decode_slice_points=encdec.decode_slice_points,
            supports_bucketed_prefill=True,
        )
    raise ValueError(f"unknown family {fam}")


# ------------------------------------------------------------- inputs -----


def _model_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract train/prefill batch for one (config, shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
    if cfg.family == "vlm":
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_patches, cfg.d_model), _model_dtype(cfg)
        )
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_frames, cfg.d_model), _model_dtype(cfg)
        )
    return out


def batch_axes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, tuple]:
    out = {"tokens": ("batch", None)}
    if shape.kind == "train":
        out["labels"] = ("batch", None)
        out["mask"] = ("batch", None)
    if cfg.family == "vlm":
        out["patch_embeds"] = ("batch", None, None)
    if cfg.family == "encdec":
        out["frames"] = ("batch", None, None)
    return out


def decode_inputs_struct(cfg: ModelConfig, shape: ShapeConfig):
    """(cache, tokens, pos) abstract inputs for decode_step.

    ``pos`` is the per-slot position vector (B,): the serving engine
    decodes slots at staggered positions, so the lowered decode cell
    must carry one write position per lane.
    """
    api = get_api(cfg)
    cache = jax.eval_shape(
        lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len)
    )
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    return cache, tokens, pos


def make_concrete(struct_tree, key=None, vocab: int = 32000):
    """Materialize a ShapeDtypeStruct pytree with deterministic test data.

    Loss masks (leaves whose path ends in "mask") become all-ones.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(struct_tree)
    keys = jax.random.split(key, len(leaves))
    out = []
    for (path, s), k in zip(leaves, keys):
        name = str(path[-1]) if path else ""
        if "mask" in name:
            out.append(jnp.ones(s.shape, s.dtype))
        elif jnp.issubdtype(s.dtype, jnp.integer):
            out.append(jax.random.randint(k, s.shape, 0, min(vocab, 512), s.dtype))
        else:
            out.append(jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype))
    return jax.tree.unflatten(treedef, out)
