"""INT8 ResNet-18/50 -- the paper's own evaluation models (SS V).

Runs convolutions as GEMMs through the Pallas kernels (im2col + int8_gemm)
with power-of-two scaling, fused ReLU and fused residual additions, exactly
the PU dataflow.  The max-pool is fused into post-processing (reduce_window
on the int8 feature map) and the average-pool runs as a mean + requantize,
consistent with the paper's choices.

Also provides a float reference forward (dequantized weights) so the int8
path and the AIMC noise studies have a baseline.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, quantize, requantize_i32
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    cin: int
    cout: int
    k: int
    stride: int
    pad: int
    relu: bool
    residual_from: Optional[str] = None   # fuse residual input tagged w/ name


def resnet_conv_specs(variant: int) -> List[ConvSpec]:
    """Per-layer conv specs (matching core/simulator.py's GEMM table)."""
    specs: List[ConvSpec] = [ConvSpec("conv1", 3, 64, 7, 2, 3, relu=True)]
    if variant == 18:
        blocks, ch_list, cin, expansion = [2, 2, 2, 2], [64, 128, 256, 512], 64, 1
        for s_i, (nb, ch) in enumerate(zip(blocks, ch_list)):
            for b in range(nb):
                stride = 2 if (s_i > 0 and b == 0) else 1
                downsample = stride != 1 or cin != ch
                specs.append(ConvSpec(f"s{s_i}b{b}c1", cin, ch, 3, stride, 1, relu=True))
                specs.append(
                    ConvSpec(
                        f"s{s_i}b{b}c2", ch, ch, 3, 1, 1, relu=True,
                        residual_from=(f"s{s_i}b{b}down" if downsample else "block_in"),
                    )
                )
                if downsample:
                    specs.append(ConvSpec(f"s{s_i}b{b}down", cin, ch, 1, stride, 0, relu=False))
                cin = ch
    elif variant == 50:
        blocks, ch_list, cin, expansion = [3, 4, 6, 3], [64, 128, 256, 512], 64, 4
        for s_i, (nb, ch) in enumerate(zip(blocks, ch_list)):
            for b in range(nb):
                stride = 2 if (s_i > 0 and b == 0) else 1
                downsample = stride != 1 or cin != ch * 4
                specs.append(ConvSpec(f"s{s_i}b{b}c1", cin, ch, 1, 1, 0, relu=True))
                specs.append(ConvSpec(f"s{s_i}b{b}c2", ch, ch, 3, stride, 1, relu=True))
                specs.append(
                    ConvSpec(
                        f"s{s_i}b{b}c3", ch, ch * 4, 1, 1, 0, relu=True,
                        residual_from=(f"s{s_i}b{b}down" if downsample else "block_in"),
                    )
                )
                if downsample:
                    specs.append(ConvSpec(f"s{s_i}b{b}down", cin, ch * 4, 1, stride, 0, relu=False))
                cin = ch * 4
    else:
        raise ValueError(variant)
    return specs


def feature_dim(variant: int) -> int:
    return 512 if variant == 18 else 2048


def init_params(variant: int, key, num_classes: int = 1000) -> dict:
    """Random-initialized quantized parameters (weights QTensor, bias int32,

    per-layer output shift).  Real deployments would load calibrated
    checkpoints; numerics and dataflow are identical.
    """
    specs = resnet_conv_specs(variant)
    params: Dict[str, dict] = {}
    keys = jax.random.split(key, len(specs) + 1)
    for spec, k in zip(specs, keys[:-1]):
        fan_in = spec.k * spec.k * spec.cin
        w = jax.random.normal(k, (spec.k, spec.k, spec.cin, spec.cout)) * (
            2.0 / fan_in
        ) ** 0.5
        wq = quantize(w)
        params[spec.name] = {
            "w": wq,
            "bias": jnp.zeros((spec.cout,), jnp.int32),
            # requantize acc -> int8 on the same activation grid:
            # shift = -e_w  (out_exp - (act_exp + w_exp) with out=act grid)
            "shift": -wq.exp,
        }
    feat = feature_dim(variant)
    wfc = jax.random.normal(keys[-1], (feat, num_classes)) * (1.0 / feat) ** 0.5
    wq = quantize(wfc)
    params["fc"] = {"w": wq, "bias": jnp.zeros((num_classes,), jnp.int32), "shift": -wq.exp}
    return params


def _maxpool_int8(x: jax.Array, k: int = 3, s: int = 2, p: int = 1) -> jax.Array:
    xp = jnp.pad(x, ((p, p), (p, p), (0, 0)), constant_values=-128)
    return jax.lax.reduce_window(
        xp, jnp.int8(-128), jax.lax.max, (k, k, 1), (s, s, 1), "VALID"
    )


def forward_int8(
    variant: int,
    params: dict,
    img: jax.Array,          # (H, W, 3) int8
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Single-image INT8 inference -> (num_classes,) int32 logits (acc grid)."""
    specs = {s.name: s for s in resnet_conv_specs(variant)}
    order = resnet_conv_specs(variant)

    x = img
    saved: Dict[str, jax.Array] = {}
    i = 0
    x = _apply_conv(params, order[0], x, None, interpret)
    x = _maxpool_int8(x)
    i = 1
    block_in = x
    pending_down: Dict[str, jax.Array] = {}
    while i < len(order):
        spec = order[i]
        if spec.residual_from is None and spec.name.endswith("down"):
            i += 1
            continue
        if spec.residual_from is not None:
            # compute downsample branch first if needed
            if spec.residual_from != "block_in":
                dspec = specs[spec.residual_from]
                res = _apply_conv(params, dspec, block_in, None, interpret)
            else:
                res = block_in
            x = _apply_conv(params, spec, x, res, interpret)
            block_in = x
        else:
            x = _apply_conv(params, spec, x, None, interpret)
        i += 1

    # global average pool (paper: executed as a conv layer; mean+requant here)
    feat = jnp.mean(x.astype(jnp.int32), axis=(0, 1))        # (C,) on act grid
    w = params["fc"]["w"]
    logits = w.q.astype(jnp.int32).T @ feat + params["fc"]["bias"]
    return logits


def _apply_conv(params, spec: ConvSpec, x, residual, interpret):
    p = params[spec.name]
    return ops.conv2d_int8(
        x, p["w"].q, p["bias"], k=spec.k, stride=spec.stride, pad=spec.pad,
        shift=p["shift"], relu=spec.relu, residual=residual,
        interpret=interpret,
    )


def forward_float(variant: int, params: dict, img: jax.Array) -> jax.Array:
    """Float reference with dequantized weights (baseline for AIMC studies)."""
    specs = {s.name: s for s in resnet_conv_specs(variant)}
    order = resnet_conv_specs(variant)

    def conv(spec: ConvSpec, x, residual=None):
        w = params[spec.name]["w"].dequantize()
        y = jax.lax.conv_general_dilated(
            x[None].transpose(0, 3, 1, 2), w.transpose(3, 2, 0, 1),
            (spec.stride, spec.stride), [(spec.pad, spec.pad)] * 2,
        )[0].transpose(1, 2, 0)
        if residual is not None:
            y = y + residual
        if spec.relu:
            y = jax.nn.relu(y)
        return y

    x = conv(order[0], img.astype(jnp.float32))
    x = jax.lax.reduce_window(
        jnp.pad(x, ((1, 1), (1, 1), (0, 0)), constant_values=-jnp.inf),
        -jnp.inf, jax.lax.max, (3, 3, 1), (2, 2, 1), "VALID",
    )
    block_in = x
    i = 1
    while i < len(order):
        spec = order[i]
        if spec.name.endswith("down") and spec.residual_from is None:
            i += 1
            continue
        if spec.residual_from is not None:
            if spec.residual_from != "block_in":
                res = conv(specs[spec.residual_from], block_in)
            else:
                res = block_in
            x = conv(spec, x, res)
            block_in = x
        else:
            x = conv(spec, x)
        i += 1
    feat = jnp.mean(x, axis=(0, 1))
    return feat @ params["fc"]["w"].dequantize()
