"""Mamba2 decoder-only LM (attention-free): embed -> scanned SSD blocks ->

norm -> unembed.  Decode carries (conv, ssm) states per layer; there is no
KV cache, which is exactly why long_500k runs for this family.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.common import apply_norm, chunked_softmax_xent, norm_axes, norm_params
from repro.parallel.sharding import logical_constraint


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _layer(cfg, key):
    k1, k2 = jax.random.split(key)
    return {"norm": norm_params(cfg, cfg.d_model, k1), "ssm": ssm_mod.ssm_params(cfg, k2)}


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 3 + cfg.n_layers)
    init = jax.nn.initializers.normal(0.02)
    params = {
        "embed": init(keys[0], (cfg.vocab, cfg.d_model), jnp.float32),
        "final_norm": norm_params(cfg, cfg.d_model, keys[1]),
        "layers": jax.vmap(lambda k: _layer(cfg, k))(jnp.stack(keys[3:])),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init(keys[2], (cfg.d_model, cfg.vocab), jnp.float32)
    return params


def param_axes(cfg: ModelConfig) -> dict:
    is_ax_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    layer_ax = jax.tree.map(
        lambda ax: ("layers",) + ax,
        {"norm": norm_axes(cfg), "ssm": ssm_mod.ssm_axes(cfg)},
        is_leaf=is_ax_leaf,
    )
    axes = {
        "embed": ("vocab", "embed_d"),
        "final_norm": norm_axes(cfg),
        "layers": layer_ax,
    }
    if not cfg.tie_embeddings:
        axes["unembed"] = ("embed_d", "vocab")
    return axes


def _unembed_matrix(cfg, params):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def forward_hidden(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = params["embed"].astype(_dtype(cfg))[tokens]
    x = logical_constraint(x, "batch", "seq", "d_model")

    def body(x, lp):
        h = apply_norm(cfg, x, lp.get("norm"))
        y, _ = ssm_mod.ssm_apply(cfg, lp["ssm"], h, None)
        return x + y, None

    if cfg.remat == "layer":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return apply_norm(cfg, x, params.get("final_norm"))


def train_loss(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    hidden = forward_hidden(cfg, params, batch["tokens"])
    return chunked_softmax_xent(
        hidden, _unembed_matrix(cfg, params), batch["labels"], batch.get("mask")
    )


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    dt = _dtype(cfg)
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_dim), dt),
        "ssm": jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
    }


def cache_axes(cfg: ModelConfig):
    return {
        "conv": ("layers", "batch", None, "ff"),
        "ssm": ("layers", "batch", "heads", None, None),
    }


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array):
    """Chunked-SSD pass that also returns the final recurrent states."""
    b, s = tokens.shape
    x = params["embed"].astype(_dtype(cfg))[tokens]
    x = logical_constraint(x, "batch", "seq", "d_model")
    st0 = ssm_mod.init_ssm_state(cfg, b)

    def body(x, lp):
        h = apply_norm(cfg, x, lp.get("norm"))
        y, st = ssm_mod.ssm_apply(cfg, lp["ssm"], h, st0)
        return x + y, st

    x, (convs, ssms) = jax.lax.scan(body, x, params["layers"])
    x = apply_norm(cfg, x, params.get("final_norm"))
    logits = (x[:, -1] @ _unembed_matrix(cfg, params).astype(x.dtype)).astype(jnp.float32)
    return logits, {"conv": convs.astype(_dtype(cfg)), "ssm": ssms}


# -------------------------------------------------- layer-sliced decode ---


def decode_slice_points(cfg: ModelConfig):
    """SSM layers are independent states: any boundary is valid."""
    return tuple(range(cfg.n_layers + 1))


def slice_params(cfg: ModelConfig, params: dict, layer_range) -> dict:
    start, stop = layer_range
    return {"layers": jax.tree.map(lambda a: a[start:stop], params["layers"])}


def slice_cache(cfg: ModelConfig, cache, layer_range):
    start, stop = layer_range
    return jax.tree.map(lambda a: a[start:stop], cache)


def decode_embed(cfg: ModelConfig, params: dict, tokens: jax.Array, pos: jax.Array) -> jax.Array:
    del pos  # SSM state is position-free
    return params["embed"].astype(_dtype(cfg))[tokens]


def decode_stage(cfg: ModelConfig, stage_params: dict, hidden: jax.Array, stage_cache: dict, pos: jax.Array):
    del pos
    if jax.tree.leaves(stage_params["layers"])[0].shape[0] == 0:
        return hidden, stage_cache

    def body(x, xs):
        lp, cst, sst = xs
        h = apply_norm(cfg, x, lp.get("norm"))
        y, st = ssm_mod.ssm_decode_step(cfg, lp["ssm"], h, (cst, sst))
        return x + y, st

    x, (convs, ssms) = jax.lax.scan(
        body, hidden,
        (stage_params["layers"], stage_cache["conv"], stage_cache["ssm"]),
    )
    return x, {"conv": convs, "ssm": ssms}


def decode_unembed(cfg: ModelConfig, params: dict, hidden: jax.Array) -> jax.Array:
    x = apply_norm(cfg, hidden, params.get("final_norm"))
    return (x[:, -1] @ _unembed_matrix(cfg, params).astype(x.dtype)).astype(jnp.float32)


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array, pos: jax.Array):
    x = decode_embed(cfg, params, tokens, pos)
    x, new_cache = decode_stage(
        cfg, slice_params(cfg, params, (0, cfg.n_layers)), x, cache, pos
    )
    return decode_unembed(cfg, params, x), new_cache
