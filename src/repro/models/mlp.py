"""Dense MLPs and top-k routed mixture-of-experts.

MoE uses GShard-style capacity dispatch but with index scatters instead of
(T, E, C) one-hot einsums, so dispatch memory is O(T*K + E*C*D) and the
whole block stays pjit-shardable: capacity shards over "data" (expert_cap
rule) and expert hidden dims over "model" (ff rule); an EP rule-set can
move experts onto their own axis without touching this code.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import mlp_act, mlp_is_gated
from repro.parallel import sharding as _sh
from repro.parallel.sharding import logical_constraint


# --------------------------------------------------------------- dense ----


def mlp_params(cfg, key, d_model=None, d_ff=None) -> dict:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    init = jax.nn.initializers.normal(0.02)
    p = {"w_up": init(keys[0], (d, f), jnp.float32),
         "w_down": init(keys[1], (f, d), jnp.float32)}
    if mlp_is_gated(cfg.mlp):
        p["w_gate"] = init(keys[2], (d, f), jnp.float32)
    if cfg.mlp_bias:
        p["b_up"] = jnp.zeros((f,), jnp.float32)
        p["b_down"] = jnp.zeros((d,), jnp.float32)
    return p


def mlp_axes(cfg) -> dict:
    ax = {"w_up": ("embed_d", "ff"), "w_down": ("ff", "embed_d")}
    if mlp_is_gated(cfg.mlp):
        ax["w_gate"] = ("embed_d", "ff")
    if cfg.mlp_bias:
        ax["b_up"] = ("ff",)
        ax["b_down"] = ("d_model",)
    return ax


def mlp_apply(cfg, p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    g = x @ p["w_up"].astype(dt) if "w_gate" not in p else x @ p["w_gate"].astype(dt)
    up = x @ p["w_up"].astype(dt) if "w_gate" in p else None
    if cfg.mlp_bias:
        g = g + p["b_up"].astype(dt)
    h = mlp_act(cfg.mlp, g, up)
    h = logical_constraint(h, "batch", None, "ff")
    y = h @ p["w_down"].astype(dt)
    if cfg.mlp_bias:
        y = y + p["b_down"].astype(dt)
    return y


# ----------------------------------------------------------------- MoE ----


def moe_params(cfg, key) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    keys = jax.random.split(key, 4)
    init = jax.nn.initializers.normal(0.02)
    p = {
        "router": init(keys[0], (d, e), jnp.float32),
        "w_up": init(keys[1], (e, d, f), jnp.float32),
        "w_down": init(keys[2], (e, f, d), jnp.float32),
    }
    if mlp_is_gated(cfg.mlp):
        p["w_gate"] = init(keys[3], (e, d, f), jnp.float32)
    return p


def moe_axes(cfg) -> dict:
    ax = {
        "router": ("embed_d", "experts"),
        "w_up": ("experts", "embed_d", "ff"),
        "w_down": ("experts", "ff", "embed_d"),
    }
    if mlp_is_gated(cfg.mlp):
        ax["w_gate"] = ("experts", "embed_d", "ff")
    return ax


def moe_capacity(cfg, tokens: int) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cap, cfg.top_k)


def moe_apply(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Routed MoE with locality-aware dispatch.

    When a sharding context is active and the batch is sharded, dispatch
    runs *locally* per device group via shard_map: each group routes its
    own tokens into its own (E, C_local, D) buffer against the (gathered)
    expert weights -- zero dispatch collectives.  Measured on
    granite/train_4k (SSPerf): global-buffer dispatch under GSPMD costs
    41-199 s/step of collectives; local dispatch removes all of it.

    Capacity semantics become per-group (C_local = local_tokens * k * cf /
    E), which is what EP systems deploy in practice -- documented in
    DESIGN.md SSArch-applicability.
    """
    mesh, rules = _sh._CTX.mesh, _sh._CTX.rules
    if mesh is not None and rules is not None:
        # Dispatch shards tokens over EVERY mesh axis -- batch rows over
        # the rule-set's batch axes, sequence over the remaining axes --
        # WITHOUT reshaping (merging two sharded dims forces a full
        # re-layout gather per layer: measured 3.9x collective regression
        # multi-pod).  Restricting to batch axes alone would replicate the
        # dispatch compute across the remaining axes (measured 16x
        # redundant MoE flops on mixtral/train_4k under fsdp_tp).
        batch_rule = tuple(
            a for a in _sh._axes_for("batch", rules, mesh)
            if mesh.shape[a] > 1
        )
        rest = tuple(
            a for a in mesh.axis_names
            if mesh.shape[a] > 1 and a not in batch_rule
        )
        b_div = 1
        for a in batch_rule:
            b_div *= mesh.shape[a]
        s_div = 1
        for a in rest:
            s_div *= mesh.shape[a]
        axes0 = batch_rule if (b_div and x.shape[0] % b_div == 0) else ()
        axes1 = rest if (s_div and x.shape[1] % s_div == 0) else ()
        shard_axes = (axes0, axes1)
        n_shards = 1
        for a in axes0 + axes1:
            n_shards *= mesh.shape[a]
        # Dispatch cost model (EXPERIMENTS.md SSPerf, cell A): local
        # dispatch replicates the expert bank (E*3*D*F bytes/layer) per
        # device group but moves no tokens; global dispatch keeps weights
        # sharded but its dynamic-index scatters generate heavy GSPMD
        # traffic proportional to the capacity buffer.  Local pays iff the
        # token traffic exceeds the expert bank: T > E*F.
        # Measured (collective term, 256 chips):
        #   granite train  T=1M >> 20k  local 4.0s  vs global 41.0s (10.2x)
        #   mixtral train  T=1M >> 115k local 17.2s vs global 32.8s  (1.9x)
        #   mixtral decode T=128 < 115k local 3.8s  vs global 13ms   (294x)
        # cfg.moe_dispatch ('local'/'global') overrides the rule.
        tokens_global = x.shape[0] * x.shape[1]
        if cfg.moe_dispatch == "local":
            local_pays = True
        elif cfg.moe_dispatch == "global":
            local_pays = False
        else:
            local_pays = tokens_global > cfg.n_experts * cfg.d_ff
        if (axes0 or axes1) and n_shards > 1 and local_pays:
            return _moe_apply_local(cfg, p, x, mesh, shard_axes)
    return _moe_apply_global(cfg, p, x)


def _moe_apply_local(cfg, p, x, mesh, shard_axes):
    """shard_map dispatch with (batch-axes, seq-axes) token sharding:
    tokens stay on their devices; expert weights enter replicated (GSPMD
    gathers the ZeRO shards at the boundary)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axes0, axes1 = shard_axes

    def _part(axes):
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    x_spec = P(_part(axes0), _part(axes1))
    aux_spec = P(_part(axes0 + axes1) if (axes0 or axes1) else None)
    w_spec = jax.tree.map(lambda _: P(), p)

    def local_fn(p_local, x_local):
        # inside shard_map every mesh axis is manual: with_sharding_constraint
        # on them is illegal AND meaningless -- suspend the logical-axis ctx.
        with _sh.activation_sharding_ctx(None, None):
            y, aux = _moe_apply_global(cfg, p_local, x_local)
        return y, aux.reshape(1)

    y, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(w_spec, x_spec),
        out_specs=(x_spec, aux_spec),
        check_rep=False,
    )(p, x)
    return y, jnp.mean(aux)


def _moe_apply_global(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Routed MoE.  x: (B, S, D) -> (y, aux_loss).

    Dispatch: top-k router; per-(token, k) target slot (e, pos) computed via
    a (T, E) assignment cumsum; token features scattered into an (E, C, D)
    buffer with mode="drop" enforcing capacity; combined back with a gather.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    cap = moe_capacity(cfg, t)
    dt = x.dtype

    xt = x.reshape(t, d)
    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, k)                  # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Load-balancing auxiliary loss (Switch-style): E * sum(f_e * p_e).
    onehot = jax.nn.one_hot(experts, e, dtype=jnp.float32)        # (T, K, E)
    assign = jnp.sum(onehot, axis=1)                              # (T, E)
    frac_tokens = jnp.mean(assign, axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs) / k

    # Position of token t in expert e's buffer (GShard cumsum).
    positions_te = jnp.cumsum(assign, axis=0) - 1.0               # (T, E)
    pos = jnp.take_along_axis(
        positions_te, experts.astype(jnp.int32), axis=1
    ).astype(jnp.int32)                                           # (T, K)
    keep = pos < cap
    pos_safe = jnp.where(keep, pos, cap)                          # OOB -> drop

    e_flat = experts.reshape(t * k)
    pos_flat = pos_safe.reshape(t * k)
    x_rep = jnp.repeat(xt[:, None, :], k, axis=1).reshape(t * k, d)

    buf = jnp.zeros((e, cap, d), dt)
    buf = buf.at[e_flat, pos_flat].add(x_rep, mode="drop")
    buf = logical_constraint(buf, "experts", "expert_cap", None)

    g = jnp.einsum("ecd,edf->ecf", buf,
                   (p["w_gate"] if "w_gate" in p else p["w_up"]).astype(dt))
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt)) if "w_gate" in p else None
    h = mlp_act(cfg.mlp, g, up)
    h = logical_constraint(h, "experts", "expert_cap", "ff")
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))   # (E, C, D)

    gathered = y_e.at[e_flat, pos_flat].get(
        mode="fill", fill_value=0
    )                                                             # (T*K, D)
    w = jnp.where(keep, gate_vals, 0.0).reshape(t * k, 1).astype(dt)
    y = jnp.sum((gathered * w).reshape(t, k, d), axis=1)
    return y.reshape(b, s, d), aux
