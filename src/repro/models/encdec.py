"""Whisper-style encoder-decoder.

The audio front-end (mel conv stack) is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (B, frames, D).
The transformer backbone is faithful: bidirectional encoder, causal
decoder with cross-attention, GELU MLPs, LayerNorm with bias, learned
decoder positions, sinusoidal encoder positions, tied unembedding.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import dispatch as kdispatch
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (
    apply_norm,
    chunked_softmax_xent,
    norm_axes,
    norm_params,
    sinusoidal_positions,
)
from repro.parallel.sharding import logical_constraint


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ------------------------------------------------------------- params -----


def _enc_layer(cfg, key):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": norm_params(cfg, cfg.d_model, k1),
        "attn": attn.attn_params(cfg, k1),
        "mlp_norm": norm_params(cfg, cfg.d_model, k2),
        "mlp": mlp_mod.mlp_params(cfg, k2),
    }


def _dec_layer(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": norm_params(cfg, cfg.d_model, k1),
        "attn": attn.attn_params(cfg, k1),
        "cross_norm": norm_params(cfg, cfg.d_model, k2),
        "cross": attn.attn_params(cfg, k2),
        "mlp_norm": norm_params(cfg, cfg.d_model, k3),
        "mlp": mlp_mod.mlp_params(cfg, k3),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 6)
    init = jax.nn.initializers.normal(0.02)
    enc_keys = jnp.stack(jax.random.split(keys[0], cfg.encoder_layers))
    dec_keys = jnp.stack(jax.random.split(keys[1], cfg.n_layers))
    return {
        "embed": init(keys[2], (cfg.vocab, cfg.d_model), jnp.float32),
        "dec_pos": init(keys[3], (cfg.max_position, cfg.d_model), jnp.float32),
        "enc_layers": jax.vmap(lambda k: _enc_layer(cfg, k))(enc_keys),
        "enc_norm": norm_params(cfg, cfg.d_model, keys[4]),
        "dec_layers": jax.vmap(lambda k: _dec_layer(cfg, k))(dec_keys),
        "dec_norm": norm_params(cfg, cfg.d_model, keys[5]),
    }


def param_axes(cfg: ModelConfig) -> dict:
    is_ax_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    enc_ax = {
        "attn_norm": norm_axes(cfg), "attn": attn.attn_axes(cfg),
        "mlp_norm": norm_axes(cfg), "mlp": mlp_mod.mlp_axes(cfg),
    }
    dec_ax = {
        "attn_norm": norm_axes(cfg), "attn": attn.attn_axes(cfg),
        "cross_norm": norm_axes(cfg), "cross": attn.attn_axes(cfg),
        "mlp_norm": norm_axes(cfg), "mlp": mlp_mod.mlp_axes(cfg),
    }
    return {
        "embed": ("vocab", "embed_d"),
        "dec_pos": (None, "embed_d"),
        "enc_layers": jax.tree.map(lambda a: ("layers",) + a, enc_ax, is_leaf=is_ax_leaf),
        "enc_norm": norm_axes(cfg),
        "dec_layers": jax.tree.map(lambda a: ("layers",) + a, dec_ax, is_leaf=is_ax_leaf),
        "dec_norm": norm_axes(cfg),
    }


# ------------------------------------------------------------- encoder ----


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, T_enc, D) stub embeddings -> encoder output (B, T_enc, D)."""
    b, t, d = frames.shape
    x = frames.astype(_dtype(cfg)) + sinusoidal_positions(t, d).astype(_dtype(cfg))[None]
    x = logical_constraint(x, "batch", "seq", "d_model")
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(x, lp):
        h = apply_norm(cfg, x, lp.get("attn_norm"))
        q, k, v = attn.project_qkv(cfg, lp["attn"], h)
        ctx = attn.gqa_attention(
            q, k, v, q_positions=positions, causal=False, chunk=cfg.attn_chunk
        )
        x = x + attn.project_out(cfg, lp["attn"], ctx)
        h2 = apply_norm(cfg, x, lp.get("mlp_norm"))
        x = x + mlp_mod.mlp_apply(cfg, lp["mlp"], h2)
        return x, None

    if cfg.remat == "layer":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg, x, params.get("enc_norm"))


# ------------------------------------------------------------- decoder ----


def _dec_layer_fn(
    cfg, lp, x, positions, enc_out, self_cache=None, cross_kv=None, decode_pos=None
):
    # fused decode kernels on the single-token path (no rope: the decoder
    # uses learned positions added at embed time)
    use_kernels = kdispatch.attention_active(cfg, x) and self_cache is not None
    h = apply_norm(cfg, x, lp.get("attn_norm"))
    if use_kernels:
        q, k, v = kdispatch.decode_qkv(cfg, lp["attn"], h, positions, rope=False)
    else:
        q, k, v = attn.project_qkv(cfg, lp["attn"], h)
    new_cache = None
    if self_cache is not None:
        ck, cv = self_cache
        if jnp.ndim(decode_pos) > 0:
            # staggered batched decode: each lane writes at its own pos
            lane = jnp.arange(ck.shape[0])
            ck = ck.at[lane, decode_pos].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[lane, decode_pos].set(v[:, 0].astype(cv.dtype))
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, decode_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, decode_pos, 0, 0))
        new_cache = (ck, cv)
        k, v = ck.astype(x.dtype), cv.astype(x.dtype)
        valid = decode_pos + x.shape[1]
    else:
        valid = None
    if use_kernels:
        x = x + kdispatch.decode_attention(
            cfg, lp["attn"], q, k, v,
            q_positions=positions, kv_valid_len=valid,
        )
    else:
        ctx = attn.gqa_attention(
            q, k, v, q_positions=positions, kv_valid_len=valid, causal=True,
            chunk=cfg.attn_chunk,
        )
        x = x + attn.project_out(cfg, lp["attn"], ctx)

    # cross-attention over encoder output (bidirectional, fixed length)
    h2 = apply_norm(cfg, x, lp.get("cross_norm"))
    qc = (h2 @ lp["cross"]["wq"].astype(x.dtype))
    if cfg.attn_bias:
        qc = qc + lp["cross"]["bq"].astype(x.dtype)
    b, s, _ = h2.shape
    qc = qc.reshape(b, s, cfg.n_heads, cfg.head_dim)
    if cross_kv is not None:
        kc, vc = cross_kv
    else:
        enc_h = enc_out
        kc = enc_h @ lp["cross"]["wk"].astype(x.dtype)
        vc = enc_h @ lp["cross"]["wv"].astype(x.dtype)
        if cfg.attn_bias:
            kc = kc + lp["cross"]["bk"].astype(x.dtype)
            vc = vc + lp["cross"]["bv"].astype(x.dtype)
        te = enc_h.shape[1]
        kc = kc.reshape(b, te, cfg.n_kv_heads, cfg.head_dim)
        vc = vc.reshape(b, te, cfg.n_kv_heads, cfg.head_dim)
    if use_kernels:
        # fixed-length bidirectional cross-attention: same kernel, causal off
        x = x + kdispatch.decode_attention(
            cfg, lp["cross"], qc, kc, vc,
            q_positions=positions, causal=False,
        )
    else:
        ctx2 = attn.gqa_attention(
            qc, kc, vc, q_positions=positions, causal=False, chunk=cfg.attn_chunk
        )
        y = ctx2.reshape(b, s, cfg.n_heads * cfg.head_dim) @ lp["cross"]["wo"].astype(x.dtype)
        if cfg.attn_bias:
            y = y + lp["cross"]["bo"].astype(x.dtype)
        x = x + y

    h3 = apply_norm(cfg, x, lp.get("mlp_norm"))
    if kdispatch.mlp_active(cfg, h3):
        x = x + kdispatch.decode_mlp(cfg, lp["mlp"], h3)
    else:
        x = x + mlp_mod.mlp_apply(cfg, lp["mlp"], h3)
    return x, new_cache, (kc, vc)


def decode_full(cfg, params, tokens, enc_out):
    """Teacher-forced decoder pass (training) -> hidden (B, S, D)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = params["embed"].astype(_dtype(cfg))[tokens]
    x = x + params["dec_pos"].astype(x.dtype)[:s][None]
    x = logical_constraint(x, "batch", "seq", "d_model")

    def body(x, lp):
        x, _, _ = _dec_layer_fn(cfg, lp, x, positions, enc_out)
        return x, None

    if cfg.remat == "layer":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return apply_norm(cfg, x, params.get("dec_norm"))


def train_loss(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    enc_out = encode(cfg, params, batch["frames"])
    hidden = decode_full(cfg, params, batch["tokens"], enc_out)
    return chunked_softmax_xent(
        hidden, params["embed"].T, batch["labels"], batch.get("mask")
    )


# ------------------------------------------------------------- serving ----


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = _dtype(cfg)
    l, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    te = cfg.encoder_frames
    return {
        "self_k": jnp.zeros((l, batch, max_len, kv, hd), dt),
        "self_v": jnp.zeros((l, batch, max_len, kv, hd), dt),
        "cross_k": jnp.zeros((l, batch, te, kv, hd), dt),
        "cross_v": jnp.zeros((l, batch, te, kv, hd), dt),
    }


def cache_axes(cfg: ModelConfig):
    kv_ax = ("layers", "batch", "kv_seq", "kv_heads", None)
    cr_ax = ("layers", "batch", None, "kv_heads", None)
    return {"self_k": kv_ax, "self_v": kv_ax, "cross_k": cr_ax, "cross_v": cr_ax}


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    frames: jax.Array,
    lengths: Optional[jax.Array] = None,
):
    """Encode + teacher-forced pass, emitting all caches for decode.

    ``lengths`` (B,) supports bucketed batched prefill (right-padded
    decoder prompts): logits come from each row's last real token; the
    padded cache tail stays causally masked until decode overwrites it.
    """
    enc_out = encode(cfg, params, frames)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = params["embed"].astype(_dtype(cfg))[tokens]
    x = x + params["dec_pos"].astype(x.dtype)[:s][None]

    cache0 = init_cache(cfg, b, s)

    def body(x, xs):
        lp, ck, cv = xs
        x, new_cache, cross = _dec_layer_fn(
            cfg, lp, x, positions, enc_out, self_cache=(ck, cv), decode_pos=0
        )
        return x, (new_cache[0], new_cache[1], cross[0], cross[1])

    x, (sk, sv, crk, crv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache0["self_k"], cache0["self_v"])
    )
    x = apply_norm(cfg, x, params.get("dec_norm"))
    x_last = x[:, -1] if lengths is None else x[jnp.arange(b), lengths - 1]
    logits = (x_last @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    return logits, {"self_k": sk, "self_v": sv, "cross_k": crk, "cross_v": crv}


# -------------------------------------------------- layer-sliced decode ---


def _decode_positions(pos, b):
    pos = jnp.asarray(pos, jnp.int32)
    positions = (
        jnp.broadcast_to(pos, (b, 1)) if pos.ndim == 0 else pos[:, None]
    ).astype(jnp.int32)
    return pos, positions


def decode_slice_points(cfg: ModelConfig) -> Tuple[int, ...]:
    """Decoder-layer indices where a stage boundary may fall."""
    return tuple(range(cfg.n_layers + 1))


def slice_params(cfg: ModelConfig, params: dict, layer_range) -> dict:
    start, stop = layer_range
    return {
        "dec_layers": jax.tree.map(
            lambda a: a[start:stop], params["dec_layers"]
        ),
    }


def slice_cache(cfg: ModelConfig, cache, layer_range):
    start, stop = layer_range
    return jax.tree.map(lambda a: a[start:stop], cache)


def decode_embed(cfg: ModelConfig, params: dict, tokens: jax.Array, pos: jax.Array) -> jax.Array:
    _, positions = _decode_positions(pos, tokens.shape[0])
    x = params["embed"].astype(_dtype(cfg))[tokens]
    return x + params["dec_pos"].astype(x.dtype)[positions]


def decode_stage(cfg: ModelConfig, stage_params: dict, hidden: jax.Array, stage_cache: dict, pos: jax.Array):
    """One token step through decoder layers [start, stop): self-attention
    against the stage's KV slice, cross-attention against its cached
    encoder projections.  Empty slices are the identity."""
    if jax.tree.leaves(stage_params["dec_layers"])[0].shape[0] == 0:
        return hidden, stage_cache
    pos, positions = _decode_positions(pos, hidden.shape[0])

    def body(x, xs):
        lp, sk, sv, ck, cv = xs
        x, new_cache, _ = _dec_layer_fn(
            cfg, lp, x, positions, None, self_cache=(sk, sv),
            cross_kv=(ck.astype(x.dtype), cv.astype(x.dtype)), decode_pos=pos,
        )
        return x, (new_cache[0], new_cache[1])

    x, (sk, sv) = jax.lax.scan(
        body, hidden,
        (stage_params["dec_layers"], stage_cache["self_k"],
         stage_cache["self_v"], stage_cache["cross_k"],
         stage_cache["cross_v"]),
    )
    return x, {
        "self_k": sk, "self_v": sv,
        "cross_k": stage_cache["cross_k"], "cross_v": stage_cache["cross_v"],
    }


def decode_unembed(cfg: ModelConfig, params: dict, hidden: jax.Array) -> jax.Array:
    x = apply_norm(cfg, hidden, params.get("dec_norm"))
    return (x[:, -1] @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array, pos: jax.Array):
    x = decode_embed(cfg, params, tokens, pos)
    x, new_cache = decode_stage(
        cfg, slice_params(cfg, params, (0, cfg.n_layers)), x, cache, pos
    )
    return decode_unembed(cfg, params, x), new_cache
