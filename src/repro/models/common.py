"""Shared model building blocks: norms, RoPE, activations, losses.

All parameters are plain dict pytrees; all functions are pure.  Compute
dtype is bf16 (v5e MXU-native) with f32 for norms/softmax/loss accumulation.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical_constraint


def rms_norm(x: jax.Array, scale: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


def layer_norm(
    x: jax.Array,
    scale: Optional[jax.Array],
    bias: Optional[jax.Array],
    eps: float = 1e-5,
) -> jax.Array:
    """LayerNorm; with scale=bias=None this is OLMo's non-parametric LN."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(cfg, x: jax.Array, params: Optional[dict]) -> jax.Array:
    kind = cfg.norm
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"] if params else None)
    if kind == "layernorm":
        return layer_norm(
            x,
            params["scale"] if params else None,
            params.get("bias") if params else None,
        )
    if kind == "nonparam_ln":      # OLMo: no learnable affine
        return layer_norm(x, None, None)
    raise ValueError(kind)


def norm_params(cfg, d: int, key=None):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "nonparam_ln":
        return None
    raise ValueError(cfg.norm)


def norm_axes(cfg):
    if cfg.norm == "rmsnorm":
        return {"scale": ("d_model",)}
    if cfg.norm == "layernorm":
        return {"scale": ("d_model",), "bias": ("d_model",)}
    return None


# ---------------------------------------------------------------- RoPE ----


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jax.Array,              # (B, S, H, hd)
    positions: jax.Array,      # (B, S) int32
    theta: float,
) -> jax.Array:
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


# ---------------------------------------------------------- activations ----


def mlp_act(kind: str, gate: jax.Array, up: Optional[jax.Array]) -> jax.Array:
    """Gated or plain MLP nonlinearity.

    swiglu: silu(gate) * up;  gelu: gelu(gate) (no up);  sq_relu:
    relu(gate)**2 (Nemotron-4's squared ReLU).
    """
    if kind == "swiglu":
        return jax.nn.silu(gate) * up
    if kind == "gelu":
        return jax.nn.gelu(gate)
    if kind == "sq_relu":
        r = jax.nn.relu(gate)
        return r * r
    raise ValueError(kind)


def mlp_is_gated(kind: str) -> bool:
    return kind == "swiglu"


# ---------------------------------------------------------------- loss ----


def chunked_softmax_xent(
    x: jax.Array,              # (B, S, D) final hidden states
    unembed: jax.Array,        # (D, V)
    labels: jax.Array,         # (B, S) int32
    mask: Optional[jax.Array] = None,   # (B, S) 0/1
    chunk: int = 512,
) -> jax.Array:
    """Cross-entropy without materializing (B, S, V) logits at once.

    Scans over sequence chunks; each chunk's logits are recomputed in the
    backward pass (jax.checkpoint), bounding live memory to
    (B, chunk, V / model-shards).
    """
    b, s, d = x.shape
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n_chunks = x.shape[1] // chunk
    xc = x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    mc = mask.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    # Hoist the unembed cast + resharding OUT of the chunk scan: closed over
    # inside the loop body, GSPMD would re-gather it (and reduce its grad)
    # once per chunk -- 8x the necessary bytes under ZeRO rules (SSPerf
    # iteration 3).  "loss_vocab"/"loss_embed_d" resolve per rule-set:
    # vocab-parallel logits under fsdp_tp, replicate-once under zero3.
    w_loss = logical_constraint(
        unembed.astype(x.dtype), "loss_embed_d", "loss_vocab"
    )

    @jax.checkpoint
    def one_chunk(xi, li, mi):
        logits = (xi @ w_loss).astype(jnp.float32)
        logits = logical_constraint(logits, "batch", None, "loss_vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mi
        return jnp.sum(nll), jnp.sum(mi)

    def body(carry, inp):
        tot, cnt = carry
        xi, li, mi = inp
        t, c = one_chunk(xi, li, mi)
        return (tot + t, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (n, d)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    emb = jnp.zeros((n, d), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(angle))
    emb = emb.at[:, 1::2].set(jnp.cos(angle))
    return emb
