"""Mamba2 (SSD -- state-space duality) blocks: chunked quadratic-within-

chunk / linear-across-chunk training form, and O(1)-state recurrent decode.

Follows the discrete SSD formulation of arXiv:2405.21060 (the
``ssd_minimal_discrete`` reference): within a chunk the output is an
attention-like masked product C_i B_j^T with decay weights
exp(A_cum_i - A_cum_j); across chunks a recurrent state (H, P, N) carries.
This is the sub-quadratic path that makes ``long_500k`` runnable for the
ssm/hybrid architectures.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import rms_norm
from repro.parallel.sharding import logical_constraint


# ------------------------------------------------------------- params -----


def ssm_params(cfg: ModelConfig, key) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    n, h, k = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    g = 1  # single B/C group
    conv_dim = din + 2 * g * n
    keys = jax.random.split(key, 5)
    init = jax.nn.initializers.normal(0.02)
    return {
        # order: [z (din), x (din), B (g*n), C (g*n), dt (h)]
        "in_proj": init(keys[0], (d, 2 * din + 2 * g * n + h), jnp.float32),
        "conv_w": init(keys[1], (k, conv_dim), jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.zeros((din,), jnp.float32),
        "out_proj": init(keys[2], (din, d), jnp.float32),
    }


def ssm_axes(cfg: ModelConfig) -> dict:
    return {
        "in_proj": ("embed_d", "ff"),
        "conv_w": ("conv", "ff"),
        "conv_b": ("ff",),
        "dt_bias": ("heads",),
        "a_log": ("heads",),
        "d_skip": ("heads",),
        "norm": ("ff",),
        "out_proj": ("ff", "embed_d"),
    }


# --------------------------------------------------------------- SSD ------


def _ssd_chunked(
    x: jax.Array,      # (B, S, H, P) -- already dt-scaled
    a: jax.Array,      # (B, S, H)    -- log decay per step (A * dt, <= 0)
    bmat: jax.Array,   # (B, S, N)
    cmat: jax.Array,   # (B, S, N)
    chunk: int,
    h0: Optional[jax.Array] = None,   # (B, H, P, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q
    xc = x.reshape(b, nc, q, h, p)
    ac = a.reshape(b, nc, q, h)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)

    a_cum = jnp.cumsum(ac, axis=2)                       # (B, nc, Q, H)
    a_tot = a_cum[:, :, -1]                              # (B, nc, H)

    # --- intra-chunk (quadratic within chunk) ---
    att = jnp.einsum("bcin,bcjn->bcij", cc, bc,
                     preferred_element_type=jnp.float32)  # (B,nc,Q,Q)
    decay = jnp.exp(a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :])  # (B,nc,Q,Q,H)
    ii = jnp.arange(q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    w = jnp.where(causal, att[..., None] * decay, 0.0)
    w = logical_constraint(w, "batch", None, None, None, "heads")
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(x.dtype), xc,
                        preferred_element_type=jnp.float32)

    # --- chunk states ---
    state_decay = jnp.exp(a_tot[:, :, None, :] - a_cum)  # (B,nc,Q,H)
    states = jnp.einsum(
        "bcjn,bcjh,bcjhp->bchpn",
        bc, state_decay.astype(x.dtype), xc,
        preferred_element_type=jnp.float32,
    )                                                     # (B,nc,H,P,N)

    # --- inter-chunk recurrence ---
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def scan_fn(carry, inp):
        st, atot = inp                                    # (B,H,P,N), (B,H)
        new = carry * jnp.exp(atot)[:, :, None, None] + st
        return new, carry                                 # emit state *before* chunk

    states_t = states.swapaxes(0, 1)                      # (nc, B, H, P, N)
    atot_t = a_tot.swapaxes(0, 1).astype(jnp.float32)     # (nc, B, H)
    h_final, h_prev = jax.lax.scan(scan_fn, h0, (states_t, atot_t))
    h_prev = h_prev.swapaxes(0, 1)                        # (B, nc, H, P, N)

    # --- inter-chunk contribution ---
    out_decay = jnp.exp(a_cum)                            # (B,nc,Q,H)
    y_off = jnp.einsum(
        "bcin,bcih,bchpn->bcihp",
        cc, out_decay.astype(x.dtype), h_prev.astype(x.dtype),
        preferred_element_type=jnp.float32,
    )

    y = (y_diag + y_off).reshape(b, nc * q, h, p)[:, :s]
    return y.astype(x.dtype), h_final


def _depthwise_conv(
    u: jax.Array,        # (B, S, C)
    w: jax.Array,        # (K, C)
    bias: jax.Array,     # (C,)
) -> jax.Array:
    k = w.shape[0]
    up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    y = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(k):
        y = y + up[:, i : i + u.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (y + bias.astype(jnp.float32)).astype(u.dtype)


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :din]
    xbc = proj[..., din : din + din + 2 * n]
    dt = proj[..., din + din + 2 * n :]
    return z, xbc, dt


def ssm_apply(
    cfg: ModelConfig,
    p: dict,
    x_in: jax.Array,                       # (B, S, D)
    state: Optional[Tuple[jax.Array, jax.Array]] = None,  # (conv (B,K-1,Cc), ssm (B,H,P,N))
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Full-sequence (chunked SSD) Mamba2 block.

    With ``state`` given, also returns the updated (conv, ssm) state for
    streaming prefill -> decode handoff.
    """
    b, s, _ = x_in.shape
    din, n, heads, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dt_type = x_in.dtype

    proj = x_in @ p["in_proj"].astype(dt_type)
    z, xbc_raw, dt = _split_proj(cfg, proj)
    xbc = _depthwise_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    xbc = logical_constraint(xbc, "batch", None, "ff")
    xs = xbc[..., :din].reshape(b, s, heads, pdim)
    bmat = xbc[..., din : din + n]
    cmat = xbc[..., din + n :]

    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = -jnp.exp(p["a_log"])                                        # (H,)
    a_dt = a * dt_f                                                 # (B,S,H)
    x_dt = xs * dt_f[..., None].astype(dt_type)

    h0 = state[1] if state is not None else None
    y, h_final = _ssd_chunked(x_dt, a_dt, bmat, cmat, cfg.ssm_chunk, h0)
    y = y + xs * p["d_skip"].astype(dt_type)[None, None, :, None]
    y = y.reshape(b, s, din)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_type), p["norm"])
    out = y @ p["out_proj"].astype(dt_type)

    new_state = None
    if state is not None:
        k = cfg.ssm_conv
        tail = xbc_raw[:, -(k - 1):] if s >= k - 1 else jnp.concatenate(
            [state[0][:, s:], xbc_raw], axis=1
        )
        new_state = (tail.astype(state[0].dtype), h_final)
    return out, new_state


def ssm_decode_step(
    cfg: ModelConfig,
    p: dict,
    x_in: jax.Array,                 # (B, 1, D)
    state: Tuple[jax.Array, jax.Array],
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """O(1) recurrent update: h' = h * exp(a*dt) + dt*x (x) B;  y = C.h' + D*x."""
    b = x_in.shape[0]
    din, n, heads, pdim, k = (
        cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv
    )
    dt_type = x_in.dtype
    conv_state, h = state

    proj = x_in[:, 0] @ p["in_proj"].astype(dt_type)                # (B, ...)
    z, xbc_new, dt = _split_proj(cfg, proj[:, None, :])
    z, xbc_new, dt = z[:, 0], xbc_new[:, 0], dt[:, 0]

    # causal depthwise conv over the rolling (K-1)-deep window
    window = jnp.concatenate([conv_state, xbc_new[:, None, :]], axis=1)  # (B,K,Cc)
    conv = jnp.sum(
        window.astype(jnp.float32) * p["conv_w"][None], axis=1
    ) + p["conv_b"]
    xbc = jax.nn.silu(conv).astype(dt_type)
    xs = xbc[:, :din].reshape(b, heads, pdim)
    bmat = xbc[:, din : din + n]
    cmat = xbc[:, din + n :]

    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(a * dt_f)                                       # (B,H)
    x_dt = xs.astype(jnp.float32) * dt_f[..., None]                 # (B,H,P)
    h_new = h * decay[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", x_dt, bmat.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", h_new, cmat.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, din).astype(dt_type)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_type), p["norm"])
    out = (y @ p["out_proj"].astype(dt_type))[:, None, :]           # (B,1,D)

    new_conv_state = window[:, 1:].astype(conv_state.dtype)
    return out, (new_conv_state, h_new)


def init_ssm_state(cfg: ModelConfig, batch: int):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return (
        jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dt),
        jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    )
