"""Grouped-query attention with streaming-softmax kv-chunking.

One implementation serves every assigned attention variant:
- full causal (starcoder2, olmo, nemotron, internvl2 backbone, whisper dec)
- sliding-window (mixtral, window=4096)
- mixed local:global (gemma3, 5 local : 1 global via per-layer window flags)
- bidirectional (whisper encoder; cross-attention)
- single-token decode against a KV cache (cache length masked by position)

The kv dimension is processed in chunks with a running (max, denom, acc)
softmax -- flash-attention dataflow expressed in lax.scan, which bounds the
score tensor to (B, Sq, H, chunk) and keeps 500k-token caches shardable
along kv_seq.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical_constraint

_NEG = -1e30


def gqa_attention(
    q: jax.Array,                      # (B, Sq, H, hd)
    k: jax.Array,                      # (B, Sk, KV, hd)
    v: jax.Array,                      # (B, Sk, KV, hd)
    *,
    q_positions: Optional[jax.Array] = None,   # (B, Sq) absolute positions
    kv_valid_len: Optional[jax.Array] = None,  # () or (B,) -- # valid cache
                                               # slots (per-lane for batched
                                               # decode at staggered positions)
    causal: bool = True,
    window: Optional[int] = None,              # static sliding window
    window_arr: Optional[jax.Array] = None,    # dynamic per-call window (scalar)
    kv_positions: Optional[jax.Array] = None,  # (Sk,) or (B, Sk) absolute
                                               # position per cache slot (ring
                                               # buffers); negative = never
                                               # written
    chunk: int = 512,
) -> jax.Array:
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    groups = h // kv
    scale = 1.0 / (hd ** 0.5)

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32)[None], (b, sq))

    if sq == 1:
        # Decode fast path: no kv-chunk scan.  The cache stays sharded along
        # kv_seq and GSPMD turns the softmax reductions into the
        # flash-decoding partial-max/partial-sum collectives.
        return _decode_attention(
            q, k, v,
            q_positions=q_positions, kv_valid_len=kv_valid_len,
            causal=causal, window=window, window_arr=window_arr,
            kv_positions=kv_positions,
        )
    assert kv_positions is None, "ring-buffer caches are decode-only"

    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    # (nc, B, chunk, KV, hd) for scan
    kc = k.reshape(b, n_chunks, chunk, kv, hd).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, chunk, kv, hd).swapaxes(0, 1)

    limit = jnp.asarray(sk if kv_valid_len is None else kv_valid_len, jnp.int32)
    if limit.ndim == 1:                       # per-lane valid length (B,)
        limit = limit[:, None, None, None]    # -> broadcast vs (B, Sq, H, C)
    if window_arr is not None:
        win = jnp.asarray(window_arr, jnp.int32)
    elif window is not None:
        win = jnp.asarray(window, jnp.int32)
    else:
        win = jnp.asarray(jnp.iinfo(jnp.int32).max, jnp.int32)

    qf = (q * scale).astype(q.dtype)

    def body(carry, inp):
        m, l, acc = carry
        kci, vci, ci = inp
        # Broadcast kv heads to query heads for this chunk only (bounded
        # memory; avoids materializing repeated K/V for the whole cache).
        k_rep = jnp.repeat(kci, groups, axis=2)          # (B, C, H, hd)
        v_rep = jnp.repeat(vci, groups, axis=2)
        s = jnp.einsum(
            "bqhd,bchd->bqhc", qf, k_rep, preferred_element_type=jnp.float32
        )                                                 # (B, Sq, H, C)
        col = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)          # (C,)
        row = q_positions[:, :, None, None]                            # (B,Sq,1,1)
        colb = col[None, None, None, :]
        valid = colb < limit
        if causal:
            valid &= colb <= row
            valid &= colb > row - win
        s = jnp.where(valid, s, _NEG)
        m_c = jnp.max(s, axis=-1)                         # (B, Sq, H)
        m_new = jnp.maximum(m, m_c)
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])                 # (B, Sq, H, C)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhc,bchd->bqhd", p.astype(v_rep.dtype), v_rep,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, h), _NEG, jnp.float32)
    l0 = jnp.zeros((b, sq, h), jnp.float32)
    a0 = jnp.zeros((b, sq, h, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks, dtype=jnp.int32))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def _decode_attention(
    q, k, v, *, q_positions, kv_valid_len, causal, window, window_arr,
    kv_positions=None,
):
    """Single-query attention over the whole (sharded) cache, grouped GQA

    einsums without materializing repeated K/V.
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    groups = h // kv
    scale = 1.0 / (hd ** 0.5)

    if window_arr is not None:
        win = jnp.asarray(window_arr, jnp.int32)
    elif window is not None:
        win = jnp.asarray(window, jnp.int32)
    else:
        win = jnp.asarray(jnp.iinfo(jnp.int32).max, jnp.int32)
    limit = jnp.asarray(sk if kv_valid_len is None else kv_valid_len, jnp.int32)
    if limit.ndim == 1:                       # per-lane valid length (B,)
        limit = limit[:, None, None, None, None]

    qg = (q * scale).reshape(b, sq, kv, groups, hd)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k, preferred_element_type=jnp.float32)
    if kv_positions is not None:
        # ring buffer: each slot carries its absolute position; negative
        # positions mark never-written slots.  (Sk,) shared or (B, Sk)
        # per-lane (batched decode at staggered positions).
        kvp = kv_positions.astype(jnp.int32)
        if kvp.ndim == 1:
            col = kvp[None, None, None, None, :]
        else:
            col = kvp[:, None, None, None, :]
        valid = col >= 0
    else:
        col = jnp.arange(sk, dtype=jnp.int32)[None, None, None, None, :]
        valid = col < limit
    row = q_positions[:, :, None, None, None]
    if causal:
        valid &= col <= row
        valid &= col > row - win
    s = jnp.where(valid, s, _NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    ctx = jnp.einsum(
        "bqkgs,bskd->bqkgd", (p / jnp.maximum(l, 1e-30)).astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return ctx.reshape(b, sq, h, hd).astype(q.dtype)


# ------------------------------------------------------------ projections --


def attn_params(cfg, key, d_model: Optional[int] = None) -> dict:
    d = d_model or cfg.d_model
    hd = cfg.head_dim
    keys = jax.random.split(key, 4)
    init = jax.nn.initializers.normal(0.02)
    p = {
        "wq": init(keys[0], (d, cfg.n_heads * hd), jnp.float32),
        "wk": init(keys[1], (d, cfg.n_kv_heads * hd), jnp.float32),
        "wv": init(keys[2], (d, cfg.n_kv_heads * hd), jnp.float32),
        "wo": init(keys[3], (cfg.n_heads * hd, d), jnp.float32),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bo"] = jnp.zeros((d,), jnp.float32)
    return p


def attn_axes(cfg) -> dict:
    ax = {
        "wq": ("qkv_d", "heads"),
        "wk": ("qkv_d", "kv_heads"),
        "wv": ("qkv_d", "kv_heads"),
        "wo": ("heads", "qkv_d"),
    }
    if cfg.attn_bias:
        ax.update(
            {"bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",),
             "bo": ("d_model",)}
        )
    return ax


def project_qkv(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    hd = cfg.head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.attn_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    q = logical_constraint(q, "batch", None, "heads", None)
    k = logical_constraint(k, "batch", None, "kv_heads", None)
    v = logical_constraint(v, "batch", None, "kv_heads", None)
    return q, k, v


def project_out(cfg, p: dict, ctx: jax.Array) -> jax.Array:
    b, s, h, hd = ctx.shape
    y = ctx.reshape(b, s, h * hd) @ p["wo"].astype(ctx.dtype)
    if cfg.attn_bias:
        y = y + p["bo"].astype(ctx.dtype)
    return y
