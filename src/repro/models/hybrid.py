"""Zamba2-style hybrid: a Mamba2 backbone with a *shared* attention block.

The real Zamba2 interleaves one shared transformer block (re-used weights)
every ~6 Mamba2 layers.  We structure the stack as
``n_groups = n_layers // hybrid_attn_every`` groups, each = [shared
attention+MLP block] followed by ``hybrid_attn_every`` scanned Mamba2
layers, plus a tail of remaining Mamba2 layers.  The shared block's weights
are closed over the group scan (one copy), matching the weight-sharing that
defines the architecture.

Decode carries: per-group KV caches (the shared block sees different inputs
at each invocation) + per-layer (conv, ssm) states.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import dispatch as kdispatch
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import ssm as ssm_mod
from repro.models.common import apply_norm, apply_rope, chunked_softmax_xent, norm_axes, norm_params
from repro.parallel.sharding import logical_constraint


def _groups(cfg: ModelConfig) -> Tuple[int, int]:
    every = cfg.hybrid_attn_every
    return cfg.n_layers // every, cfg.n_layers % every


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ------------------------------------------------------------- params -----


def _mamba_layer(cfg, key):
    k1, k2 = jax.random.split(key)
    return {"norm": norm_params(cfg, cfg.d_model, k1), "ssm": ssm_mod.ssm_params(cfg, k2)}


def init_params(cfg: ModelConfig, key) -> dict:
    n_groups, tail = _groups(cfg)
    keys = jax.random.split(key, 6)
    init = jax.nn.initializers.normal(0.02)
    gkeys = jax.random.split(keys[3], n_groups * cfg.hybrid_attn_every).reshape(
        n_groups, cfg.hybrid_attn_every, 2
    )
    params = {
        "embed": init(keys[0], (cfg.vocab, cfg.d_model), jnp.float32),
        "final_norm": norm_params(cfg, cfg.d_model, keys[1]),
        "shared": {
            "attn_norm": norm_params(cfg, cfg.d_model, keys[2]),
            "attn": attn.attn_params(cfg, keys[2]),
            "mlp_norm": norm_params(cfg, cfg.d_model, keys[2]),
            "mlp": mlp_mod.mlp_params(cfg, keys[2]),
        },
        "groups": jax.vmap(jax.vmap(lambda k: _mamba_layer(cfg, k)))(gkeys),
    }
    if tail:
        tkeys = jax.random.split(keys[4], tail)
        params["tail"] = jax.vmap(lambda k: _mamba_layer(cfg, k))(jnp.stack(tkeys))
    if not cfg.tie_embeddings:
        params["unembed"] = init(keys[5], (cfg.d_model, cfg.vocab), jnp.float32)
    return params


def param_axes(cfg: ModelConfig) -> dict:
    n_groups, tail = _groups(cfg)
    mamba_ax = {"norm": norm_axes(cfg), "ssm": ssm_mod.ssm_axes(cfg)}
    is_ax_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    group_ax = jax.tree.map(lambda ax: ("layers", "layers") + ax, mamba_ax, is_leaf=is_ax_leaf)
    axes = {
        "embed": ("vocab", "embed_d"),
        "final_norm": norm_axes(cfg),
        "shared": {
            "attn_norm": norm_axes(cfg),
            "attn": attn.attn_axes(cfg),
            "mlp_norm": norm_axes(cfg),
            "mlp": mlp_mod.mlp_axes(cfg),
        },
        "groups": group_ax,
    }
    if tail:
        axes["tail"] = jax.tree.map(lambda ax: ("layers",) + ax, mamba_ax, is_leaf=is_ax_leaf)
    if not cfg.tie_embeddings:
        axes["unembed"] = ("embed_d", "vocab")
    return axes


# ------------------------------------------------------------- forward ----


def _shared_block(
    cfg, sp, x, positions, cache_kv=None, decode_pos=None
):
    # fused decode kernels on the single-token path (rope is unconditional
    # in the hybrid's shared attention block)
    use_kernels = kdispatch.attention_active(cfg, x) and cache_kv is not None
    h = apply_norm(cfg, x, sp.get("attn_norm"))
    if use_kernels:
        q, k, v = kdispatch.decode_qkv(cfg, sp["attn"], h, positions, rope=True)
    else:
        q, k, v = attn.project_qkv(cfg, sp["attn"], h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache_kv is not None:
        ck, cv = cache_kv
        if jnp.ndim(decode_pos) > 0:
            # staggered batched decode: each lane writes at its own pos
            lane = jnp.arange(ck.shape[0])
            ck = ck.at[lane, decode_pos].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[lane, decode_pos].set(v[:, 0].astype(cv.dtype))
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, decode_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, decode_pos, 0, 0))
        new_cache = (ck, cv)
        k, v = ck.astype(x.dtype), cv.astype(x.dtype)
        valid = decode_pos + x.shape[1]
    else:
        valid = None
    if use_kernels:
        x = x + kdispatch.decode_attention(
            cfg, sp["attn"], q, k, v,
            q_positions=positions, kv_valid_len=valid,
        )
    else:
        ctx = attn.gqa_attention(
            q, k, v, q_positions=positions, kv_valid_len=valid, causal=True,
            chunk=cfg.attn_chunk,
        )
        x = x + attn.project_out(cfg, sp["attn"], ctx)
    h2 = apply_norm(cfg, x, sp.get("mlp_norm"))
    if kdispatch.mlp_active(cfg, h2):
        x = x + kdispatch.decode_mlp(cfg, sp["mlp"], h2)
    else:
        x = x + mlp_mod.mlp_apply(cfg, sp["mlp"], h2)
    return x, new_cache


def _mamba_layer_apply(cfg, lp, x, state=None):
    h = apply_norm(cfg, x, lp.get("norm"))
    y, new_state = ssm_mod.ssm_apply(cfg, lp["ssm"], h, state)
    return x + y, new_state


def forward_hidden(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
):
    b, s = tokens.shape
    n_groups, tail = _groups(cfg)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = params["embed"].astype(_dtype(cfg))[tokens]
    x = logical_constraint(x, "batch", "seq", "d_model")

    # Cache-collecting prefill is the sequential path below; this scan is
    # the training/forward path (no caches).
    def group_fn_train(x, gp):
        x, _ = _shared_block(cfg, params["shared"], x, positions)

        def mamba_fn(carry, lp):
            y, _ = _mamba_layer_apply(cfg, lp, carry, None)
            return y, None

        if cfg.remat == "layer":
            mamba_fn = jax.checkpoint(
                mamba_fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        x, _ = jax.lax.scan(mamba_fn, x, gp)
        return x, None

    x, _ = jax.lax.scan(group_fn_train, x, params["groups"])
    if tail:
        def mamba_fn(carry, lp):
            y, _ = _mamba_layer_apply(cfg, lp, carry, None)
            return y, None
        if cfg.remat == "layer":
            mamba_fn = jax.checkpoint(
                mamba_fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        x, _ = jax.lax.scan(mamba_fn, x, params["tail"])
    x = apply_norm(cfg, x, params.get("final_norm"))
    return x


def _unembed_matrix(cfg, params):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def train_loss(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    hidden = forward_hidden(cfg, params, batch["tokens"])
    return chunked_softmax_xent(
        hidden, _unembed_matrix(cfg, params), batch["labels"], batch.get("mask")
    )


# ------------------------------------------------------------- serving ----


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    n_groups, tail = _groups(cfg)
    kv_shape = (n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    dt = _dtype(cfg)
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    n_mamba = cfg.n_layers
    return {
        "attn_k": jnp.zeros(kv_shape, dt),
        "attn_v": jnp.zeros(kv_shape, dt),
        "conv": jnp.zeros((n_mamba, batch, cfg.ssm_conv - 1, conv_dim), dt),
        "ssm": jnp.zeros(
            (n_mamba, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
    }


def cache_axes(cfg: ModelConfig):
    return {
        "attn_k": ("layers", "batch", "kv_seq", "kv_heads", None),
        "attn_v": ("layers", "batch", "kv_seq", "kv_heads", None),
        "conv": ("layers", "batch", None, "ff"),
        "ssm": ("layers", "batch", "heads", None, None),
    }


def _mamba_param_slices(cfg, params):
    """Yield per-layer mamba params in inference order (groups then tail)."""
    n_groups, tail = _groups(cfg)
    every = cfg.hybrid_attn_every
    flat = jax.tree.map(
        lambda a: a.reshape((n_groups * every,) + a.shape[2:]), params["groups"]
    )
    if tail:
        flat = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), flat, params["tail"]
        )
    return flat


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array):
    """Sequential-over-layers prefill that also fills all caches."""
    b, s = tokens.shape
    n_groups, tail = _groups(cfg)
    every = cfg.hybrid_attn_every
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = params["embed"].astype(_dtype(cfg))[tokens]
    cache = init_cache(cfg, b, s)
    mamba_flat = _mamba_param_slices(cfg, params)

    attn_ks, attn_vs, convs, ssms = [], [], [], []
    li = 0
    for g in range(n_groups):
        x, kv = _shared_block(
            cfg, params["shared"], x, positions,
            cache_kv=(cache["attn_k"][g], cache["attn_v"][g]), decode_pos=0,
        )
        attn_ks.append(kv[0])
        attn_vs.append(kv[1])
        for i in range(every):
            lp = jax.tree.map(lambda a: a[li], mamba_flat)
            x, st = _mamba_layer_apply(
                cfg, lp, x, ssm_mod.init_ssm_state(cfg, b)
            )
            convs.append(st[0])
            ssms.append(st[1])
            li += 1
    for i in range(tail):
        lp = jax.tree.map(lambda a: a[li], mamba_flat)
        x, st = _mamba_layer_apply(cfg, lp, x, ssm_mod.init_ssm_state(cfg, b))
        convs.append(st[0])
        ssms.append(st[1])
        li += 1
    x = apply_norm(cfg, x, params.get("final_norm"))
    logits = (x[:, -1] @ _unembed_matrix(cfg, params).astype(x.dtype)).astype(jnp.float32)
    new_cache = {
        "attn_k": jnp.stack(attn_ks),
        "attn_v": jnp.stack(attn_vs),
        "conv": jnp.stack(convs).astype(cache["conv"].dtype),
        "ssm": jnp.stack(ssms),
    }
    return logits, new_cache


# -------------------------------------------------- layer-sliced decode ---
# A stage boundary may only fall on a *group* boundary: the shared
# attention block runs immediately before mamba layer g*every, so cutting
# mid-group would strand a group's KV cache on one stage and its mamba
# layers on another.  The tail (layers past the last group) rides with
# whichever stage owns the final boundary.


def decode_slice_points(cfg: ModelConfig) -> Tuple[int, ...]:
    n_groups, tail = _groups(cfg)
    every = cfg.hybrid_attn_every
    pts = list(range(0, n_groups * every + 1, every))
    if tail:
        pts.append(cfg.n_layers)
    return tuple(pts)


def _group_range(cfg: ModelConfig, start: int, stop: int) -> Tuple[int, int]:
    n_groups, _ = _groups(cfg)
    every = cfg.hybrid_attn_every
    if start not in decode_slice_points(cfg) or stop not in decode_slice_points(cfg):
        raise ValueError(
            f"hybrid layer range ({start}, {stop}) is not group-aligned; "
            f"valid slice points: {decode_slice_points(cfg)}"
        )
    g0 = min(start, n_groups * every) // every
    g1 = min(stop, n_groups * every) // every
    return g0, g1


def slice_params(cfg: ModelConfig, params: dict, layer_range) -> dict:
    """Stage-local decode params for mamba layers [start, stop).

    The shared attention block's weights are *replicated* into every
    stage whose range contains a group boundary (weight sharing is the
    architecture; the stage pipeline pays its residency per stage)."""
    start, stop = layer_range
    _group_range(cfg, start, stop)   # validates alignment
    flat = _mamba_param_slices(cfg, params)
    return {
        "shared": params["shared"],
        "mamba": jax.tree.map(lambda a: a[start:stop], flat),
    }


def slice_cache(cfg: ModelConfig, cache, layer_range):
    start, stop = layer_range
    g0, g1 = _group_range(cfg, start, stop)
    return {
        "attn_k": cache["attn_k"][g0:g1],
        "attn_v": cache["attn_v"][g0:g1],
        "conv": cache["conv"][start:stop],
        "ssm": cache["ssm"][start:stop],
    }


def decode_embed(cfg: ModelConfig, params: dict, tokens: jax.Array, pos: jax.Array) -> jax.Array:
    del pos
    return params["embed"].astype(_dtype(cfg))[tokens]


def decode_stage(cfg: ModelConfig, stage_params: dict, hidden: jax.Array, stage_cache: dict, pos: jax.Array):
    """One token step through a group-aligned slice.  The slice's group
    structure is recovered from the cache shapes: the first
    ``n_groups_local * every`` mamba layers are grouped (each group led
    by the shared attention block over its KV lane), the remainder is
    tail."""
    b = hidden.shape[0]
    every = cfg.hybrid_attn_every
    pos = jnp.asarray(pos, jnp.int32)
    positions = (
        jnp.broadcast_to(pos, (b, 1)) if pos.ndim == 0 else pos[:, None]
    ).astype(jnp.int32)
    n_g = stage_cache["attn_k"].shape[0]
    n_m = stage_cache["conv"].shape[0]
    mamba = stage_params["mamba"]
    x = hidden

    def mamba_fn(carry, inner):
        lp, cst, sst = inner
        h = apply_norm(cfg, carry, lp.get("norm"))
        y, new_state = ssm_mod.ssm_decode_step(cfg, lp["ssm"], h, (cst, sst))
        return carry + y, new_state

    if n_g:
        group_mamba = jax.tree.map(
            lambda a: a[: n_g * every].reshape((n_g, every) + a.shape[1:]),
            mamba,
        )
        conv_groups = stage_cache["conv"][: n_g * every].reshape(
            (n_g, every) + stage_cache["conv"].shape[1:]
        )
        ssm_groups = stage_cache["ssm"][: n_g * every].reshape(
            (n_g, every) + stage_cache["ssm"].shape[1:]
        )

        def group_fn(x, xs):
            gp, kc, vc, conv_st, ssm_st = xs
            x, kv = _shared_block(
                cfg, stage_params["shared"], x, positions,
                cache_kv=(kc, vc), decode_pos=pos,
            )
            x, (new_conv, new_ssm) = jax.lax.scan(
                mamba_fn, x, (gp, conv_st, ssm_st)
            )
            return x, (kv[0], kv[1], new_conv, new_ssm)

        x, (ks, vs, convs, ssms) = jax.lax.scan(
            group_fn, x,
            (group_mamba, stage_cache["attn_k"], stage_cache["attn_v"],
             conv_groups, ssm_groups),
        )
        new_conv = convs.reshape((-1,) + convs.shape[2:])
        new_ssm = ssms.reshape((-1,) + ssms.shape[2:])
        ks_out, vs_out = ks, vs
    else:
        ks_out, vs_out = stage_cache["attn_k"], stage_cache["attn_v"]
        new_conv = stage_cache["conv"][:0]
        new_ssm = stage_cache["ssm"][:0]

    n_tail = n_m - n_g * every
    if n_tail:
        tail_params = jax.tree.map(lambda a: a[n_g * every :], mamba)
        x, (tconv, tssm) = jax.lax.scan(
            mamba_fn, x,
            (tail_params, stage_cache["conv"][n_g * every :],
             stage_cache["ssm"][n_g * every :]),
        )
        new_conv = jnp.concatenate([new_conv, tconv], axis=0)
        new_ssm = jnp.concatenate([new_ssm, tssm], axis=0)
    return x, {
        "attn_k": ks_out, "attn_v": vs_out,
        "conv": new_conv, "ssm": new_ssm,
    }


def decode_unembed(cfg: ModelConfig, params: dict, hidden: jax.Array) -> jax.Array:
    x = apply_norm(cfg, hidden, params.get("final_norm"))
    return (x[:, -1] @ _unembed_matrix(cfg, params).astype(x.dtype)).astype(jnp.float32)


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array, pos: jax.Array):
    x = decode_embed(cfg, params, tokens, pos)
    x, new_cache = decode_stage(
        cfg, slice_params(cfg, params, (0, cfg.n_layers)), x, cache, pos
    )
    return decode_unembed(cfg, params, x), new_cache
