"""Decoder-only transformer LM: dense, MoE, and VLM-stub variants.

Layers are scanned (stacked params) with per-layer remat, so the lowered
HLO stays compact for 48-layer production configs and activation memory is
bounded by one layer boundary per layer (sequence-parallel sharded).

Supports: GQA + RoPE, sliding-window and local:global attention schedules,
MoE blocks, learned positions, tied embeddings, a stubbed vision front-end
(precomputed patch embeddings overwrite the first ``vision_patches`` token
slots -- the assignment treats modality front-ends as stubs).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import dispatch as kdispatch
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (
    apply_norm,
    apply_rope,
    chunked_softmax_xent,
    norm_axes,
    norm_params,
)
from repro.parallel.sharding import logical_constraint

_BIG_WINDOW = jnp.iinfo(jnp.int32).max


# ------------------------------------------------ int8 KV cache (pow2) ----
# The paper's INT8 + power-of-two-scale arithmetic applied to the decode
# state: K/V are stored as int8 payloads with one int8 exponent per
# (token, kv-head); dequantization on read is a shift-scale, exactly the
# PU's scale/shift module.  Halves decode HBM traffic (SSPerf).


def kv_quantize(x: jax.Array):
    """(..., hd) float -> (int8 payload, int8 exponent over last dim)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    e = jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30) / 127.0))
    e = jnp.clip(e, -126, 126)
    q = jnp.clip(
        jnp.round(xf / jnp.exp2(e)[..., None]), -128, 127
    ).astype(jnp.int8)
    return q, e.astype(jnp.int8)


def kv_dequantize(q: jax.Array, e: jax.Array, dt) -> jax.Array:
    return (q.astype(jnp.float32) * jnp.exp2(e.astype(jnp.float32))[..., None]).astype(dt)


# ------------------------------------------------------------- params -----


def _layer_params(cfg: ModelConfig, key) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "attn_norm": norm_params(cfg, cfg.d_model, k1),
        "attn": attn.attn_params(cfg, k2),
        "mlp_norm": norm_params(cfg, cfg.d_model, k3),
    }
    if cfg.is_moe:
        p["moe"] = mlp_mod.moe_params(cfg, k4)
    else:
        p["mlp"] = mlp_mod.mlp_params(cfg, k4)
    # None (non-parametric norms) are invalid scan xs; drop them.
    return {k: v for k, v in p.items() if v is not None}


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 4 + cfg.n_layers)
    init = jax.nn.initializers.normal(0.02)
    params: Dict[str, Any] = {
        "embed": init(keys[0], (cfg.vocab, cfg.d_model), jnp.float32),
        "final_norm": norm_params(cfg, cfg.d_model, keys[1]),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init(keys[2], (cfg.d_model, cfg.vocab), jnp.float32)
    if cfg.pos_embed == "learned":
        params["pos_embed"] = init(keys[3], (cfg.max_position, cfg.d_model), jnp.float32)
    layer_keys = jnp.stack(keys[4:])
    params["layers"] = jax.vmap(lambda k: _layer_params(cfg, k))(layer_keys)
    return {k: v for k, v in params.items() if v is not None}


def param_axes(cfg: ModelConfig) -> dict:
    layer_ax = {
        "attn_norm": norm_axes(cfg),
        "attn": attn.attn_axes(cfg),
        "mlp_norm": norm_axes(cfg),
    }
    if cfg.is_moe:
        layer_ax["moe"] = mlp_mod.moe_axes(cfg)
    else:
        layer_ax["mlp"] = mlp_mod.mlp_axes(cfg)
    layer_ax = {k: v for k, v in layer_ax.items() if v is not None}
    # prepend the stacked 'layers' axis
    layer_ax = jax.tree.map(
        lambda ax: ("layers",) + ax,
        layer_ax,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )
    axes: Dict[str, Any] = {
        "embed": ("vocab", "embed_d"),
        "final_norm": norm_axes(cfg),
        "layers": layer_ax,
    }
    if not cfg.tie_embeddings:
        axes["unembed"] = ("embed_d", "vocab")
    if cfg.pos_embed == "learned":
        axes["pos_embed"] = (None, "embed_d")
    return {k: v for k, v in axes.items() if v is not None}


def layer_windows(cfg: ModelConfig) -> jax.Array:
    """Per-layer effective attention window (int32, stacked for scan)."""
    idx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    if cfg.global_every:
        is_global = (idx + 1) % cfg.global_every == 0
        return jnp.where(is_global, _BIG_WINDOW, cfg.window or _BIG_WINDOW)
    if cfg.window:
        return jnp.full((cfg.n_layers,), cfg.window, jnp.int32)
    return jnp.full((cfg.n_layers,), _BIG_WINDOW, jnp.int32)


# ------------------------------------------------------------- forward ----


def _layer_fn(
    cfg: ModelConfig,
    x: jax.Array,                 # (B, S, D)
    lp: dict,
    window: jax.Array,            # () int32
    positions: jax.Array,         # (B, S)
    cache_kv: Optional[Tuple[jax.Array, jax.Array]],   # (B, Smax, KV, hd) x2
    decode_pos: Optional[jax.Array],                   # () or (B,) int32
    return_kv: bool,
):
    dt = x.dtype
    # fused decode kernels (kernels/decode.py) take over the single-token
    # hot path when cfg.decode_kernels is set; cache write stays XLA.
    use_kernels = kdispatch.attention_active(cfg, x) and cache_kv is not None
    h = apply_norm(cfg, x, lp.get("attn_norm"))
    if use_kernels:
        q, k, v = kdispatch.decode_qkv(
            cfg, lp["attn"], h, positions, rope=cfg.pos_embed == "rope"
        )
    else:
        q, k, v = attn.project_qkv(cfg, lp["attn"], h)
        if cfg.pos_embed == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    kv_positions = None
    if cache_kv is not None:
        cache_len = cache_kv[0].shape[1]
        # ring buffer (pure-SWA): write round-robin; slot s holds absolute
        # position pos - ((pos - s) mod L); never-written slots come out
        # negative and are masked in attention.
        ring = bool(cfg.kv_ring and cfg.window and not cfg.global_every)
        # decode_pos may be () (all lanes aligned) or (B,) (staggered
        # batched decode: each lane writes its own cache position)
        per_lane = jnp.ndim(decode_pos) > 0
        write_pos = decode_pos % cache_len if ring else decode_pos
        if ring:
            slots = jnp.arange(cache_len, dtype=jnp.int32)
            if per_lane:
                kv_positions = decode_pos[:, None] - (
                    (decode_pos[:, None] - slots[None, :]) % cache_len
                )
            else:
                kv_positions = decode_pos - ((decode_pos - slots) % cache_len)

        def cwrite(buf, new):
            new = new.astype(buf.dtype)
            if per_lane:
                # one-token decode: scatter each lane's row at its own pos
                return buf.at[jnp.arange(buf.shape[0]), write_pos].set(new[:, 0])
            start = (0, write_pos) + (0,) * (buf.ndim - 2)
            return jax.lax.dynamic_update_slice(buf, new, start)

        if cfg.kv_quant:
            ck, cv, ke, ve = cache_kv
            kq, ke_new = kv_quantize(k)
            vq, ve_new = kv_quantize(v)
            ck, cv = cwrite(ck, kq), cwrite(cv, vq)
            ke, ve = cwrite(ke, ke_new), cwrite(ve, ve_new)
            new_cache = (ck, cv, ke, ve)
            k_att = kv_dequantize(ck, ke, dt)
            v_att = kv_dequantize(cv, ve, dt)
        else:
            ck, cv = cache_kv
            ck, cv = cwrite(ck, k), cwrite(cv, v)
            new_cache = (ck, cv)
            k_att, v_att = ck, cv
        valid = decode_pos + x.shape[1]
    else:
        k_att, v_att = k, v
        valid = None

    if use_kernels:
        x = x + kdispatch.decode_attention(
            cfg, lp["attn"], q, k_att.astype(dt), v_att.astype(dt),
            q_positions=positions,
            kv_valid_len=valid,
            window_arr=window,
            kv_positions=kv_positions,
        )
    else:
        ctx = attn.gqa_attention(
            q, k_att.astype(dt), v_att.astype(dt),
            q_positions=positions,
            kv_valid_len=valid,
            causal=True,
            window_arr=window,
            kv_positions=kv_positions,
            chunk=cfg.attn_chunk,
        )
        x = x + attn.project_out(cfg, lp["attn"], ctx)
    x = logical_constraint(x, "batch", "seq", "d_model")

    if return_kv and cfg.kv_quant:
        kq, ke_out = kv_quantize(k)
        vq, ve_out = kv_quantize(v)
        kv_quant_out = (kq, vq, ke_out, ve_out)

    h2 = apply_norm(cfg, x, lp.get("mlp_norm"))
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        y, aux = mlp_mod.moe_apply(cfg, lp["moe"], h2)
    elif kdispatch.mlp_active(cfg, h2):
        y = kdispatch.decode_mlp(cfg, lp["mlp"], h2)
    else:
        y = mlp_mod.mlp_apply(cfg, lp["mlp"], h2)
    x = x + y
    x = logical_constraint(x, "batch", "seq", "d_model")
    if not return_kv:
        kv_out = None
    elif cfg.kv_quant:
        kv_out = kv_quant_out
    else:
        kv_out = (k, v)
    return x, aux, new_cache, kv_out


def _embed(cfg, params, tokens, patch_embeds, positions):
    x = params["embed"].astype(_dtype(cfg))[tokens]
    if cfg.family == "vlm" and patch_embeds is not None:
        pe = patch_embeds.astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
    if cfg.pos_embed == "learned":
        x = x + params["pos_embed"].astype(x.dtype)[positions]
    return x


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def forward_hidden(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,                 # (B, S)
    patch_embeds: Optional[jax.Array] = None,
    return_cache: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Full-sequence pass -> (hidden (B,S,D), moe aux loss, optional kv cache)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed(cfg, params, tokens, patch_embeds, positions)
    x = logical_constraint(x, "batch", "seq", "d_model")
    windows = layer_windows(cfg)

    def body(carry, xs):
        x, aux_sum = carry
        lp, win = xs
        x, aux, _, kv = _layer_fn(
            cfg, x, lp, win, positions, None, None, return_kv=return_cache
        )
        return (x, aux_sum + aux), kv

    if cfg.remat == "layer":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    (x, aux), kvs = jax.lax.scan(body, (x, 0.0), (params["layers"], windows))
    x = apply_norm(cfg, x, params.get("final_norm"))
    cache = None
    if return_cache:
        cache = tuple(kvs)   # (L, B, S, KV, hd) payloads (+ exps if quant)
    return x, aux / cfg.n_layers, cache


def _unembed_matrix(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def train_loss(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    hidden, aux, _ = forward_hidden(
        cfg, params, batch["tokens"], batch.get("patch_embeds")
    )
    loss = chunked_softmax_xent(
        hidden, _unembed_matrix(cfg, params), batch["labels"], batch.get("mask")
    )
    return loss + 0.01 * aux


def logits_last(cfg: ModelConfig, params: dict, hidden: jax.Array) -> jax.Array:
    """(B, S, D) -> logits of the final position (B, V)."""
    h_last = hidden[:, -1]
    return (h_last @ _unembed_matrix(cfg, params).astype(hidden.dtype)).astype(
        jnp.float32
    )


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    patch_embeds: Optional[jax.Array] = None,
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-context pass -> (last-token logits (B,V), kv cache (L,B,S,KV,hd) x2).

    ``lengths`` (B,) enables bucketed batched prefill: rows are true
    prompts right-padded to a shared bucket length; logits are gathered
    at each row's last *real* token (``lengths - 1``).  The cache keeps
    the padded tail -- causal masking hides those slots from every query
    at position < length, and batched decode overwrites slot ``length``
    (then length+1, ...) before it ever becomes visible, so the tail is
    never attended to.

    Ring configs (kv_ring + pure SWA) return the ring layout: the last
    ``window`` tokens placed at slots ``position % window``; the ring
    re-layout is whole-sequence, so it composes with ``lengths=None``
    only (the serving engine admits ring configs lane-isolated).
    """
    hidden, _, cache = forward_hidden(
        cfg, params, tokens, patch_embeds, return_cache=True
    )
    if cfg.kv_ring and cfg.window and not cfg.global_every:
        if lengths is not None:
            raise ValueError(
                "bucketed prefill (lengths) is unsupported for kv_ring "
                "configs: the ring re-layout is a whole-sequence shift"
            )
        s = tokens.shape[1]
        w = min(s, cfg.window)
        ring_len = cfg.window if s >= cfg.window else s

        def conv(kv_full):
            # seq axis is 2: (L, B, S, KV[, hd])
            if s <= ring_len:
                return kv_full
            last = jax.lax.slice_in_dim(kv_full, s - ring_len, s, axis=2)
            slots = (jnp.arange(s - ring_len, s) % ring_len)
            out = jnp.zeros(
                kv_full.shape[:2] + (ring_len,) + kv_full.shape[3:],
                kv_full.dtype,
            )
            return out.at[:, :, slots].set(last)

        cache = tuple(conv(c) for c in cache)
    if lengths is not None:
        b = tokens.shape[0]
        h_last = hidden[jnp.arange(b), lengths - 1]
        logits = (
            h_last @ _unembed_matrix(cfg, params).astype(hidden.dtype)
        ).astype(jnp.float32)
        return logits, cache
    return logits_last(cfg, params, hidden), cache


def _ring_len(cfg: ModelConfig, max_len: int) -> int:
    """Effective cache length: the attention window for pure-SWA models."""
    if cfg.kv_ring and cfg.window and not cfg.global_every:
        return min(max_len, cfg.window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    max_len = _ring_len(cfg, max_len)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_quant:
        # int8 payloads + per-(token, kv-head) power-of-two exponents:
        # the paper's PU arithmetic applied to the decode state.
        eshape = shape[:-1]
        return (
            jnp.zeros(shape, jnp.int8),
            jnp.zeros(shape, jnp.int8),
            jnp.full(eshape, -126, jnp.int8),
            jnp.full(eshape, -126, jnp.int8),
        )
    return (jnp.zeros(shape, _dtype(cfg)), jnp.zeros(shape, _dtype(cfg)))


def cache_axes(cfg: ModelConfig):
    ax = ("layers", "batch", "kv_seq", "kv_heads", None)
    if cfg.kv_quant:
        ex = ("layers", "batch", "kv_seq", "kv_heads")
        return (ax, ax, ex, ex)
    return (ax, ax)


# -------------------------------------------------- layer-sliced decode ---
# The stage pipeline (runtime.stage_decode) runs each pipeline stage's
# contiguous layer range on its own submesh: decode_step decomposes into
# decode_embed (first stage) -> decode_stage per layer slice -> decode_unembed
# (last stage), and the fused single-PU loop is exactly the one-stage
# composition, so staged and fused serving share every per-layer op.


def _decode_positions(pos: jax.Array, b: int) -> Tuple[jax.Array, jax.Array]:
    """Normalize pos to (int32 pos, (B, 1) positions) for one-token decode."""
    pos = jnp.asarray(pos, jnp.int32)
    positions = (
        jnp.broadcast_to(pos, (b, 1)) if pos.ndim == 0 else pos[:, None]
    ).astype(jnp.int32)
    return pos, positions


def decode_slice_points(cfg: ModelConfig) -> Tuple[int, ...]:
    """Layer indices where a stage boundary may fall (every layer)."""
    return tuple(range(cfg.n_layers + 1))


def slice_params(cfg: ModelConfig, params: dict, layer_range) -> dict:
    """Stage-local decode params for layers [start, stop)."""
    start, stop = layer_range
    return {
        "layers": jax.tree.map(lambda a: a[start:stop], params["layers"]),
        "windows": layer_windows(cfg)[start:stop],
    }


def slice_cache(cfg: ModelConfig, cache, layer_range):
    """Stage-local KV cache lanes for layers [start, stop)."""
    start, stop = layer_range
    return jax.tree.map(lambda a: a[start:stop], cache)


def decode_embed(cfg: ModelConfig, params: dict, tokens: jax.Array, pos: jax.Array) -> jax.Array:
    """First-stage half of the embed/unembed split: token -> hidden (B, 1, D)."""
    _, positions = _decode_positions(pos, tokens.shape[0])
    return _embed(cfg, params, tokens, None, positions)


def decode_stage(
    cfg: ModelConfig,
    stage_params: dict,
    hidden: jax.Array,               # (B, 1, D)
    stage_cache,
    pos: jax.Array,                  # () or (B,) int32 -- write position
):
    """One token step through a contiguous layer slice -> (hidden, cache).

    ``stage_params``/``stage_cache`` come from :func:`slice_params` /
    :func:`slice_cache`; an empty slice is the identity (the hidden state
    passes through untouched)."""
    if stage_params["layers"] and jax.tree.leaves(stage_params["layers"])[0].shape[0] == 0:
        return hidden, stage_cache
    pos, positions = _decode_positions(pos, hidden.shape[0])

    def body(x, xs):
        lp, win = xs[0], xs[1]
        x, _, new_cache, _ = _layer_fn(
            cfg, x, lp, win, positions, tuple(xs[2:]), pos, return_kv=False
        )
        return x, new_cache

    x, new_cache = jax.lax.scan(
        body, hidden,
        (stage_params["layers"], stage_params["windows"]) + tuple(stage_cache),
    )
    return x, tuple(new_cache)


def decode_unembed(cfg: ModelConfig, params: dict, hidden: jax.Array) -> jax.Array:
    """Last-stage half of the split: hidden (B, 1, D) -> logits (B, V)."""
    x = apply_norm(cfg, hidden, params.get("final_norm"))
    return logits_last(cfg, params, x)


def decode_step(
    cfg: ModelConfig,
    params: dict,
    cache: Tuple[jax.Array, jax.Array],
    tokens: jax.Array,               # (B, 1)
    pos: jax.Array,                  # () or (B,) int32 -- write position
                                     # (per-lane when slots are staggered)
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One token step against a KV cache -> (logits (B,V), new cache).

    Exactly the one-stage composition of the sliced entry points, so the
    fused loop and the stage pipeline run identical per-layer math."""
    x = decode_embed(cfg, params, tokens, pos)
    x, new_cache = decode_stage(
        cfg, slice_params(cfg, params, (0, cfg.n_layers)), x, cache, pos
    )
    return decode_unembed(cfg, params, x), new_cache
