"""Model substrate: every assigned architecture family in pure JAX.

- transformer.py : decoder-only LMs (dense / MoE / VLM-stub front-end)
- ssm.py         : Mamba2 (SSD, chunked + recurrent decode)
- hybrid.py      : Zamba2 (Mamba2 backbone + shared attention block)
- encdec.py      : Whisper-style encoder-decoder (stub audio front-end)
- resnet.py      : the paper's own INT8 ResNet-18/50 evaluation models
"""
