"""Scan-aware FLOP counting on the closed jaxpr.

XLA:CPU's ``compiled.cost_analysis()`` counts a while-loop body ONCE
(verified empirically: a 16-layer scanned transformer reports ~1/16 of the
dot FLOPs), which would poison every roofline number for scanned models.
The jaxpr still has static scan lengths, so we walk it recursively and
multiply: exact for dot_general/conv, 1 flop/element for elementwise.

Shapes in the jaxpr are GLOBAL (pre-GSPMD); divide by the mesh size for
per-device figures.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax._src import core as jcore


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _dot_flops(eqn) -> float:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = _prod(lhs.shape[i] for i in lc)
    return 2.0 * _prod(out.shape) * k


_ELEMWISE_COST = {
    "exp": 8.0, "log": 8.0, "tanh": 8.0, "logistic": 8.0, "erf": 8.0,
    "rsqrt": 4.0, "sqrt": 4.0, "sin": 8.0, "cos": 8.0, "pow": 8.0,
    "integer_pow": 2.0, "div": 2.0,
}


def _as_jaxpr(x):
    return x.jaxpr if isinstance(x, jcore.ClosedJaxpr) else x


def _sub_jaxprs(params: dict):
    for v in params.values():
        if isinstance(v, (jcore.ClosedJaxpr, jcore.Jaxpr)):
            yield _as_jaxpr(v)
        elif isinstance(v, (tuple, list)):
            for e in v:
                if isinstance(e, (jcore.ClosedJaxpr, jcore.Jaxpr)):
                    yield _as_jaxpr(e)


def count_flops(jaxpr: jcore.Jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_flops(eqn)
        elif name in ("conv_general_dilated",):
            out = eqn.outvars[0].aval
            rhs = eqn.invars[1].aval
            # flops = 2 * out_elems * (kernel spatial * in_channels)
            total += 2.0 * _prod(out.shape) * _prod(rhs.shape[:-1])
        elif name == "scan":
            body = _as_jaxpr(eqn.params["jaxpr"])
            total += eqn.params["length"] * count_flops(body)
        elif name == "shard_map":
            # the body jaxpr carries PER-SHARD shapes: multiply by the mesh
            # size so the count stays global (each shard runs the body once)
            body = _as_jaxpr(eqn.params["jaxpr"])
            size = getattr(eqn.params.get("mesh"), "size", 1) or 1
            total += size * count_flops(body)
        elif name == "while":
            # we never emit unbounded whiles; count body once, conservatively
            total += count_flops(_as_jaxpr(eqn.params["body_jaxpr"]))
        elif name == "cond":
            total += max(count_flops(_as_jaxpr(b)) for b in eqn.params["branches"])
        else:
            subs = list(_sub_jaxprs(eqn.params))
            if subs:
                # call-like primitive (jit/pjit/remat/custom_vjp/...)
                for s in subs:
                    total += count_flops(s)
            elif eqn.outvars and hasattr(eqn.outvars[0], "aval"):
                # elementwise / reduction: ~1 flop per output element
                aval = eqn.outvars[0].aval
                if hasattr(aval, "shape"):
                    total += _ELEMWISE_COST.get(name, 1.0) * _prod(aval.shape)
    return total


def step_flops(step_fn, specs) -> float:
    """Global analytic FLOPs of one step (forward+backward for train)."""
    closed = jax.make_jaxpr(step_fn)(*specs)
    return count_flops(closed.jaxpr)
