"""Roofline terms from a compiled dry-run artifact (DESIGN.md SS6).

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.

Three measurement caveats of the CPU-backend dry-run, handled here:

1. XLA:CPU ``cost_analysis()`` counts while-loop (scan) bodies ONCE.  We
   therefore derive FLOPs from the jaxpr with static scan lengths
   (analysis/jaxpr_cost.py) -- exact for dot_general, which dominates.
   The raw cost_analysis numbers are still recorded in the artifact.

2. Collective ops live inside scan bodies in the post-partitioning HLO, so
   their bytes must be multiplied by the loop trip count.  We parse the HLO
   text per-computation, recover each while's trip count from its condition
   (compare-against-constant), and multiply recursively.

3. HBM traffic: we use ``memory_analysis`` buffer classes --
   arguments + outputs + 2x temporaries -- as the per-step traffic proxy
   (each argument read once, outputs written once, temps written+read).
   XLA:CPU's "bytes accessed" shares the while-body undercount and assumes
   no fusion, so it is recorded but not used for the term.

Shapes in the partitioned HLO are per-device; jaxpr shapes are global.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 FLOP/s per v5e chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]"
)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+("
    + "|".join(_COLL_KINDS)
    + r")(-start|-done)?\("
)
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)"
    r"|while\(.*?\).*?body=%?([\w.\-]+).*?condition=%?([\w.\-]+)"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Map computation name -> its lines."""
    comps: Dict[str, List[str]] = {}
    current: Optional[str] = None
    for line in hlo_text.splitlines():
        # header: "%name (args...) -> result {"; args may nest parens
        # (tuple-typed computations), so match lazily up to the '->'.
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*?\))?\s*->.*{\s*$", line)
        if m and ("{" in line):
            current = m.group(1)
            comps[current] = []
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is not None:
            comps[current].append(line)
    return comps


def _entry_name(hlo_text: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.MULTILINE)
    return m.group(1) if m else None


def _trip_count(cond_lines: List[str]) -> int:
    """Trip count from a counted-loop condition (max compare constant)."""
    best = 1
    for line in cond_lines:
        if "compare" in line or "constant" in line:
            for m in _CONST_RE.finditer(line):
                best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class CollectiveStats:
    op_bytes: Dict[str, float]        # per-device bytes per collective kind
    op_counts: Dict[str, float]       # dynamic counts (x trip counts)

    @property
    def total_bytes(self) -> float:
        return sum(self.op_bytes.values())

    @property
    def wire_bytes(self) -> float:
        """Ring-algorithm wire estimate: all-reduce ~2x its buffer."""
        tot = 0.0
        for kind, b in self.op_bytes.items():
            tot += 2.0 * b if kind == "all-reduce" else float(b)
        return tot


def parse_collectives(hlo_text: str) -> CollectiveStats:
    comps = _split_computations(hlo_text)
    entry = _entry_name(hlo_text)

    cache: Dict[str, Tuple[Dict[str, float], Dict[str, float]]] = {}

    def walk(name: str, depth=0) -> Tuple[Dict[str, float], Dict[str, float]]:
        if name in cache:
            return cache[name]
        b = {k: 0.0 for k in _COLL_KINDS}
        c = {k: 0.0 for k in _COLL_KINDS}
        cache[name] = (b, c)  # break cycles defensively
        for line in comps.get(name, ()):
            om = _OP_RE.match(line)
            if om and om.group(3) != "-done":
                kind = om.group(2)
                result = om.group(1)
                nbytes = sum(
                    _shape_bytes(m.group(1), m.group(2))
                    for m in _SHAPE_RE.finditer(result)
                )
                if om.group(3) == "-start":
                    # start result tuples carry (input, output) buffers
                    nbytes = nbytes / 2.0
                b[kind] += nbytes
                c[kind] += 1
            elif " while(" in line and depth < 16:
                wm = re.search(r"condition=%?([\w.\-]+)", line)
                bm = re.search(r"body=%?([\w.\-]+)", line)
                if wm and bm:
                    trips = _trip_count(comps.get(wm.group(1), []))
                    bb, bc = walk(bm.group(1), depth + 1)
                    for k in _COLL_KINDS:
                        b[k] += trips * bb[k]
                        c[k] += trips * bc[k]
            else:
                # fusion/call ops referencing other computations
                fm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", line)
                if fm and fm.group(1) in comps and depth < 16:
                    bb, bc = walk(fm.group(1), depth + 1)
                    for k in _COLL_KINDS:
                        b[k] += bb[k]
                        c[k] += bc[k]
        cache[name] = (b, c)
        return b, c

    if entry is None:
        # fall back: flat scan, no trip multiplication
        b = {k: 0.0 for k in _COLL_KINDS}
        c = {k: 0.0 for k in _COLL_KINDS}
        for line in hlo_text.splitlines():
            om = _OP_RE.match(line)
            if om and om.group(3) != "-done":
                kind = om.group(2)
                nbytes = sum(
                    _shape_bytes(m.group(1), m.group(2))
                    for m in _SHAPE_RE.finditer(om.group(1))
                )
                b[kind] += nbytes
                c[kind] += 1
        return CollectiveStats(op_bytes=b, op_counts=c)

    b, c = walk(entry)
    return CollectiveStats(op_bytes=b, op_counts=c)


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    collective_wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_device: float
    useful_flops_ratio: float
    bound_s: float                   # max of the three terms
    roofline_fraction: float         # useful compute time / bound

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline(
    jaxpr_flops_global: float,
    mem_stats,                        # CompiledMemoryStats
    collectives: CollectiveStats,
    model_flops_global: float,
    n_devices: int,
) -> RooflineTerms:
    flops = jaxpr_flops_global / n_devices
    # Aliased (donated) outputs update their input buffer in place: the
    # write traffic is the updated slice, not the full buffer, so aliased
    # bytes are subtracted from the output-write term (they remain counted
    # once as argument reads).
    hbm_bytes = float(
        mem_stats.argument_size_in_bytes
        + mem_stats.output_size_in_bytes
        - mem_stats.alias_size_in_bytes
        + 2 * mem_stats.temp_size_in_bytes
    )
    coll = float(collectives.total_bytes)

    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    collective_s = coll / LINK_BW

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    model_fpd = model_flops_global / n_devices
    bound = max(compute_s, memory_s, collective_s)
    return RooflineTerms(
        flops_per_device=flops,
        hbm_bytes_per_device=hbm_bytes,
        collective_bytes_per_device=coll,
        collective_wire_bytes=collectives.wire_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_per_device=model_fpd,
        useful_flops_ratio=(model_fpd / flops) if flops else 0.0,
        bound_s=bound,
        roofline_fraction=(model_fpd / PEAK_FLOPS) / bound if bound else 0.0,
    )


def model_flops_global(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train / 2*N*D forward (N = active params,

    D = tokens processed this step).
    """
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
