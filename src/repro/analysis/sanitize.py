"""Runtime sanitizers for the serving hot path (DESIGN.md SS11).

Three recurring serving-bug families get a *runtime* tripwire here, the
dynamic counterpart of the static rules in ``repro.analysis.lint``:

- **Silent retraces** (PR 4's bug class): :class:`TraceCounter` is the
  one implementation of trace-time counting -- a counter bumps only
  while jit is *tracing* the wrapped function, so steady-state traffic
  that reuses compiled buckets leaves it flat -- and
  :func:`retrace_guard` turns "the counters stayed flat" into a context
  manager that raises :class:`RetraceError` when they did not.
- **Accidental host syncs** (PR 6's bug class): :func:`transfer_guard`
  wraps a decode block in ``jax.transfer_guard_device_to_host
  ("disallow")`` so an implicit device->host transfer inside the
  device-resident round raises instead of silently serializing the
  pipeline.  The guard is thread-local (it covers the caller's
  dispatches, e.g. the coalesced staged block); designed host syncs at
  block boundaries stay *outside* the guarded region.
- **Lock discipline in the threaded executors**: the lock-order
  recorder wraps ``StageStreamCore._cond`` and
  ``StagePipelineExecutor._active_lock`` (via
  :func:`instrument_condition` / :func:`instrument_lock`) and records
  every pairwise acquisition order into a process-wide edge registry;
  acquiring A-then-B after B-then-A was seen anywhere is reported by
  :func:`lock_violations`.  :func:`require_held` asserts a code path
  runs under an instrumented lock.

Everything gates on ``REPRO_SANITIZE=1`` (:func:`enabled`): with the
flag unset the instrument factories return plain ``threading`` objects
and :func:`transfer_guard` is a no-op, so the steady-state hot path
pays nothing.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import traceback
from typing import Dict, List, Optional, Sequence, Tuple

_FALSY = ("", "0", "false", "False", "no")


def enabled() -> bool:
    """True when the runtime sanitizers are switched on
    (``REPRO_SANITIZE`` set to anything truthy)."""
    return os.environ.get("REPRO_SANITIZE", "") not in _FALSY


# ---------------------------------------------------------------------------
# retrace accounting
# ---------------------------------------------------------------------------


class RetraceError(RuntimeError):
    """A guarded region traced more jit functions than it was allowed."""


class TraceCounter:
    """Per-kind trace counters that bump only at jit *trace* time.

    ``counts`` is a plain dict so owners can expose it directly (the
    serving engine aliases it as ``trace_counts`` for stats and
    benchmarks).  ``wrap(kind, fn)`` returns ``fn`` with a counter bump
    on entry -- under ``jax.jit`` the wrapper body only runs while
    tracing, so compiled steady-state calls leave the counter flat.
    ``jit(fn, kind=...)`` is the one-step ``jax.jit(wrap(...))``.
    """

    def __init__(self, kinds: Sequence[str] = ()):
        self.counts: Dict[str, int] = {k: 0 for k in kinds}

    def bump(self, kind: str) -> None:
        self.counts[kind] = self.counts.get(kind, 0) + 1

    def wrap(self, kind: str, fn):
        def traced(*args, **kwargs):
            self.bump(kind)
            return fn(*args, **kwargs)

        return traced

    def jit(self, fn, *, kind: str, **jit_kwargs):
        import jax

        return jax.jit(self.wrap(kind, fn), **jit_kwargs)

    def snapshot(self) -> Dict[str, int]:
        return dict(self.counts)

    def total(self) -> int:
        return sum(self.counts.values())


@contextlib.contextmanager
def retrace_guard(
    counter: TraceCounter,
    max_new_traces: int = 0,
    kinds: Optional[Sequence[str]] = None,
):
    """Fail if ``counter`` records more than ``max_new_traces`` new
    traces inside the block (optionally restricted to ``kinds``).

    The canonical zero-retrace check: warm the engine, then serve live
    traffic under ``retrace_guard(engine.tracing)`` -- any retrace
    under mixed-length traffic raises :class:`RetraceError` with the
    per-kind delta instead of silently recompiling mid-stream.
    """
    before = counter.snapshot()
    yield counter
    after = counter.snapshot()
    keys = set(before) | set(after)
    if kinds is not None:
        keys &= set(kinds)
    new = {
        k: after.get(k, 0) - before.get(k, 0)
        for k in sorted(keys)
        if after.get(k, 0) != before.get(k, 0)
    }
    total = sum(new.values())
    if total > max_new_traces:
        raise RetraceError(
            f"{total} new jit trace(s) inside a retrace_guard "
            f"(allowed {max_new_traces}): {new}"
        )


# ---------------------------------------------------------------------------
# host-transfer tripwire
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def transfer_guard(active: Optional[bool] = None):
    """Disallow implicit device->host transfers inside the block.

    ``active=None`` follows :func:`enabled` -- the serving engine wraps
    every decode block in this, so the tripwire arms under
    ``REPRO_SANITIZE=1`` and costs nothing otherwise.  Only the
    device->host direction is guarded: host->device transfers (jit
    argument uploads, compile-time constants) are benign on the decode
    path, while a device->host pull mid-block is exactly the silent
    serialization PR 6 chased.
    """
    if active is None:
        active = enabled()
    if not active:
        yield
        return
    import jax

    with jax.transfer_guard_device_to_host("disallow"):
        yield


# ---------------------------------------------------------------------------
# lock-order recorder
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LockOrderViolation:
    kind: str      # "order" (inconsistent pairwise order) | "unguarded"
    first: str     # lock held / expected
    second: str    # lock acquired out of order ("" for unguarded)
    site: str      # file:line of the offending acquisition


_tl = threading.local()
_registry_lock = threading.Lock()
_edges: Dict[Tuple[str, str], str] = {}
_violations: List[LockOrderViolation] = []


def _held() -> List[str]:
    held = getattr(_tl, "held", None)
    if held is None:
        held = []
        _tl.held = held
    return held


def _call_site() -> str:
    # the frame that called acquire()/require_held(): two sanitize
    # frames sit above it on the stack
    frames = traceback.extract_stack(limit=4)
    for fr in reversed(frames):
        if "sanitize" not in (fr.filename or ""):
            return f"{fr.filename}:{fr.lineno}"
    return "<unknown>"


def reset_lock_monitor() -> None:
    """Clear the process-wide edge registry and recorded violations."""
    with _registry_lock:
        _edges.clear()
        _violations.clear()


def lock_violations() -> List[LockOrderViolation]:
    """Violations recorded since the last :func:`reset_lock_monitor`."""
    with _registry_lock:
        return list(_violations)


def _note_acquired(name: str) -> None:
    held = _held()
    site = _call_site()
    with _registry_lock:
        for prev in held:
            if prev == name:
                continue
            _edges.setdefault((prev, name), site)
            first_site = _edges.get((name, prev))
            if first_site is not None:
                _violations.append(
                    LockOrderViolation(
                        kind="order", first=prev, second=name, site=site
                    )
                )
    held.append(name)


def _note_released(name: str) -> None:
    held = _held()
    for i in range(len(held) - 1, -1, -1):
        if held[i] == name:
            del held[i]
            return


class _InstrumentedLock:
    """A ``threading.Lock``/``RLock`` wrapper feeding the recorder.

    Lock *names* are class-level (e.g. every ``StageStreamCore``
    instance shares ``"StageStreamCore._cond"``): ordering violations
    are a property of the code's lock classes, not of instances.
    """

    def __init__(self, inner, name: str):
        self._inner = inner
        self.name = name

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            _note_acquired(self.name)
        return got

    def release(self) -> None:
        self._inner.release()
        _note_released(self.name)

    def locked(self) -> bool:
        return self._inner.locked()

    def held_by_me(self) -> bool:
        return self.name in _held()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False


class _InstrumentedCondition(_InstrumentedLock):
    """Condition wrapper: acquire/release feed the recorder, the wait
    and notify family delegates.  ``wait`` keeps the lock "held" from
    the recorder's view -- while waiting, the thread acquires nothing
    else through this code path, so edges stay accurate."""

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._inner.wait(timeout)

    def wait_for(self, predicate, timeout: Optional[float] = None):
        return self._inner.wait_for(predicate, timeout)

    def notify(self, n: int = 1) -> None:
        self._inner.notify(n)

    def notify_all(self) -> None:
        self._inner.notify_all()


def instrument_lock(name: str, lock=None, active: Optional[bool] = None):
    """A (possibly instrumented) lock: plain ``threading.Lock`` when
    the sanitizers are off, recorder-wrapped otherwise."""
    inner = lock if lock is not None else threading.Lock()
    if not (enabled() if active is None else active):
        return inner
    return _InstrumentedLock(inner, name)


def instrument_condition(name: str, cond=None, active: Optional[bool] = None):
    """A (possibly instrumented) condition variable, like
    :func:`instrument_lock`."""
    inner = cond if cond is not None else threading.Condition()
    if not (enabled() if active is None else active):
        return inner
    return _InstrumentedCondition(inner, name)


def require_held(lock, site: str = "") -> None:
    """Record an ``unguarded`` violation when the calling thread does
    not hold ``lock``.  No-op for uninstrumented locks (sanitizers
    off), so call sites can assert lock discipline unconditionally."""
    if not isinstance(lock, _InstrumentedLock):
        return
    if lock.held_by_me():
        return
    with _registry_lock:
        _violations.append(
            LockOrderViolation(
                kind="unguarded",
                first=lock.name,
                second="",
                site=site or _call_site(),
            )
        )
