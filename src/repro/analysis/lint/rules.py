"""The five static rules (DESIGN.md SS11).

=======  =========================  ==============================================
ID       slug                       catches
=======  =========================  ==============================================
RPL001   donation-after-use         a variable passed at a ``donate_argnums``
                                    position read after the call without
                                    reassignment (PR 6's bug class)
RPL002   eager-host-op-in-hot-path  ``np.asarray``/``.item()``/``int()``/
                                    ``float()``/``jax.device_get`` in functions
                                    reachable from the decode round
RPL003   hardcoded-interpret        Pallas entry points pinning ``interpret``
                                    to a literal instead of resolving through
                                    ``kernels.common.default_interpret()``
RPL004   unlocked-shared-write      writes to ``self._*`` of a threaded class
                                    outside a ``with self.<lock/cond>`` block
RPL005   jit-missing-static         ``jax.jit`` tracing a config-like argument
                                    not covered by static_argnums/argnames
=======  =========================  ==============================================

Each rule walks the :class:`~repro.analysis.lint.core.Project` AST and
anchors findings to precise source spans; the driver resolves
``# lint: disable=RULE -- reason`` waivers per finding.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.lint.callgraph import CallGraph, FuncInfo, own_nodes
from repro.analysis.lint.core import (
    FileSource,
    Finding,
    Project,
    resolve_waivers,
)


@dataclasses.dataclass(frozen=True)
class Rule:
    rule_id: str
    slug: str
    description: str
    check_fn: Callable[["Rule", Project], List[Finding]]

    def check(self, project: Project) -> List[Finding]:
        return self.check_fn(self, project)

    def finding(
        self, file: FileSource, node: ast.AST, message: str
    ) -> Finding:
        f = Finding(
            rule_id=self.rule_id,
            slug=self.slug,
            path=file.path,
            line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0),
            message=message,
        )
        resolve_waivers(file, f, node)
        return f


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _dotted(node: ast.AST) -> Optional[str]:
    """``Name``/``Attribute`` chain rooted at a Name -> ``"a.b.c"``;
    anything else (subscripts, calls) is untrackable -> None."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if not isinstance(cur, ast.Name):
        return None
    parts.append(cur.id)
    return ".".join(reversed(parts))


def _is_jit_call(node: ast.Call) -> bool:
    """``jax.jit(...)``, bare ``jit(...)``, or any ``<obj>.jit(...)``
    (the TraceCounter.jit wrapper forwards its jit kwargs)."""
    f = node.func
    if isinstance(f, ast.Name) and f.id == "jit":
        return True
    if isinstance(f, ast.Attribute) and f.attr == "jit":
        return True
    return False


def _literal_ints(node: ast.AST) -> Optional[Set[int]]:
    """Literal int / tuple-of-int (conditional expressions fold to the
    union of both arms) -> the index set; unresolvable -> None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        out: Set[int] = set()
        for e in node.elts:
            got = _literal_ints(e)
            if got is None:
                return None
            out |= got
        return out
    if isinstance(node, ast.IfExp):
        a = _literal_ints(node.body)
        b = _literal_ints(node.orelse)
        if a is None or b is None:
            return None
        return a | b
    return None


def _literal_strs(node: ast.AST) -> Optional[Set[str]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        out: Set[str] = set()
        for e in node.elts:
            got = _literal_strs(e)
            if got is None:
                return None
            out |= got
        return out
    return None


def _pos(node: ast.AST) -> Tuple[int, int]:
    return (getattr(node, "lineno", 0), getattr(node, "col_offset", 0))


def _end_pos(node: ast.AST) -> Tuple[int, int]:
    return (
        getattr(node, "end_lineno", getattr(node, "lineno", 0)),
        getattr(node, "end_col_offset", 0),
    )


def _target_names(stmt: ast.stmt) -> Set[str]:
    """Dotted names assigned by a statement's targets."""
    out: Set[str] = set()
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.For):
        targets = [stmt.target]
    for t in targets:
        stack = [t]
        while stack:
            n = stack.pop()
            if isinstance(n, (ast.Tuple, ast.List)):
                stack.extend(n.elts)
                continue
            d = _dotted(n)
            if d is not None:
                out.add(d)
    return out


# ---------------------------------------------------------------------------
# RPL001 donation-after-use
# ---------------------------------------------------------------------------


def _donation_bindings(
    file: FileSource,
) -> Dict[Tuple[str, Optional[str], str], Set[int]]:
    """Map of jitted-with-donation bindings in one file.

    Keys: ``("name", None, n)`` for ``n = jax.jit(..., donate_argnums=...)``
    and ``("attr", Class, a)`` for ``self.a = jax.jit(...)`` inside
    class ``Class``.  Values: the donated positional indices (literal,
    with conditional expressions folded to the union of both arms)."""
    out: Dict[Tuple[str, Optional[str], str], Set[int]] = {}
    for node in ast.walk(file.tree):
        if not isinstance(node, ast.Assign):
            continue
        val = node.value
        if not isinstance(val, ast.Call) or not _is_jit_call(val):
            continue
        donated: Optional[Set[int]] = None
        for kw in val.keywords:
            if kw.arg == "donate_argnums":
                donated = _literal_ints(kw.value)
        if not donated:
            continue
        cls = file.enclosing(node, ast.ClassDef)
        for tgt in node.targets:
            elts = (
                tgt.elts
                if isinstance(tgt, (ast.Tuple, ast.List))
                else [tgt]
            )
            for t in elts:
                if isinstance(t, ast.Name):
                    out[("name", None, t.id)] = donated
                elif (
                    isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"
                    and cls is not None
                ):
                    out[("attr", cls.name, t.attr)] = donated
    return out


def _check_donation_after_use(rule: Rule, project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for file in project.files:
        bindings = _donation_bindings(file)
        if not bindings:
            continue
        funcs = [
            n for n in ast.walk(file.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        ]
        for fn in funcs:
            cls = file.enclosing(fn, ast.ClassDef)
            cls_name = cls.name if cls is not None else None
            for call in own_nodes(fn):
                if not isinstance(call, ast.Call):
                    continue
                donated = _donated_positions(call, cls_name, bindings)
                if not donated:
                    continue
                findings.extend(
                    _scan_uses_after(rule, file, fn, call, donated)
                )
    return findings


def _donated_positions(
    call: ast.Call,
    cls_name: Optional[str],
    bindings: Dict[Tuple[str, Optional[str], str], Set[int]],
) -> Optional[Set[int]]:
    f = call.func
    if isinstance(f, ast.Name):
        return bindings.get(("name", None, f.id))
    if (
        isinstance(f, ast.Attribute)
        and isinstance(f.value, ast.Name)
        and f.value.id == "self"
    ):
        return bindings.get(("attr", cls_name, f.attr))
    return None


def _scan_uses_after(
    rule: Rule,
    file: FileSource,
    fn: ast.AST,
    call: ast.Call,
    donated: Set[int],
) -> List[Finding]:
    """Flag reads of a donated argument after the call, unless the
    call's own statement (or a later statement before the read)
    reassigns it.  The scan is positional (source order) and stops at
    the first ``return``/``raise`` after the call -- reads past an
    exit belong to sibling branches."""
    stmt = file.enclosing_stmt(call)
    if stmt is None:
        return []
    reassigned = _target_names(stmt)
    names = {
        _dotted(call.args[p])
        for p in donated
        if p < len(call.args)
    }
    names = {n for n in names if n is not None and n not in reassigned}
    if not names:
        return []
    after = _end_pos(stmt)
    # events after the call: loads and stores of each donated name, and
    # control-flow exits
    events: List[Tuple[Tuple[int, int], str, ast.AST, Optional[str]]] = []
    for node in own_nodes(fn):
        pos = _pos(node)
        if pos <= after:
            continue
        if isinstance(node, (ast.Return, ast.Raise)):
            events.append((pos, "exit", node, None))
            continue
        if not isinstance(node, (ast.Name, ast.Attribute)):
            continue
        d = _dotted(node)
        if d not in names:
            continue
        kind = (
            "store"
            if isinstance(node.ctx, (ast.Store, ast.Del))
            else "load"
        )
        events.append((pos, kind, node, d))
    events.sort(key=lambda e: e[0])
    findings: List[Finding] = []
    open_names = set(names)
    for _pos_, kind, node, d in events:
        if kind == "exit":
            break
        if d not in open_names:
            continue
        if kind == "store":
            open_names.discard(d)
            continue
        findings.append(
            rule.finding(
                file,
                node,
                f"'{d}' was donated into the jitted call at line "
                f"{call.lineno} (donate_argnums) and is read here "
                "without reassignment -- its buffer no longer exists",
            )
        )
        open_names.discard(d)   # one finding per donated name
    return findings


# ---------------------------------------------------------------------------
# RPL002 eager-host-op-in-hot-path
# ---------------------------------------------------------------------------

# decode-round entry points; bare names match any class (the serving
# engine's device step, the staged runner's round/block methods, the
# stage-thread loop and its run_stage callbacks)
HOT_PATH_ROOTS: Tuple[str, ...] = (
    "_step_device",
    "decode_round",
    "decode_block",
    "_decode_block_coalesced",
    "_run_stage",
    "_finish_group",
    "_stage_loop",
    "run_stage",
)


def _host_op(node: ast.Call) -> Optional[str]:
    f = node.func
    if isinstance(f, ast.Name) and f.id in ("int", "float"):
        return f"{f.id}()"
    if isinstance(f, ast.Attribute):
        if f.attr == "item":
            return ".item()"
        if isinstance(f.value, ast.Name):
            if f.value.id in ("np", "numpy") and f.attr in (
                "asarray", "array"
            ):
                return f"{f.value.id}.{f.attr}()"
            if f.value.id == "jax" and f.attr == "device_get":
                return "jax.device_get()"
    return None


def _check_eager_host_op(rule: Rule, project: Project) -> List[Finding]:
    graph = CallGraph(project)
    findings: List[Finding] = []
    for info in graph.reachable(HOT_PATH_ROOTS):
        for node in own_nodes(info.node):
            if not isinstance(node, ast.Call):
                continue
            op = _host_op(node)
            if op is None:
                continue
            findings.append(
                rule.finding(
                    info.file,
                    node,
                    f"{op} in '{info.qualname}', reachable from the "
                    "decode round -- forces a host sync on the hot "
                    "path",
                )
            )
    return findings


# ---------------------------------------------------------------------------
# RPL003 hardcoded-interpret
# ---------------------------------------------------------------------------


def _check_hardcoded_interpret(rule: Rule, project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for file in project.files:
        for node in ast.walk(file.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                findings.extend(_interpret_defaults(rule, file, node))
            elif isinstance(node, ast.Call):
                f = node.func
                name = (
                    f.attr if isinstance(f, ast.Attribute)
                    else f.id if isinstance(f, ast.Name) else None
                )
                if name != "pallas_call":
                    continue
                for kw in node.keywords:
                    if (
                        kw.arg == "interpret"
                        and isinstance(kw.value, ast.Constant)
                        and isinstance(kw.value.value, bool)
                    ):
                        findings.append(
                            rule.finding(
                                file,
                                kw.value,
                                "pallas_call pins interpret="
                                f"{kw.value.value}; thread the caller's "
                                "resolved flag (kernels.common."
                                "default_interpret) instead",
                            )
                        )
    return findings


def _interpret_defaults(
    rule: Rule, file: FileSource, fn
) -> List[Finding]:
    out: List[Finding] = []
    args = fn.args
    pos_args = args.posonlyargs + args.args
    defaults = args.defaults
    offset = len(pos_args) - len(defaults)
    pairs = [
        (a, d)
        for a, d in zip(pos_args[offset:], defaults)
    ] + [
        (a, d)
        for a, d in zip(args.kwonlyargs, args.kw_defaults)
        if d is not None
    ]
    for a, d in pairs:
        if (
            a.arg == "interpret"
            and isinstance(d, ast.Constant)
            and isinstance(d.value, bool)
        ):
            out.append(
                rule.finding(
                    file,
                    d,
                    f"'{fn.name}' hardcodes interpret={d.value}; "
                    "default to None and resolve via "
                    "kernels.common.default_interpret() so the "
                    "backend/env override applies",
                )
            )
    return out


# ---------------------------------------------------------------------------
# RPL004 unlocked-shared-write
# ---------------------------------------------------------------------------

_LOCK_FACTORY = ("Lock", "RLock", "Condition")
_INSTRUMENT_FACTORY = ("instrument_lock", "instrument_condition")
_LOCK_ATTR_RE = re.compile(r"lock|cond|mutex", re.IGNORECASE)


def _threaded_class_locks(cls: ast.ClassDef) -> Optional[Set[str]]:
    """None if the class is not threaded; else the set of its lock/cond
    attribute names.  A class is *threaded* when it creates a
    ``threading.Lock/RLock/Condition`` (or a sanitize-instrumented
    one), or spawns ``threading.Thread`` workers."""
    locks: Set[str] = set()
    threaded = False
    for node in ast.walk(cls):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        name = (
            f.attr if isinstance(f, ast.Attribute)
            else f.id if isinstance(f, ast.Name) else None
        )
        if name == "Thread":
            threaded = True
        if name in _LOCK_FACTORY or name in _INSTRUMENT_FACTORY:
            threaded = True
    if not threaded:
        return None
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign):
            continue
        val = node.value
        if not isinstance(val, ast.Call):
            continue
        f = val.func
        name = (
            f.attr if isinstance(f, ast.Attribute)
            else f.id if isinstance(f, ast.Name) else None
        )
        if name not in _LOCK_FACTORY and name not in _INSTRUMENT_FACTORY:
            continue
        for tgt in node.targets:
            if (
                isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"
            ):
                locks.add(tgt.attr)
    return locks


def _under_lock(
    file: FileSource, node: ast.AST, lock_attrs: Set[str]
) -> bool:
    """Is ``node`` inside a ``with self.<lock>`` block?"""
    cur = file.parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return False
        if isinstance(cur, ast.With):
            for item in cur.items:
                ctx = item.context_expr
                if (
                    isinstance(ctx, ast.Attribute)
                    and isinstance(ctx.value, ast.Name)
                    and ctx.value.id == "self"
                    and (
                        ctx.attr in lock_attrs
                        or _LOCK_ATTR_RE.search(ctx.attr)
                    )
                ):
                    return True
        cur = file.parents.get(cur)
    return False


def _check_unlocked_shared_write(
    rule: Rule, project: Project
) -> List[Finding]:
    findings: List[Finding] = []
    for file in project.files:
        for cls in ast.walk(file.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            locks = _threaded_class_locks(cls)
            if locks is None:
                continue
            for method in cls.body:
                if not isinstance(
                    method, (ast.FunctionDef, ast.AsyncFunctionDef)
                ):
                    continue
                if method.name == "__init__":
                    continue   # constructor runs before threads exist
                for node in ast.walk(method):
                    tgt = _shared_write_target(node)
                    if tgt is None:
                        continue
                    if tgt in locks:
                        continue
                    if _under_lock(file, node, locks):
                        continue
                    findings.append(
                        rule.finding(
                            file,
                            node,
                            f"write to 'self.{tgt}' in threaded class "
                            f"'{cls.name}.{method.name}' outside a "
                            "'with self.<lock>' block",
                        )
                    )
    return findings


def _shared_write_target(node: ast.AST) -> Optional[str]:
    """``self._x = ...`` / ``self._x[k] = ...`` / ``self._x += ...``
    store target -> ``_x``; anything else None."""
    if not isinstance(node, (ast.Attribute, ast.Subscript)):
        return None
    if not isinstance(getattr(node, "ctx", None), (ast.Store, ast.Del)):
        return None
    base = node.value if isinstance(node, ast.Subscript) else node
    if isinstance(node, ast.Subscript):
        if not isinstance(base, ast.Attribute):
            return None
    if (
        isinstance(base, ast.Attribute)
        and isinstance(base.value, ast.Name)
        and base.value.id == "self"
        and base.attr.startswith("_")
    ):
        return base.attr
    return None


# ---------------------------------------------------------------------------
# RPL005 jit-missing-static
# ---------------------------------------------------------------------------

_CONFIG_PARAM_RE = re.compile(r"^(cfg|config|mcfg)$|(_cfg|_config)$")


def _check_jit_missing_static(rule: Rule, project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for file in project.files:
        defs = {
            n.name: n
            for n in ast.walk(file.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        methods: Dict[Tuple[str, str], ast.AST] = {}
        for cls in ast.walk(file.tree):
            if isinstance(cls, ast.ClassDef):
                for m in cls.body:
                    if isinstance(
                        m, (ast.FunctionDef, ast.AsyncFunctionDef)
                    ):
                        methods[(cls.name, m.name)] = m
        for node in ast.walk(file.tree):
            if not isinstance(node, ast.Call) or not _is_jit_call(node):
                continue
            if not node.args:
                continue
            target = node.args[0]
            params, skip_self = _jit_target_params(
                file, node, target, defs, methods
            )
            if params is None:
                continue
            static_idx: Set[int] = set()
            static_names: Set[str] = set()
            for kw in node.keywords:
                if kw.arg == "static_argnums":
                    static_idx |= _literal_ints(kw.value) or set()
                if kw.arg == "static_argnames":
                    static_names |= _literal_strs(kw.value) or set()
            for i, pname in enumerate(params):
                if not _CONFIG_PARAM_RE.search(pname):
                    continue
                if i in static_idx or pname in static_names:
                    continue
                findings.append(
                    rule.finding(
                        file,
                        node,
                        f"jax.jit traces config-like argument "
                        f"'{pname}' (position {i}); mark it static "
                        "(static_argnums/static_argnames) or close "
                        "over it",
                    )
                )
    return findings


def _jit_target_params(
    file: FileSource,
    call: ast.Call,
    target: ast.AST,
    defs: Dict[str, ast.AST],
    methods: Dict[Tuple[str, str], ast.AST],
) -> Tuple[Optional[List[str]], bool]:
    """Positional parameter names of the jitted callable, ``self``
    dropped for bound methods; (None, False) when unresolvable."""
    if isinstance(target, ast.Lambda):
        return [a.arg for a in target.args.args], False
    if isinstance(target, ast.Name):
        fn = defs.get(target.id)
        if fn is None:
            return None, False
        names = [a.arg for a in fn.args.args]
        return names, False
    if (
        isinstance(target, ast.Attribute)
        and isinstance(target.value, ast.Name)
        and target.value.id == "self"
    ):
        cls = file.enclosing(call, ast.ClassDef)
        if cls is None:
            return None, False
        fn = methods.get((cls.name, target.attr))
        if fn is None:
            return None, False
        names = [a.arg for a in fn.args.args]
        if names and names[0] == "self":
            names = names[1:]
        return names, True
    return None, False


# ---------------------------------------------------------------------------
# the rule table
# ---------------------------------------------------------------------------

RULES: Tuple[Rule, ...] = (
    Rule(
        "RPL001",
        "donation-after-use",
        "variable passed at a donate_argnums position is read after "
        "the call without reassignment",
        _check_donation_after_use,
    ),
    Rule(
        "RPL002",
        "eager-host-op-in-hot-path",
        "np.asarray/.item()/int()/float()/jax.device_get inside "
        "functions reachable from the decode round",
        _check_eager_host_op,
    ),
    Rule(
        "RPL003",
        "hardcoded-interpret",
        "Pallas entry points pin interpret to a literal instead of "
        "resolving kernels.common.default_interpret()",
        _check_hardcoded_interpret,
    ),
    Rule(
        "RPL004",
        "unlocked-shared-write",
        "write to self._* of a threaded executor class outside a "
        "'with self.<lock/cond>' block",
        _check_unlocked_shared_write,
    ),
    Rule(
        "RPL005",
        "jit-missing-static",
        "jax.jit call site traces a config-like argument not covered "
        "by static_argnums/static_argnames",
        _check_jit_missing_static,
    ),
)
