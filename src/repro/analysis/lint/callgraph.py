"""Function index + hot-path reachability for the lint rules.

The eager-host-op rule (RPL002) needs "is this function reachable from
the decode round?".  The call graph here is deliberately simple --
sound enough for a lint gate, cheap enough to run on every CI push:

- **Nodes** are every ``def`` in the analyzed files (methods, nested
  closures included), keyed by identity.
- **Edges** resolve two call shapes: a bare ``name(...)`` call binds to
  any function of the same *file* with that name, and a
  ``self.attr(...)`` call binds to (a) same-class methods named
  ``attr`` and (b) functions bound to ``self.attr`` anywhere in the
  class (``self.attr = jax.jit(fn, ...)`` -- the serving engine's
  jitted-closure idiom), resolved through the names referenced by the
  binding's value expression.
- **Roots** are matched by name: ``"Class.method"`` pins the class,
  a bare ``"name"`` matches any function with that name.

Cross-module calls through local variables (``runner.decode_round``)
are not resolved; the rule's root set names those entry points
directly instead.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.lint.core import FileSource, Project

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


@dataclasses.dataclass
class FuncInfo:
    file: FileSource
    node: ast.AST                  # FunctionDef | AsyncFunctionDef
    name: str
    class_name: Optional[str]      # enclosing class, if any

    @property
    def qualname(self) -> str:
        if self.class_name:
            return f"{self.class_name}.{self.name}"
        return self.name


def own_nodes(func_node: ast.AST) -> Iterable[ast.AST]:
    """All AST nodes of a function body, nested ``def``/``class``
    bodies excluded (each nested def is its own graph node; lambdas
    stay in -- they have no name to form an edge with)."""
    stack = list(ast.iter_child_nodes(func_node))
    while stack:
        n = stack.pop()
        if isinstance(n, _FUNC_NODES + (ast.ClassDef,)):
            continue
        yield n
        stack.extend(ast.iter_child_nodes(n))


class CallGraph:
    def __init__(self, project: Project):
        self.functions: List[FuncInfo] = []
        # (file, name) -> funcs; (file, class, method) -> funcs
        self._by_name: Dict[Tuple[int, str], List[FuncInfo]] = {}
        self._by_method: Dict[Tuple[int, str, str], List[FuncInfo]] = {}
        # (file, class, attr) -> function names its binding references
        self._attr_bindings: Dict[Tuple[int, str, str], Set[str]] = {}
        for fi, file in enumerate(project.files):
            for node in ast.walk(file.tree):
                if not isinstance(node, _FUNC_NODES):
                    continue
                cls = file.enclosing(node, ast.ClassDef)
                info = FuncInfo(
                    file=file,
                    node=node,
                    name=node.name,
                    class_name=cls.name if cls is not None else None,
                )
                self.functions.append(info)
                self._by_name.setdefault((fi, node.name), []).append(info)
                if info.class_name:
                    self._by_method.setdefault(
                        (fi, info.class_name, node.name), []
                    ).append(info)
            # self.attr = <expr referencing functions> bindings
            for node in ast.walk(file.tree):
                if not isinstance(node, ast.Assign):
                    continue
                cls = file.enclosing(node, ast.ClassDef)
                if cls is None:
                    continue
                names: Set[str] = set()
                for n in ast.walk(node.value):
                    if isinstance(n, ast.Name):
                        names.add(n.id)
                    elif isinstance(n, ast.Attribute) and _is_self(n.value):
                        # self.m bound through a wrapper, e.g.
                        # self._blk = self.tracing.jit(self._blk_impl)
                        names.add(n.attr)
                if not names:
                    continue
                for tgt in node.targets:
                    elts = (
                        tgt.elts
                        if isinstance(tgt, (ast.Tuple, ast.List))
                        else [tgt]
                    )
                    for t in elts:
                        attr = _self_attr(t)
                        if attr is not None:
                            self._attr_bindings.setdefault(
                                (fi, cls.name, attr), set()
                            ).update(names)
        self._file_index = {
            id(file): fi for fi, file in enumerate(project.files)
        }
        self._edges: Dict[int, List[FuncInfo]] = {}
        for info in self.functions:
            self._edges[id(info.node)] = list(self._callees(info))

    def _callees(self, info: FuncInfo) -> Iterable[FuncInfo]:
        fi = self._file_index[id(info.file)]
        for node in own_nodes(info.node):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Name):
                yield from self._by_name.get((fi, f.id), [])
            elif isinstance(f, ast.Attribute) and _is_self(f.value):
                if info.class_name:
                    yield from self._by_method.get(
                        (fi, info.class_name, f.attr), []
                    )
                    for name in self._attr_bindings.get(
                        (fi, info.class_name, f.attr), ()
                    ):
                        yield from self._by_name.get((fi, name), [])
                        yield from self._by_method.get(
                            (fi, info.class_name, name), []
                        )

    def reachable(self, roots: Sequence[str]) -> List[FuncInfo]:
        """Functions reachable from any root spec (``"Class.method"``
        or bare ``"name"``), the roots themselves included."""
        class_roots = {r for r in roots if "." in r}
        name_roots = {r for r in roots if "." not in r}
        seen: Set[int] = set()
        frontier = [
            f for f in self.functions
            if f.name in name_roots or f.qualname in class_roots
        ]
        out: List[FuncInfo] = []
        while frontier:
            f = frontier.pop()
            if id(f.node) in seen:
                continue
            seen.add(id(f.node))
            out.append(f)
            frontier.extend(self._edges.get(id(f.node), ()))
        return out


def _is_self(node: ast.AST) -> bool:
    return isinstance(node, ast.Name) and node.id == "self"


def _self_attr(node: ast.AST) -> Optional[str]:
    """``self.attr`` target -> ``attr``, else None."""
    if isinstance(node, ast.Attribute) and _is_self(node.value):
        return node.attr
    return None
