"""JAX-aware static lint for the repro tree (DESIGN.md SS11).

Run as ``python -m repro.analysis.lint src tests``; exits 1 on any
unwaived finding.  Rules live in :mod:`repro.analysis.lint.rules`, the
driver (waiver parsing, reporting) in :mod:`repro.analysis.lint.core`.
"""
from repro.analysis.lint.core import (
    Finding,
    FileSource,
    Project,
    lint_paths,
    main,
)
from repro.analysis.lint.rules import RULES

__all__ = [
    "Finding",
    "FileSource",
    "Project",
    "RULES",
    "lint_paths",
    "main",
]
