"""Lint driver: file loading, waiver parsing, findings, reporting.

The static rules (``repro.analysis.lint.rules``) consume a
:class:`Project` built here -- every analyzed file parsed once, with a
parent map for enclosing-statement lookups -- and emit
:class:`Finding`\\ s anchored to AST nodes.  The driver then resolves
each finding against the file's waiver comments:

    # lint: disable=RPL002 -- one-line justification

A waiver on the finding's line, the line above it, the first line of
the enclosing statement, or the line above *that*, waives the finding
(multi-line statements can carry the comment above the statement).
Waivers name rules by ID (``RPL002``) or slug
(``eager-host-op-in-hot-path``), comma-separated.  A waiver without a
justification (no ``-- text`` tail) does NOT waive: the policy is that
every suppression explains itself, so the finding stays unwaived with
a note saying why.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

_WAIVER_RE = re.compile(
    r"#\s*lint:\s*disable=([A-Za-z0-9_,\- ]+?)\s*(?:--\s*(\S.*))?$"
)

# paths containing any of these parts are skipped by default: the lint
# fixtures are *data* for the linter's own tests, deliberately bad
DEFAULT_EXCLUDE_PARTS: Tuple[str, ...] = ("lint_fixtures",)


@dataclasses.dataclass
class Finding:
    rule_id: str
    slug: str
    path: str
    line: int
    col: int
    message: str
    waived: bool = False
    waiver_note: str = ""

    def format(self) -> str:
        tag = " (waived)" if self.waived else ""
        note = f" [{self.waiver_note}]" if self.waiver_note else ""
        return (
            f"{self.path}:{self.line}:{self.col} "
            f"{self.rule_id}[{self.slug}]{tag}: {self.message}{note}"
        )


class FileSource:
    """One parsed source file: AST, parent map, and waiver comments."""

    def __init__(self, path: str, source: Optional[str] = None):
        self.path = str(path)
        self.source = (
            source if source is not None else Path(path).read_text()
        )
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=self.path)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        # line -> ({rule ids/slugs}, justification)
        self.waivers: Dict[int, Tuple[Set[str], str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _WAIVER_RE.search(text)
            if m:
                rules = {
                    r.strip() for r in m.group(1).split(",") if r.strip()
                }
                self.waivers[i] = (rules, (m.group(2) or "").strip())

    def enclosing_stmt(self, node: ast.AST) -> Optional[ast.stmt]:
        cur: Optional[ast.AST] = node
        while cur is not None and not isinstance(cur, ast.stmt):
            cur = self.parents.get(cur)
        return cur

    def enclosing(self, node: ast.AST, kinds) -> Optional[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, kinds):
                return cur
            cur = self.parents.get(cur)
        return None


class Project:
    """All files of one lint run (rules may resolve across them)."""

    def __init__(self, files: Sequence[FileSource]):
        self.files = list(files)


def iter_py_files(
    paths: Sequence[str],
    exclude_parts: Sequence[str] = DEFAULT_EXCLUDE_PARTS,
) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            cands = sorted(path.rglob("*.py"))
        else:
            cands = [path]
        for c in cands:
            if any(part in c.parts for part in exclude_parts):
                continue
            out.append(c)
    return out


def resolve_waivers(
    file: FileSource, finding: Finding, node: ast.AST
) -> None:
    """Waive ``finding`` if a matching justified waiver comment covers
    the node's line, the enclosing statement's first line, or the line
    above either."""
    stmt = file.enclosing_stmt(node)
    lines = {finding.line, finding.line - 1}
    if stmt is not None:
        lines |= {stmt.lineno, stmt.lineno - 1}
    matched_without_note = False
    for line in sorted(lines, reverse=True):
        entry = file.waivers.get(line)
        if entry is None:
            continue
        rules, note = entry
        if finding.rule_id not in rules and finding.slug not in rules:
            continue
        if note:
            finding.waived = True
            finding.waiver_note = note
            return
        matched_without_note = True
    if matched_without_note:
        finding.waiver_note = (
            "waiver missing justification (use '-- reason')"
        )


def lint_paths(
    paths: Sequence[str],
    rules: Optional[Sequence[str]] = None,
    exclude_parts: Sequence[str] = DEFAULT_EXCLUDE_PARTS,
) -> List[Finding]:
    """Run the rule set over ``paths`` (files or directories); returns
    every finding, waived ones included (filter on ``.waived``).
    ``rules`` restricts to a subset of rule IDs/slugs."""
    from repro.analysis.lint.rules import RULES

    files = [FileSource(str(p)) for p in iter_py_files(paths, exclude_parts)]
    project = Project(files)
    findings: List[Finding] = []
    for rule in RULES:
        if rules is not None and (
            rule.rule_id not in rules and rule.slug not in rules
        ):
            continue
        findings.extend(rule.check(project))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule_id))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    from repro.analysis.lint.rules import RULES

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="JAX-aware static lint for the repro tree "
        "(DESIGN.md SS11). Exit 1 on any unwaived finding.",
    )
    ap.add_argument("paths", nargs="*", default=["src", "tests"])
    ap.add_argument(
        "--rules", default=None,
        help="comma-separated rule IDs/slugs to run (default: all)",
    )
    ap.add_argument(
        "--show-waived", action="store_true",
        help="print waived findings too",
    )
    ap.add_argument(
        "--include-fixtures", action="store_true",
        help="lint tests/lint_fixtures too (excluded by default: the "
        "bad fixtures exist to trip the rules)",
    )
    ap.add_argument(
        "--list-rules", action="store_true",
        help="print the rule table and exit",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in RULES:
            print(f"{rule.rule_id}  {rule.slug}: {rule.description}")
        return 0

    rule_filter = (
        [r.strip() for r in args.rules.split(",")] if args.rules else None
    )
    exclude = () if args.include_fixtures else DEFAULT_EXCLUDE_PARTS
    findings = lint_paths(
        args.paths, rules=rule_filter, exclude_parts=exclude
    )
    unwaived = [f for f in findings if not f.waived]
    shown = findings if args.show_waived else unwaived
    for f in shown:
        print(f.format())
    n_waived = len(findings) - len(unwaived)
    print(
        f"lint: {len(findings)} finding(s), {n_waived} waived, "
        f"{len(unwaived)} unwaived"
    )
    return 1 if unwaived else 0
