from repro.analysis.lint import main

raise SystemExit(main())
