"""Compiled-artifact analysis: roofline terms from cost_analysis + HLO."""
