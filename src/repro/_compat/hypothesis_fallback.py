"""Minimal stand-in for the `hypothesis` property-testing API.

The container image has no hypothesis wheel and installing packages is
off-limits, but the test suite leans on property tests for the scheduler
and kernels.  This module implements the small slice of the API those
tests use -- ``given``/``settings`` decorators and the ``strategies``
combinators ``integers``, ``floats``, ``booleans``, ``sampled_from``,
``lists``, and ``composite`` -- as a deterministic random-example runner
(seeded per test, so failures reproduce).

It is *not* hypothesis: no shrinking, no example database, no edge-case
bias beyond always trying strategy bounds first.  ``tests/conftest.py``
installs it into ``sys.modules`` only when the real package is missing.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib
from typing import Any, Callable, List, Sequence


class Strategy:
    """A value generator: ``draw(rnd)`` produces one example."""

    def __init__(self, draw_fn: Callable[[random.Random], Any], boundary=()):
        self._draw = draw_fn
        # values worth trying before random sampling (poor man's edge bias)
        self.boundary = tuple(boundary)

    def draw(self, rnd: random.Random) -> Any:
        return self._draw(rnd)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rnd: fn(self.draw(rnd)))

    def filter(self, pred: Callable[[Any], bool]) -> "Strategy":
        def draw(rnd: random.Random):
            for _ in range(1000):
                v = self.draw(rnd)
                if pred(v):
                    return v
            raise RuntimeError("filter predicate too restrictive")
        return Strategy(draw)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(
        lambda rnd: rnd.randint(min_value, max_value),
        boundary=(min_value, max_value),
    )


def floats(
    min_value: float,
    max_value: float,
    allow_nan: bool = False,
    allow_infinity: bool = False,
) -> Strategy:
    del allow_nan, allow_infinity  # bounded draws are always finite
    return Strategy(
        lambda rnd: rnd.uniform(min_value, max_value),
        boundary=(min_value, max_value),
    )


def booleans() -> Strategy:
    return Strategy(lambda rnd: rnd.random() < 0.5, boundary=(False, True))


def sampled_from(options: Sequence[Any]) -> Strategy:
    options = list(options)
    return Strategy(lambda rnd: rnd.choice(options), boundary=options[:2])


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    def draw(rnd: random.Random):
        n = rnd.randint(min_size, max_size)
        return [elements.draw(rnd) for _ in range(n)]
    return Strategy(draw)


def composite(fn: Callable[..., Any]) -> Callable[..., Strategy]:
    """``@st.composite`` -- fn's first arg is the ``draw`` function."""

    @functools.wraps(fn)
    def make(*args, **kwargs) -> Strategy:
        def draw_example(rnd: random.Random):
            return fn(lambda strat: strat.draw(rnd), *args, **kwargs)
        return Strategy(draw_example)

    return make


class _Settings:
    def __init__(self, max_examples: int = 20, deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._hypothesis_settings = self
        return fn


settings = _Settings


def given(**strategy_kwargs: Strategy):
    """Run the test over ``max_examples`` deterministic random examples."""

    def decorate(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            cfg = getattr(fn, "_hypothesis_settings", None) or _Settings()
            seed0 = zlib.crc32(fn.__qualname__.encode())
            names = list(strategy_kwargs)
            for ex in range(cfg.max_examples):
                rnd = random.Random(seed0 + ex)
                drawn = {}
                for pos, name in enumerate(names):
                    strat = strategy_kwargs[name]
                    # first examples walk the strategy boundaries
                    if ex < len(strat.boundary):
                        drawn[name] = strat.boundary[ex]
                    else:
                        drawn[name] = strat.draw(rnd)
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (#{ex}): {drawn!r}"
                    ) from e
        runner.hypothesis = types.SimpleNamespace(inner_test=fn)
        # Hide the drawn parameters from pytest's fixture resolution: the
        # visible signature keeps only non-strategy params (real fixtures).
        sig = inspect.signature(fn)
        runner.__signature__ = sig.replace(
            parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategy_kwargs
            ]
        )
        return runner

    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` if the real one is absent."""
    if "hypothesis" in sys.modules:
        return
    try:
        import hypothesis  # noqa: F401  (real package present)
        return
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.Strategy = Strategy
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "composite"):
        setattr(strategies, name, globals()[name])
    strategies.Strategy = Strategy
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
