"""Version/dependency compatibility shims.

The repo targets current JAX but must degrade gracefully on the pinned
container toolchain (jax 0.4.x, no hypothesis wheel).  Policy: real
packages always win; shims only fill in when an import would fail.
"""
