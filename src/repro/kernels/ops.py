"""Jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU,
so the same call sites work in both environments.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import int8_gemm as _gemm
from repro.kernels import im2col as _im2col
from repro.kernels import ref as _ref
from repro.kernels.common import default_interpret as _default_interpret


def int8_gemm(
    w: jax.Array,
    x: jax.Array,
    bias: Optional[jax.Array] = None,
    shift: jax.Array | int = 0,
    residual: Optional[jax.Array] = None,
    *,
    relu: bool = False,
    block_n: int = 128,
    block_p: int = 128,
    block_m: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Systolic-array GEMM: int8 in, int8 out, fused bias/shift/ReLU/residual."""
    if interpret is None:
        interpret = _default_interpret()
    return _gemm.int8_gemm(
        w, x, bias, shift, residual,
        relu=relu, block_n=block_n, block_p=block_p, block_m=block_m,
        interpret=interpret,
    )


def im2col(
    img: jax.Array, k: int, stride: int = 1, pad: int = 0,
    *, interpret: Optional[bool] = None,
) -> jax.Array:
    """IM2COL patch matrix (OH*OW, k*k*C) from an HWC feature map."""
    if interpret is None:
        interpret = _default_interpret()
    if k == 1 and pad == 0:
        # The PU's common input datapath handles k=1, p=0, s in {1,2}
        # as plain (strided) linear transfers without IM2COL (SS II-B).
        img = img[::stride, ::stride]
        h, w, c = img.shape
        return img.reshape(h * w, c)
    return _im2col.im2col(img, k, stride, pad, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("k", "stride", "pad", "relu", "interpret")
)
def conv2d_int8(
    img: jax.Array,                     # (H, W, Cin) int8
    w4d: jax.Array,                     # (k, k, Cin, Cout) int8
    bias: Optional[jax.Array] = None,   # (Cout,) int32
    *,
    k: int,
    stride: int = 1,
    pad: int = 0,
    shift: jax.Array | int = 0,
    relu: bool = False,
    residual: Optional[jax.Array] = None,   # (OH, OW, Cout) int8
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Convolution as GEMM: IM2COL + systolic int8 GEMM (paper Fig. 3).

    Returns (OH, OW, Cout) int8.
    """
    if interpret is None:
        interpret = _default_interpret()
    h, w, cin = img.shape
    cout = w4d.shape[-1]
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1

    patches = im2col(img, k, stride, pad, interpret=interpret)  # (OH*OW, kkC)
    wmat = w4d.transpose(3, 0, 1, 2).reshape(cout, k * k * cin)
    res2d = None
    if residual is not None:
        res2d = residual.reshape(oh * ow, cout).T
    y = int8_gemm(
        wmat, patches.T, bias, shift, res2d, relu=relu, interpret=interpret
    )  # (Cout, OH*OW)
    return y.T.reshape(oh, ow, cout)


def niu_refresh(
    q: jax.Array, exp, seed, *, interpret: Optional[bool] = None, **kw
) -> jax.Array:
    """NIU round (paper SS VI): fresh AIMC noise on an int8 weight tile."""
    from repro.kernels import niu as _niu

    if interpret is None:
        interpret = _default_interpret()
    return _niu.niu_refresh(q, exp, seed, interpret=interpret, **kw)


# Re-export oracles so tests/benchmarks can sweep kernels against them from
# one import site.
int8_gemm_ref = _ref.int8_gemm_ref
im2col_ref = _ref.im2col_ref
conv2d_int8_ref = _ref.conv2d_int8_ref
