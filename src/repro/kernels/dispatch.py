"""Model -> kernel dispatch for the fused decode path.

The models layer (``transformer._layer_fn``, ``encdec._dec_layer_fn``,
``hybrid._shared_block``) calls these wrappers instead of touching
``decode.py`` directly, so every decode entry point -- the fused
``_decode_block`` scan, the per-stage loops, and the coalesced staged path
-- picks the kernels up from one place.  Activation is gated on
``cfg.decode_kernels`` (threaded from ``ServeConfig.decode_kernels`` by
the serving engine) plus the single-token shape test, with a
``REPRO_DECODE_KERNELS=0`` env kill switch for A/B triage without
replumbing configs.

This module deliberately imports nothing from ``repro.models`` (the models
import *it*); ``cfg`` is duck-typed on the ``ModelConfig`` fields it reads.

Block sizing (``kernel_blocks``): the streaming plan's schedulable tile is
one whole weight matrix (``runtime.serving.model_gemms`` /
``plan_model_streaming``), so the kernel's block size is the *VMEM
refinement* of a plan tile -- the tile is consumed whole when it fits the
per-operand VMEM budget and split into equal HBM->VMEM slabs along its
streaming axis otherwise.  The planner's tile sequence and the kernel's
block sequence therefore describe the same HBM traffic.

Exclusions (kept on XLA; DESIGN.md SS10): the KV-cache scatter between
QKV and attention, norms/residuals, MoE MLPs (token routing is not a
weight-streaming GEMM), and ``logical_constraint`` sharding annotations
(the decode kernels assume per-device replicated weights).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode

_ENV_KILL = "REPRO_DECODE_KERNELS"
# Per-operand VMEM budget for one streamed slab.  ~4 MiB leaves room for
# double-buffering plus scratch inside a ~16 MiB VMEM.
_VMEM_BUDGET = 4 * 2 ** 20


def enabled(cfg) -> bool:
    """True when the fused decode kernels are switched on for this model."""
    if os.environ.get(_ENV_KILL, "1") in ("0", "false", "False", "no"):
        return False
    return bool(getattr(cfg, "decode_kernels", False))


def _single_token(x: jax.Array) -> bool:
    return x.ndim == 3 and x.shape[1] == 1


def attention_active(cfg, x: jax.Array) -> bool:
    """Fused QKV/attention applies: flag on + single-token decode step."""
    return enabled(cfg) and _single_token(x)


def mlp_active(cfg, x: jax.Array) -> bool:
    """Fused MLP applies: flag on + single token + dense (non-MoE) MLP."""
    return enabled(cfg) and _single_token(x) and not getattr(cfg, "is_moe", False)


def _slab(dim: int, bytes_per_unit: int) -> int:
    """VMEM refinement of a plan tile: whole when it fits, equal slabs
    (rounded up to the 128-lane tile) otherwise."""
    total = dim * bytes_per_unit
    if total <= _VMEM_BUDGET:
        return dim
    n = -(-total // _VMEM_BUDGET)
    blk = -(-dim // n)
    blk = ((blk + 127) // 128) * 128
    return min(blk, dim)


def kernel_blocks(cfg, *, sk: Optional[int] = None, dtype=jnp.bfloat16) -> dict:
    """Derive each kernel's block size from the model's plan-tile shapes."""
    it = jnp.dtype(dtype).itemsize
    d, hd = cfg.d_model, cfg.head_dim
    dq = cfg.n_heads * hd
    dkv = cfg.n_kv_heads * hd
    out = {
        # qkv streams d_model rows of the three projection tiles together
        "block_m": _slab(d, (dq + 2 * dkv) * it),
        # mlp streams d_ff columns of gate+up plus the matching down rows
        "block_f": _slab(cfg.d_ff, 3 * d * it),
    }
    if sk is not None:
        # attention streams KV slots (k and v slabs per slot)
        out["block_s"] = _slab(sk, 2 * cfg.n_kv_heads * hd * it)
    return out


def decode_qkv(cfg, p: dict, x: jax.Array, positions: jax.Array, *, rope: bool):
    """(B, 1, d) -> q (B, 1, Hq, hd), k/v (B, 1, Hkv, hd) via fused_qkv."""
    b = x.shape[0]
    blocks = kernel_blocks(cfg, dtype=x.dtype)
    q, k, v = decode.fused_qkv(
        x[:, 0],
        p["wq"], p["wk"], p["wv"],
        p.get("bq"), p.get("bk"), p.get("bv"),
        positions.reshape(b) if positions is not None else None,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim,
        rope=rope,
        theta=float(cfg.rope_theta),
        block_m=blocks["block_m"],
    )
    return q[:, None], k[:, None], v[:, None]


def decode_attention(
    cfg,
    p: dict,
    q: jax.Array,                       # (B, 1, Hq, hd)
    k: jax.Array,                       # (B, Sk, Hkv, hd)
    v: jax.Array,
    *,
    q_positions: jax.Array,             # (B,) or (B, 1)
    kv_valid_len: Optional[jax.Array] = None,
    window: Optional[int] = None,
    window_arr: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    causal: bool = True,
) -> jax.Array:
    """Fused attention + output projection -> (B, 1, d)."""
    b, sk = q.shape[0], k.shape[1]
    blocks = kernel_blocks(cfg, sk=sk, dtype=q.dtype)
    y = decode.fused_decode_attention(
        q[:, 0],
        k, v,
        p["wo"], p.get("bo"),
        q_positions=q_positions.reshape(b),
        kv_valid_len=kv_valid_len,
        window=window,
        window_arr=window_arr,
        kv_positions=kv_positions,
        causal=causal,
        block_s=blocks["block_s"],
    )
    return y[:, None]


def decode_mlp(cfg, p: dict, x: jax.Array) -> jax.Array:
    """(B, 1, d) -> (B, 1, d) via fused_mlp (dense MLPs only)."""
    blocks = kernel_blocks(cfg, dtype=x.dtype)
    y = decode.fused_mlp(
        x[:, 0],
        p["w_up"], p.get("w_gate"), p.get("b_up"),
        p["w_down"], p.get("b_down"),
        act=cfg.mlp,
        block_f=blocks["block_f"],
    )
    return y[:, None]
