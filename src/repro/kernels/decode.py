"""Fused Pallas decode-stage kernels: the paper's PU datapath on the
per-token serving hot path.

Three kernels cover the two per-token hot ops of one decode layer
(DESIGN.md SS10); all reuse ``int8_gemm.py``'s structure -- a reduction
grid streaming weight/state tiles HBM->VMEM, accumulation into VMEM
scratch across grid steps, and the post-processing fused into the last
step's epilogue:

- :func:`fused_qkv` -- the Q/K/V projections of a single decode token as
  one weight-streaming pass over ``d_model`` (shared activation tile, all
  three heads' accumulators live in scratch), with bias add and RoPE
  rotation fused into the epilogue.
- :func:`fused_decode_attention` -- single-token GQA attention over the
  whole KV cache *and* the output projection: per-lane ring/valid/window
  masking and a streaming-softmax (running max / denom / accumulator)
  reduction over KV blocks, with ``ctx @ wo + bo`` fused into the final
  block's epilogue so the (B, Hq, hd) context never round-trips HBM.
- :func:`fused_mlp` -- the (gated-)MLP as one pass over ``d_ff`` blocks:
  up/gate GEMMs, bias and activation per block, immediately contracted
  through the matching ``w_down`` rows into a (B, d_model) scratch
  accumulator -- the (B, d_ff) intermediate never materializes in HBM.

Blocking matches the plan's weight-streaming granularity: a schedulable
plan tile is one weight matrix (``runtime.serving.model_gemms``), and the
kernel splits a tile into VMEM-budgeted sub-blocks only when it exceeds
the budget (``dispatch.kernel_blocks``), so the planner's tile sequence
and the kernel's block sequence describe the same traffic.

Numerics mirror the XLA reference ops (f32 accumulation, one rounding to
the compute dtype per GEMM, masking with the same -1e30 sentinel), so
greedy decode streams stay argmax-identical to the composed-XLA path;
exact bit-identity is NOT guaranteed (streaming softmax reassociates).

``interpret=None`` resolves through :func:`common.default_interpret`:
interpreted on CPU, compiled on TPU, ``REPRO_KERNEL_INTERPRET`` override.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import resolve_interpret

_NEG = -1e30          # masking sentinel; matches models.attention._NEG
_BIG = jnp.iinfo(jnp.int32).max
# padded ring-slot / arange sentinels (chosen so every mask comparison on
# a padded column is False without int32 overflow)
_PAD_NEG = -(1 << 30)
_PAD_POS = 1 << 30


def _pad_axis(a: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    rem = (-a.shape[axis]) % mult
    if not rem:
        return a
    pads = [(0, 0)] * a.ndim
    pads[axis] = (0, rem)
    return jnp.pad(a, pads, constant_values=value)


# ------------------------------------------------------------- fused QKV --


def _qkv_kernel(
    x_ref,          # (B, bm)
    wq_ref,         # (bm, Dq)
    wk_ref,         # (bm, Dkv)
    wv_ref,         # (bm, Dkv)
    bq_ref,         # (1, Dq)
    bk_ref,         # (1, Dkv)
    bv_ref,         # (1, Dkv)
    sin_ref,        # (B, hd/2) f32
    cos_ref,        # (B, hd/2) f32
    q_ref,          # out (B, Dq)
    k_ref,          # out (B, Dkv)
    v_ref,          # out (B, Dkv)
    accq_ref,       # scratch (B, Dq) f32
    acck_ref,       # scratch (B, Dkv) f32
    accv_ref,       # scratch (B, Dkv) f32
    *,
    n_m: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope: bool,
    has_bias: bool,
):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        accq_ref[...] = jnp.zeros_like(accq_ref)
        acck_ref[...] = jnp.zeros_like(acck_ref)
        accv_ref[...] = jnp.zeros_like(accv_ref)

    xb = x_ref[...]
    dims = (((1,), (0,)), ((), ()))
    accq_ref[...] += jax.lax.dot_general(
        xb, wq_ref[...], dims, preferred_element_type=jnp.float32
    )
    acck_ref[...] += jax.lax.dot_general(
        xb, wk_ref[...], dims, preferred_element_type=jnp.float32
    )
    accv_ref[...] += jax.lax.dot_general(
        xb, wv_ref[...], dims, preferred_element_type=jnp.float32
    )

    @pl.when(j == n_m - 1)
    def _epilogue():
        dt = q_ref.dtype
        # one rounding to the compute dtype per GEMM (mirrors the XLA dot),
        # THEN bias, THEN rope -- project_qkv/apply_rope op order.
        q = accq_ref[...].astype(dt)
        k = acck_ref[...].astype(dt)
        v = accv_ref[...].astype(dt)
        if has_bias:
            q = q + bq_ref[...].astype(dt)
            k = k + bk_ref[...].astype(dt)
            v = v + bv_ref[...].astype(dt)

        if rope:
            b = q.shape[0]
            half = head_dim // 2
            cos = cos_ref[...][:, None, :]           # (B, 1, hd/2)
            sin = sin_ref[...][:, None, :]

            def rot(t, heads):
                tf = t.reshape(b, heads, head_dim).astype(jnp.float32)
                t1 = tf[..., :half]
                t2 = tf[..., half:]
                out = jnp.concatenate(
                    [t1 * cos - t2 * sin, t2 * cos + t1 * sin], axis=-1
                )
                return out.astype(dt).reshape(b, heads * head_dim)

            q = rot(q, n_heads)
            k = rot(k, n_kv_heads)
        q_ref[...] = q
        k_ref[...] = k
        v_ref[...] = v


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_heads", "n_kv_heads", "head_dim", "rope", "theta",
        "block_m", "interpret",
    ),
)
def fused_qkv(
    x: jax.Array,                       # (B, d) compute dtype
    wq: jax.Array,                      # (d, Hq*hd)
    wk: jax.Array,                      # (d, Hkv*hd)
    wv: jax.Array,                      # (d, Hkv*hd)
    bq: Optional[jax.Array] = None,     # (Hq*hd,)
    bk: Optional[jax.Array] = None,
    bv: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,   # (B,) int32 (rope only)
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope: bool = True,
    theta: float = 1e4,
    block_m: int = 512,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode token's QKV projections + bias + RoPE in one pass.

    Returns ``(q (B, Hq, hd), k (B, Hkv, hd), v (B, Hkv, hd))`` in
    ``x.dtype`` -- the post-rope tensors the cache write and attention
    consume.
    """
    interpret = resolve_interpret(interpret)
    b, d = x.shape
    dq, dkv = n_heads * head_dim, n_kv_heads * head_dim
    dt = x.dtype
    has_bias = bq is not None

    if positions is None:
        positions = jnp.zeros((b,), jnp.int32)
    if rope:
        freqs = 1.0 / (
            theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
        )
        angles = positions[:, None].astype(jnp.float32) * freqs   # (B, hd/2)
        sin, cos = jnp.sin(angles), jnp.cos(angles)
    else:
        sin = cos = jnp.zeros((b, head_dim // 2), jnp.float32)

    zq = jnp.zeros((1, dq), dt)
    zkv = jnp.zeros((1, dkv), dt)
    bq2 = bq.reshape(1, dq).astype(dt) if has_bias else zq
    bk2 = bk.reshape(1, dkv).astype(dt) if has_bias else zkv
    bv2 = bv.reshape(1, dkv).astype(dt) if has_bias else zkv

    block_m = min(block_m, d)
    xp = _pad_axis(x, 1, block_m)
    wqp = _pad_axis(wq.astype(dt), 0, block_m)
    wkp = _pad_axis(wk.astype(dt), 0, block_m)
    wvp = _pad_axis(wv.astype(dt), 0, block_m)
    n_m = xp.shape[1] // block_m

    q, k, v = pl.pallas_call(
        functools.partial(
            _qkv_kernel, n_m=n_m, n_heads=n_heads, n_kv_heads=n_kv_heads,
            head_dim=head_dim, rope=rope, has_bias=has_bias,
        ),
        grid=(n_m,),
        in_specs=[
            pl.BlockSpec((b, block_m), lambda j: (0, j)),
            pl.BlockSpec((block_m, dq), lambda j: (j, 0)),
            pl.BlockSpec((block_m, dkv), lambda j: (j, 0)),
            pl.BlockSpec((block_m, dkv), lambda j: (j, 0)),
            pl.BlockSpec((1, dq), lambda j: (0, 0)),
            pl.BlockSpec((1, dkv), lambda j: (0, 0)),
            pl.BlockSpec((1, dkv), lambda j: (0, 0)),
            pl.BlockSpec((b, head_dim // 2), lambda j: (0, 0)),
            pl.BlockSpec((b, head_dim // 2), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, dq), lambda j: (0, 0)),
            pl.BlockSpec((b, dkv), lambda j: (0, 0)),
            pl.BlockSpec((b, dkv), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, dq), dt),
            jax.ShapeDtypeStruct((b, dkv), dt),
            jax.ShapeDtypeStruct((b, dkv), dt),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, dq), jnp.float32),
            pltpu.VMEM((b, dkv), jnp.float32),
            pltpu.VMEM((b, dkv), jnp.float32),
        ],
        interpret=interpret,
    )(xp, wqp, wkp, wvp, bq2, bk2, bv2, sin, cos)
    return (
        q.reshape(b, n_heads, head_dim),
        k.reshape(b, n_kv_heads, head_dim),
        v.reshape(b, n_kv_heads, head_dim),
    )


# -------------------------------------------- fused decode attention + wo --


def _decode_attn_kernel(
    q_ref,          # (1, Hq, hd)
    k_ref,          # (1, bs, Hkv, hd)
    v_ref,          # (1, bs, Hkv, hd)
    col_ref,        # (1, bs) int32 -- absolute position per cache slot
    limit_ref,      # (1, 1) int32 -- per-lane valid length
    row_ref,        # (1, 1) int32 -- query position
    win_ref,        # (1, 1) int32 -- attention window
    wo_ref,         # (Hq*hd, d)
    bo_ref,         # (1, d)
    out_ref,        # (1, d)
    m_ref,          # scratch (Hkv, G) f32 -- running max
    l_ref,          # scratch (Hkv, G) f32 -- running denom
    acc_ref,        # scratch (Hkv, G, hd) f32 -- running PV accumulator
    *,
    n_s: int,
    n_kv_heads: int,
    groups: int,
    head_dim: int,
    scale: float,
    causal: bool,
    use_kvp: bool,
    has_bias: bool,
):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (Hq, hd)
    qs = (q * scale).astype(q.dtype)
    kb = k_ref[0]                                  # (bs, Hkv, hd)
    vb = v_ref[0]

    col = col_ref[0]                               # (bs,)
    if use_kvp:
        # ring buffer: each slot carries its absolute position; negative
        # positions mark never-written slots (padded slots carry _PAD_NEG)
        valid = col >= 0
    else:
        valid = col < limit_ref[0, 0]
    if causal:
        row = row_ref[0, 0]
        win = win_ref[0, 0]
        valid = valid & (col <= row) & (col > row - win)

    # per-kv-head streaming-softmax update (static unroll: Hkv is small and
    # keeps every dot rank-2 for the MXU)
    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_rows, l_rows, acc_rows = [], [], []
    cdims = (((1,), (1,)), ((), ()))               # (G,hd) x (bs,hd)^T
    pdims = (((1,), (0,)), ((), ()))               # (G,bs) x (bs,hd)
    for kh in range(n_kv_heads):
        qh = qs[kh * groups:(kh + 1) * groups]     # (G, hd)
        s = jax.lax.dot_general(
            qh, kb[:, kh, :], cdims, preferred_element_type=jnp.float32
        )                                          # (G, bs)
        s = jnp.where(valid[None, :], s, _NEG)
        m_c = jnp.max(s, axis=-1)                  # (G,)
        m_new = jnp.maximum(m_prev[kh], m_c)
        corr = jnp.exp(m_prev[kh] - m_new)
        p = jnp.exp(s - m_new[:, None])            # (G, bs)
        l_rows.append(l_prev[kh] * corr + jnp.sum(p, axis=-1))
        pv = jax.lax.dot_general(
            p.astype(vb.dtype), vb[:, kh, :], pdims,
            preferred_element_type=jnp.float32,
        )                                          # (G, hd)
        acc_rows.append(acc_prev[kh] * corr[:, None] + pv)
        m_rows.append(m_new)
    m_ref[...] = jnp.stack(m_rows)
    l_ref[...] = jnp.stack(l_rows)
    acc_ref[...] = jnp.stack(acc_rows)

    @pl.when(s_idx == n_s - 1)
    def _epilogue():
        dt = out_ref.dtype
        ctx = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        ctx = ctx.astype(dt).reshape(1, n_kv_heads * groups * head_dim)
        y = jax.lax.dot_general(
            ctx, wo_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(dt)
        if has_bias:
            y = y + bo_ref[...].astype(dt)
        out_ref[...] = y


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_s", "interpret"),
)
def fused_decode_attention(
    q: jax.Array,                       # (B, Hq, hd) post-rope, unscaled
    k: jax.Array,                       # (B, Sk, Hkv, hd)
    v: jax.Array,                       # (B, Sk, Hkv, hd)
    wo: jax.Array,                      # (Hq*hd, d)
    bo: Optional[jax.Array] = None,     # (d,)
    *,
    q_positions: jax.Array,             # (B,) int32 absolute query position
    kv_valid_len: Optional[jax.Array] = None,   # () or (B,) int32
    window: Optional[int] = None,               # static sliding window
    window_arr: Optional[jax.Array] = None,     # dynamic () int32 window
    kv_positions: Optional[jax.Array] = None,   # (Sk,) or (B, Sk) ring slots
    causal: bool = True,
    block_s: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Single-token GQA attention + output projection -> (B, d).

    Mask semantics mirror ``models.attention._decode_attention`` exactly:
    ``kv_positions`` (ring caches; negative = never written) else
    ``arange < kv_valid_len``; causal row/window bounds on top.  The KV
    axis is streamed in ``block_s`` slabs with a running
    (max, denom, accumulator) softmax, and ``ctx @ wo (+ bo)`` runs in the
    last slab's epilogue.
    """
    interpret = resolve_interpret(interpret)
    b, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    groups = hq // hkv
    dt = q.dtype
    d = wo.shape[1]
    has_bias = bo is not None
    use_kvp = kv_positions is not None
    scale = 1.0 / (hd ** 0.5)

    if block_s is None:
        block_s = min(sk, 512)
    block_s = min(block_s, sk)

    if use_kvp:
        col = jnp.broadcast_to(
            kv_positions.astype(jnp.int32).reshape(-1, sk), (b, sk)
        )
        pad_val = _PAD_NEG
    else:
        col = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))
        pad_val = _PAD_POS
    col = _pad_axis(col, 1, block_s, value=pad_val)
    kp = _pad_axis(k, 1, block_s)
    vp = _pad_axis(v, 1, block_s)
    n_s = kp.shape[1] // block_s

    limit = jnp.broadcast_to(
        jnp.asarray(sk if kv_valid_len is None else kv_valid_len, jnp.int32),
        (b,),
    ).reshape(b, 1)
    row = q_positions.astype(jnp.int32).reshape(b, 1)
    if window_arr is not None:
        win = jnp.asarray(window_arr, jnp.int32)
    elif window is not None:
        win = jnp.asarray(window, jnp.int32)
    else:
        win = jnp.asarray(_BIG, jnp.int32)
    win = win.reshape(1, 1)

    wo_dt = wo.astype(dt)
    bo2 = bo.reshape(1, d).astype(dt) if has_bias else jnp.zeros((1, d), dt)

    out = pl.pallas_call(
        functools.partial(
            _decode_attn_kernel, n_s=n_s, n_kv_heads=hkv, groups=groups,
            head_dim=hd, scale=scale, causal=causal, use_kvp=use_kvp,
            has_bias=has_bias,
        ),
        grid=(b, n_s),
        in_specs=[
            pl.BlockSpec((1, hq, hd), lambda i, s: (i, 0, 0)),
            pl.BlockSpec((1, block_s, hkv, hd), lambda i, s: (i, s, 0, 0)),
            pl.BlockSpec((1, block_s, hkv, hd), lambda i, s: (i, s, 0, 0)),
            pl.BlockSpec((1, block_s), lambda i, s: (i, s)),
            pl.BlockSpec((1, 1), lambda i, s: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, s: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, s: (0, 0)),
            pl.BlockSpec((hq * hd, d), lambda i, s: (0, 0)),
            pl.BlockSpec((1, d), lambda i, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, s: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), dt),
        scratch_shapes=[
            pltpu.VMEM((hkv, groups), jnp.float32),
            pltpu.VMEM((hkv, groups), jnp.float32),
            pltpu.VMEM((hkv, groups, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, kp, vp, col, limit, row, win, wo_dt, bo2)
    return out


# -------------------------------------------------------------- fused MLP --


def _mlp_kernel(
    x_ref,          # (B, d)
    wg_ref,         # (d, bf) -- gate weights (== up weights when ungated)
    wu_ref,         # (d, bf)
    bu_ref,         # (1, bf)
    wd_ref,         # (bf, d)
    bd_ref,         # (1, d)
    out_ref,        # (B, d)
    acc_ref,        # scratch (B, d) f32
    *,
    n_f: int,
    act: str,
    gated: bool,
    has_bias: bool,
):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dt = out_ref.dtype
    xb = x_ref[...]
    dims = (((1,), (0,)), ((), ()))
    # d_model is unblocked, so each f-slab's up/gate columns complete in one
    # dot -- rounding to the compute dtype here is exactly the XLA dot's
    g = jax.lax.dot_general(
        xb, wg_ref[...], dims, preferred_element_type=jnp.float32
    ).astype(dt)
    if has_bias:
        g = g + bu_ref[...].astype(dt)
    if gated:
        up = jax.lax.dot_general(
            xb, wu_ref[...], dims, preferred_element_type=jnp.float32
        ).astype(dt)
    if act == "swiglu":
        h = jax.nn.silu(g) * up
    elif act == "gelu":
        h = jax.nn.gelu(g)
    elif act == "sq_relu":
        r = jax.nn.relu(g)
        h = r * r
    else:
        raise ValueError(act)
    acc_ref[...] += jax.lax.dot_general(
        h.astype(dt), wd_ref[...], dims, preferred_element_type=jnp.float32
    )

    @pl.when(j == n_f - 1)
    def _epilogue():
        y = acc_ref[...].astype(dt)
        if has_bias:
            y = y + bd_ref[...].astype(dt)
        out_ref[...] = y


@functools.partial(
    jax.jit, static_argnames=("act", "block_f", "interpret")
)
def fused_mlp(
    x: jax.Array,                       # (B, d) compute dtype
    w_up: jax.Array,                    # (d, f)
    w_gate: Optional[jax.Array] = None, # (d, f) -- presence selects gating
    b_up: Optional[jax.Array] = None,   # (f,)
    w_down: Optional[jax.Array] = None, # (f, d)
    b_down: Optional[jax.Array] = None, # (d,)
    *,
    act: str = "swiglu",
    block_f: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """up-proj -> activation -> down-proj without the HBM intermediate.

    Matches ``models.mlp.mlp_apply``: ``g = x @ (w_gate or w_up) (+ b_up)``,
    ``up = x @ w_up`` when gated, ``act(g, up) @ w_down (+ b_down)``.
    """
    interpret = resolve_interpret(interpret)
    b, d = x.shape
    f = w_up.shape[1]
    dt = x.dtype
    gated = w_gate is not None
    has_bias = b_up is not None
    if act == "swiglu" and not gated:
        raise ValueError("swiglu requires w_gate")

    block_f = min(block_f, f)
    wg = (w_gate if gated else w_up).astype(dt)
    wu = w_up.astype(dt)
    wgp = _pad_axis(wg, 1, block_f)
    wup = _pad_axis(wu, 1, block_f)
    wdp = _pad_axis(w_down.astype(dt), 0, block_f)
    fp = wgp.shape[1]
    n_f = fp // block_f
    bu2 = (
        _pad_axis(b_up.reshape(1, f).astype(dt), 1, block_f)
        if has_bias else jnp.zeros((1, fp), dt)
    )
    bd2 = (
        b_down.reshape(1, d).astype(dt) if has_bias else jnp.zeros((1, d), dt)
    )

    out = pl.pallas_call(
        functools.partial(
            _mlp_kernel, n_f=n_f, act=act, gated=gated, has_bias=has_bias
        ),
        grid=(n_f,),
        in_specs=[
            pl.BlockSpec((b, d), lambda j: (0, 0)),
            pl.BlockSpec((d, block_f), lambda j: (0, j)),
            pl.BlockSpec((d, block_f), lambda j: (0, j)),
            pl.BlockSpec((1, block_f), lambda j: (0, j)),
            pl.BlockSpec((block_f, d), lambda j: (j, 0)),
            pl.BlockSpec((1, d), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, d), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), dt),
        scratch_shapes=[pltpu.VMEM((b, d), jnp.float32)],
        interpret=interpret,
    )(x, wgp, wup, bu2, wdp, bd2)
    return out
