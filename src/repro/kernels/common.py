"""Shared kernel-layer plumbing: the interpret/compiled dispatch rule.

Every Pallas entry point in this package takes ``interpret``; the correct
default depends on where the process is running.  ``default_interpret()``
is the single source of that decision:

- ``REPRO_KERNEL_INTERPRET`` env var, when set, wins ("1"/"true" forces
  interpret mode everywhere -- the CI kernel step uses this so the suite
  is pinned to the interpreter even if a TPU is attached; "0" forces
  compiled lowering).
- Otherwise the JAX backend decides: compiled on TPU, interpreted
  elsewhere (CPU/GPU containers validate the same kernel bodies through
  the Pallas interpreter).

Wrappers resolve ``interpret=None`` through this helper at trace time, so
an ``interpret`` kwarg stays available for tests that pin one mode.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

_ENV = "REPRO_KERNEL_INTERPRET"
_FALSY = ("0", "false", "False", "no", "")


def default_interpret() -> bool:
    """True when Pallas kernels should run through the interpreter."""
    env = os.environ.get(_ENV)
    if env is not None:
        return env not in _FALSY
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` -> :func:`default_interpret`; booleans pass through."""
    return default_interpret() if interpret is None else bool(interpret)
