"""Pallas kernel: IM2COL patch extraction (paper SS II-B, Fig. 3).

The paper realizes IM2COL as *address/length command bundles* to the AXI
DataMover: feature maps stay in HWC order in HBM and the DMA engine gathers
strided segments into the activation buffer, forming the patch matrix
on-the-fly.  The TPU-native analogue: the kernel's index arithmetic plays
the command generator, and the Pallas block pipeline plays the DMA -- each
grid step gathers the strided rows of one output-row block from the (padded)
feature map in VMEM and emits the corresponding patch-matrix rows.

Grid: one program per output row (OH); each program emits the (OW, k*k*C)
patch block for that row, assembled from k*k strided slices -- a direct
transcription of the "address and length bundles ... according to the IFM
dimensions and Conv characteristics".
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _im2col_kernel(img_ref, out_ref, *, k: int, stride: int, ow: int, c: int):
    oh_idx = pl.program_id(0)
    base = oh_idx * stride
    pieces = []
    for ki in range(k):
        row = img_ref[base + ki]          # (Wp, C) -- one feature-map row
        for kj in range(k):
            # Strided gather of OW segments of C channels: the DMA command
            # bundle for (ki, kj) of this output row.
            sl = jax.lax.slice(
                row, (kj, 0), (kj + (ow - 1) * stride + 1, c), (stride, 1)
            )                              # (OW, C)
            pieces.append(sl)
    # Patch layout: [(ki, kj) outer, C inner] -- matches weight reshape
    # w4d.transpose(3,0,1,2).reshape(Cout, k*k*Cin).
    out_ref[0] = jnp.stack(pieces, axis=1).reshape(ow, k * k * c)


@functools.partial(jax.jit, static_argnames=("k", "stride", "pad", "interpret"))
def im2col(
    img: jax.Array,        # (H, W, C), int8 (or any dtype)
    k: int,
    stride: int = 1,
    pad: int = 0,
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Patch matrix (OH*OW, k*k*C) from an HWC feature map.

    ``interpret=None`` resolves via :func:`common.default_interpret`.
    """
    interpret = resolve_interpret(interpret)
    h, w, c = img.shape
    imgp = jnp.pad(img, ((pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1

    out = pl.pallas_call(
        functools.partial(_im2col_kernel, k=k, stride=stride, ow=ow, c=c),
        grid=(oh,),
        in_specs=[pl.no_block_spec],
        out_specs=pl.BlockSpec((1, ow, k * k * c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((oh, ow, k * k * c), img.dtype),
        interpret=interpret,
    )(imgp)
    return out.reshape(oh * ow, k * k * c)
