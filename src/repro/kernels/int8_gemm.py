"""Pallas TPU kernel: the systolic-array INT8 GEMM with fused post-processing.

TPU-native adaptation of the paper's PU datapath (DESIGN.md SS2):

- The DSP48E2 systolic array becomes the MXU, driven by an int8 x int8 ->
  int32 ``dot_general`` per VMEM block.
- The URAM weight store + ping-pong BRAM activation buffers become the
  Pallas block pipeline: ``BlockSpec`` index maps stream (bn x bm) weight
  tiles and (bm x bp) activation tiles HBM->VMEM, and Pallas double-buffers
  the next block's DMA under the current block's compute -- precisely the
  overlap the ping-pong buffers provide on the FPGA.
- Accumulation over ceil(M/bm) grid steps into a VMEM scratch mirrors the
  ceil(M/C_SA)-round partial-product accumulation of the SA.
- The epilogue fuses the scale/shift module (power-of-two requantize), the
  ReLU unit, and the SIMD residual-addition unit of the post-processing
  block -- applied on the last reduction step only.

Grid layout: ``(N/bn, P/bp, M/bm)`` with the reduction axis innermost so
each (i, j) output tile accumulates in scratch across consecutive steps.
Block defaults are MXU-aligned (multiples of 128; int8 native tile on TPU
is (32, 128), so 128 keeps both sublane and lane dims aligned).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import INT8_MAX, INT8_MIN
from repro.kernels.common import resolve_interpret


def _gemm_kernel(
    w_ref,            # (bn, bm) int8
    x_ref,            # (bm, bp) int8
    bias_ref,         # (bn, 1) int32
    shift_ref,        # (1, 1) int32
    res_ref,          # (bn, bp) int8 (dummy zeros when disabled)
    out_ref,          # (bn, bp) int8
    acc_ref,          # scratch (bn, bp) int32
    *,
    n_k: int,
    relu: bool,
    has_residual: bool,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.broadcast_to(
            bias_ref[...].astype(jnp.int32), acc_ref.shape
        )

    acc_ref[...] += jax.lax.dot_general(
        w_ref[...],
        x_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        shift = shift_ref[0, 0]
        # Power-of-two scale/shift with round-half-away-from-zero, exactly
        # the scale/shifts module after the SA (Fig. 2(b)).
        sh = jnp.maximum(shift, 0)
        half = jnp.where(shift > 0, (1 << jnp.maximum(shift - 1, 0)), 0)
        pos = (acc + half) >> sh
        neg = -((-acc + half) >> sh)
        y = jnp.where(acc >= 0, pos, neg)
        y = jnp.where(shift >= 0, y, acc << jnp.maximum(-shift, 0))
        y = jnp.clip(y, INT8_MIN, INT8_MAX)
        if has_residual:
            y = jnp.clip(y + res_ref[...].astype(jnp.int32), INT8_MIN, INT8_MAX)
        if relu:
            y = jnp.maximum(y, 0)
        out_ref[...] = y.astype(jnp.int8)


def _pad_to(a: jax.Array, mults: tuple) -> jax.Array:
    pads = []
    for dim, mult in zip(a.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if any(p[1] for p in pads):
        a = jnp.pad(a, pads)
    return a


@functools.partial(
    jax.jit,
    static_argnames=(
        "relu", "block_n", "block_p", "block_m", "interpret",
    ),
)
def int8_gemm(
    w: jax.Array,                      # (N, M) int8
    x: jax.Array,                      # (M, P) int8
    bias: Optional[jax.Array] = None,  # (N,) int32
    shift: jax.Array | int = 0,
    residual: Optional[jax.Array] = None,  # (N, P) int8
    *,
    relu: bool = False,
    block_n: int = 128,
    block_p: int = 128,
    block_m: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Quantized GEMM ``y = post(shift_round(w @ x + bias))`` -> int8 (N, P).

    ``interpret=None`` resolves via :func:`common.default_interpret`
    (interpreted off-TPU, compiled on TPU, env override).
    """
    interpret = resolve_interpret(interpret)
    n, m = w.shape
    m2, p = x.shape
    assert m == m2, (w.shape, x.shape)
    has_residual = residual is not None

    if bias is None:
        bias = jnp.zeros((n,), jnp.int32)
    shift = jnp.asarray(shift, jnp.int32).reshape(1, 1)
    if residual is None:
        residual = jnp.zeros((1, 1), jnp.int8)  # dummy; blocks map to (0,0)

    wp = _pad_to(w, (block_n, block_m))
    xp = _pad_to(x, (block_m, block_p))
    biasp = _pad_to(bias.reshape(-1, 1).astype(jnp.int32), (block_n, 1))
    resp = _pad_to(residual, (block_n, block_p)) if has_residual else residual

    np_, mp_ = wp.shape
    pp_ = xp.shape[1]
    n_k = mp_ // block_m
    grid = (np_ // block_n, pp_ // block_p, n_k)

    res_spec = (
        pl.BlockSpec((block_n, block_p), lambda i, j, k: (i, j))
        if has_residual
        else pl.BlockSpec((1, 1), lambda i, j, k: (0, 0))
    )

    out = pl.pallas_call(
        functools.partial(
            _gemm_kernel, n_k=n_k, relu=relu, has_residual=has_residual
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_m), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_m, block_p), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_n, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            res_spec,
        ],
        out_specs=pl.BlockSpec((block_n, block_p), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, pp_), jnp.int8),
        scratch_shapes=[pltpu.VMEM((block_n, block_p), jnp.int32)],
        interpret=interpret,
    )(wp, xp, biasp, shift, resp)
    return out[:n, :p]
