"""Pallas TPU kernel: the Noise Injection Unit (paper SS VI).

The paper's NIU is a *hardware block* that replaces a PU: each inference
round it reads the noiseless int8 weights of AIMC-emulated tiles from a
pristine HBM region, injects fresh device-noise instances, and overwrites
the weight regions the PU consumes.  This kernel is its TPU-native
realization: a tiled read-modify-write over the quantized weight buffer,
streaming (block_r x block_c) tiles HBM->VMEM, perturbing them with a
counter-based in-kernel RNG, and emitting the updated int8 payloads.

RNG: a stateless integer-mix hash of (seed, element index) -- the
counter-based construction hardware NIUs use, portable across interpret
mode (CPU validation) and TPU lowering (no backend PRNG primitives
needed).  Gaussian samples come from a Box-Muller transform of two
uniform draws.

Noise model (matches core/aimc.py's float path on the dequantized scale):
    sigma = prog_noise_scale * (0.25*|w| + 0.05*w_max)
    w'    = clip(round((drift*(w + sigma*N) + read*w_max*N') / 2^e), -128, 127)
with w = q * 2^e and w_max the tile's programmed range.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _mix(x: jax.Array) -> jax.Array:
    """xorshift-multiply integer mixer (lowbias32), uint32 -> uint32."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _uniform(counter: jax.Array, salt: int) -> jax.Array:
    """(0,1) floats from the counter hash (uint32 bits / 2^32)."""
    bits = _mix(counter ^ jnp.uint32(salt))
    u = bits.astype(jnp.float32) / jnp.float32(2**32)
    return jnp.clip(u, 1e-7, 1.0 - 1e-7)


def _gaussian(counter: jax.Array, salt: int) -> jax.Array:
    u1 = _uniform(counter, salt)
    u2 = _uniform(counter, salt + 0x9E3779B9)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)


def _niu_kernel(
    seed_ref,        # (1, 1) int32
    q_ref,           # (br, bc) int8 pristine payload
    exp_ref,         # (1, 1) int32 power-of-two exponent
    wmax_ref,        # (1, 1) f32 programmed range
    out_ref,         # (br, bc) int8 noisy payload
    *,
    prog_noise_scale: float,
    read_noise_scale: float,
    drift: float,
    n_cols: int,
):
    i = pl.program_id(0)
    j = pl.program_id(1)
    br, bc = q_ref.shape

    scale = jnp.exp2(exp_ref[0, 0].astype(jnp.float32))
    w = q_ref[...].astype(jnp.float32) * scale
    w_max = wmax_ref[0, 0]

    # Per-element global counter: unique across the grid and the tile.
    row = jax.lax.broadcasted_iota(jnp.uint32, (br, bc), 0) + jnp.uint32(i * br)
    col = jax.lax.broadcasted_iota(jnp.uint32, (br, bc), 1) + jnp.uint32(j * bc)
    counter = (
        row * jnp.uint32(n_cols) + col
    ) ^ _mix(seed_ref[0, 0].astype(jnp.uint32))

    g = _gaussian(counter, 0x1234567)
    sigma_prog = prog_noise_scale * (0.25 * jnp.abs(w) + 0.05 * w_max)
    w_noisy = w + sigma_prog * g
    if drift != 1.0:
        w_noisy = w_noisy * drift
    if read_noise_scale > 0.0:
        g2 = _gaussian(counter, 0x7654321)
        w_noisy = w_noisy + read_noise_scale * w_max * g2

    q = jnp.clip(jnp.round(w_noisy / scale), -128, 127)
    out_ref[...] = q.astype(jnp.int8)


@functools.partial(
    jax.jit,
    static_argnames=(
        "prog_noise_scale", "read_noise_scale", "drift",
        "block_r", "block_c", "interpret",
    ),
)
def niu_refresh(
    q: jax.Array,                 # (R, C) int8 pristine payload
    exp: jax.Array,               # () int32/int8 pow2 exponent
    seed: jax.Array | int,        # () int32
    *,
    prog_noise_scale: float = 0.1,
    read_noise_scale: float = 0.02,
    drift: float = 1.0,
    block_r: int = 256,
    block_c: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One NIU round: fresh noise instance on an int8 weight tile -> int8.

    ``interpret=None`` resolves via :func:`common.default_interpret`
    (interpreted off-TPU, compiled on TPU, env override).
    """
    interpret = resolve_interpret(interpret)
    r, c = q.shape
    pad_r, pad_c = (-r) % block_r, (-c) % block_c
    qp = jnp.pad(q, ((0, pad_r), (0, pad_c))) if (pad_r or pad_c) else q
    rp, cp = qp.shape

    exp_arr = jnp.asarray(exp, jnp.int32).reshape(1, 1)
    scale = jnp.exp2(exp_arr[0, 0].astype(jnp.float32))
    wmax = (jnp.max(jnp.abs(q.astype(jnp.float32))) * scale).reshape(1, 1)
    seed_arr = jnp.asarray(seed, jnp.int32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(
            _niu_kernel,
            prog_noise_scale=prog_noise_scale,
            read_noise_scale=read_noise_scale,
            drift=drift,
            n_cols=c,   # unpadded: counters must match the oracle's grid
        ),
        grid=(rp // block_r, cp // block_c),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), jnp.int8),
        interpret=interpret,
    )(seed_arr, qp, exp_arr, wmax)
    return out[:r, :c]


def niu_refresh_ref(
    q: jax.Array,
    exp: jax.Array,
    seed: jax.Array | int,
    *,
    prog_noise_scale: float = 0.1,
    read_noise_scale: float = 0.02,
    drift: float = 1.0,
) -> jax.Array:
    """Pure-jnp oracle: same counter-based RNG, no tiling."""
    r, c = q.shape
    scale = jnp.exp2(jnp.asarray(exp, jnp.int32).astype(jnp.float32))
    w = q.astype(jnp.float32) * scale
    w_max = jnp.max(jnp.abs(w))
    row = jax.lax.broadcasted_iota(jnp.uint32, (r, c), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (r, c), 1)
    counter = (row * jnp.uint32(c) + col) ^ _mix(
        jnp.asarray(seed, jnp.int32).astype(jnp.uint32)
    )
    g = _gaussian(counter, 0x1234567)
    w_noisy = w + prog_noise_scale * (0.25 * jnp.abs(w) + 0.05 * w_max) * g
    if drift != 1.0:
        w_noisy = w_noisy * drift
    if read_noise_scale > 0.0:
        g2 = _gaussian(counter, 0x7654321)
        w_noisy = w_noisy + read_noise_scale * w_max * g2
    return jnp.clip(jnp.round(w_noisy / scale), -128, 127).astype(jnp.int8)
