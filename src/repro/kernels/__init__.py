"""Pallas kernel layer: the paper's PU datapath, TPU-native.

Public surface for everything callers need -- the int8 GEMM/conv stack
(``ops``), the NIU refresh, the fused decode-stage kernels (``decode``),
the model-facing dispatch layer, the pure-jnp oracles (``ref``), and the
interpret/compiled dispatch rule (``common``).  Import from here rather
than from submodules.
"""
from repro.kernels import dispatch, ref
from repro.kernels.common import default_interpret, resolve_interpret
from repro.kernels.decode import (
    fused_decode_attention,
    fused_mlp,
    fused_qkv,
)
from repro.kernels.ops import (
    conv2d_int8,
    conv2d_int8_ref,
    im2col,
    im2col_ref,
    int8_gemm,
    int8_gemm_ref,
    niu_refresh,
)
from repro.kernels.ref import (
    decode_attention_ref,
    fused_mlp_ref,
    fused_qkv_ref,
)

__all__ = [
    "conv2d_int8",
    "conv2d_int8_ref",
    "decode_attention_ref",
    "default_interpret",
    "dispatch",
    "fused_decode_attention",
    "fused_mlp",
    "fused_qkv",
    "im2col",
    "im2col_ref",
    "int8_gemm",
    "int8_gemm_ref",
    "fused_mlp_ref",
    "fused_qkv_ref",
    "niu_refresh",
    "ref",
    "resolve_interpret",
]
