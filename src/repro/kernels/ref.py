"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests).

Semantics mirror the PU datapath (paper Fig. 2):
  int8 weights x int8 activations -> int32 accumulate (+ int32 bias on the
  first column's C-port) -> power-of-two scale/shift -> saturate to int8 ->
  optional ReLU -> optional fused residual addition -> final ReLU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import INT8_MAX, INT8_MIN, shift_round


def int8_gemm_ref(
    w: jax.Array,                      # (N, M) int8 weights
    x: jax.Array,                      # (M, P) int8 activations
    bias: Optional[jax.Array] = None,  # (N,) int32
    shift: int | jax.Array = 0,        # power-of-two rescale (right shift)
    relu: bool = False,
    residual: Optional[jax.Array] = None,  # (N, P) int8, same output grid
) -> jax.Array:
    """Oracle for the systolic-array GEMM + post-processing chain."""
    acc = jnp.dot(
        w.astype(jnp.int32), x.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)[:, None]
    y = shift_round(acc, shift)
    y = jnp.clip(y, INT8_MIN, INT8_MAX)
    if residual is not None:
        # SIMD element-wise addition unit; result saturates back to int8 and
        # passes "again by the required activation function" (SS II-A).
        y = jnp.clip(y + residual.astype(jnp.int32), INT8_MIN, INT8_MAX)
    if relu:
        y = jnp.maximum(y, 0)
    return y.astype(jnp.int8)


def im2col_ref(img: jax.Array, k: int, stride: int, pad: int) -> jax.Array:
    """Oracle for the IM2COL transform.

    ``img`` is (H, W, C) in the paper's HWC order; returns
    (OH*OW, k*k*C) patch rows with [(ki, kj) outer, C inner] layout.
    """
    h, w, c = img.shape
    imgp = jnp.pad(img, ((pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    rows = []
    for ki in range(k):
        for kj in range(k):
            sl = jax.lax.slice(
                imgp,
                (ki, kj, 0),
                (ki + (oh - 1) * stride + 1, kj + (ow - 1) * stride + 1, c),
                (stride, stride, 1),
            )  # (OH, OW, C)
            rows.append(sl.reshape(oh * ow, c))
    return jnp.concatenate(rows, axis=-1)


def conv2d_int8_ref(
    img: jax.Array,                    # (H, W, Cin) int8
    w4d: jax.Array,                    # (k, k, Cin, Cout) int8
    bias: Optional[jax.Array] = None,  # (Cout,) int32
    stride: int = 1,
    pad: int = 0,
    shift: int | jax.Array = 0,
    relu: bool = False,
    residual: Optional[jax.Array] = None,  # (OH, OW, Cout) int8
) -> jax.Array:
    """End-to-end conv oracle via XLA's conv on int32 (layout-independent

    cross-check of im2col + gemm composition).
    """
    lhs = img.astype(jnp.int32)[None].transpose(0, 3, 1, 2)        # NCHW
    rhs = w4d.astype(jnp.int32).transpose(3, 2, 0, 1)              # OIHW
    acc = jax.lax.conv_general_dilated(
        lhs, rhs, (stride, stride), [(pad, pad), (pad, pad)],
        preferred_element_type=jnp.int32,
    )[0].transpose(1, 2, 0)                                        # (OH,OW,Cout)
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)
    y = jnp.clip(shift_round(acc, shift), INT8_MIN, INT8_MAX)
    if residual is not None:
        y = jnp.clip(y + residual.astype(jnp.int32), INT8_MIN, INT8_MAX)
    if relu:
        y = jnp.maximum(y, 0)
    return y.astype(jnp.int8)
