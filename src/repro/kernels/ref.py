"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests).

Semantics mirror the PU datapath (paper Fig. 2):
  int8 weights x int8 activations -> int32 accumulate (+ int32 bias on the
  first column's C-port) -> power-of-two scale/shift -> saturate to int8 ->
  optional ReLU -> optional fused residual addition -> final ReLU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import INT8_MAX, INT8_MIN, shift_round


def int8_gemm_ref(
    w: jax.Array,                      # (N, M) int8 weights
    x: jax.Array,                      # (M, P) int8 activations
    bias: Optional[jax.Array] = None,  # (N,) int32
    shift: int | jax.Array = 0,        # power-of-two rescale (right shift)
    relu: bool = False,
    residual: Optional[jax.Array] = None,  # (N, P) int8, same output grid
) -> jax.Array:
    """Oracle for the systolic-array GEMM + post-processing chain."""
    acc = jnp.dot(
        w.astype(jnp.int32), x.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)[:, None]
    y = shift_round(acc, shift)
    y = jnp.clip(y, INT8_MIN, INT8_MAX)
    if residual is not None:
        # SIMD element-wise addition unit; result saturates back to int8 and
        # passes "again by the required activation function" (SS II-A).
        y = jnp.clip(y + residual.astype(jnp.int32), INT8_MIN, INT8_MAX)
    if relu:
        y = jnp.maximum(y, 0)
    return y.astype(jnp.int8)


def im2col_ref(img: jax.Array, k: int, stride: int, pad: int) -> jax.Array:
    """Oracle for the IM2COL transform.

    ``img`` is (H, W, C) in the paper's HWC order; returns
    (OH*OW, k*k*C) patch rows with [(ki, kj) outer, C inner] layout.
    """
    h, w, c = img.shape
    imgp = jnp.pad(img, ((pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    rows = []
    for ki in range(k):
        for kj in range(k):
            sl = jax.lax.slice(
                imgp,
                (ki, kj, 0),
                (ki + (oh - 1) * stride + 1, kj + (ow - 1) * stride + 1, c),
                (stride, stride, 1),
            )  # (OH, OW, C)
            rows.append(sl.reshape(oh * ow, c))
    return jnp.concatenate(rows, axis=-1)


def conv2d_int8_ref(
    img: jax.Array,                    # (H, W, Cin) int8
    w4d: jax.Array,                    # (k, k, Cin, Cout) int8
    bias: Optional[jax.Array] = None,  # (Cout,) int32
    stride: int = 1,
    pad: int = 0,
    shift: int | jax.Array = 0,
    relu: bool = False,
    residual: Optional[jax.Array] = None,  # (OH, OW, Cout) int8
) -> jax.Array:
    """End-to-end conv oracle via XLA's conv on int32 (layout-independent

    cross-check of im2col + gemm composition).
    """
    lhs = img.astype(jnp.int32)[None].transpose(0, 3, 1, 2)        # NCHW
    rhs = w4d.astype(jnp.int32).transpose(3, 2, 0, 1)              # OIHW
    acc = jax.lax.conv_general_dilated(
        lhs, rhs, (stride, stride), [(pad, pad), (pad, pad)],
        preferred_element_type=jnp.int32,
    )[0].transpose(1, 2, 0)                                        # (OH,OW,Cout)
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)
    y = jnp.clip(shift_round(acc, shift), INT8_MIN, INT8_MAX)
    if residual is not None:
        y = jnp.clip(y + residual.astype(jnp.int32), INT8_MIN, INT8_MAX)
    if relu:
        y = jnp.maximum(y, 0)
    return y.astype(jnp.int8)


# --------------------------------------------------- decode-stage oracles --
# Standalone jnp mirrors of the models-layer decode math (project_qkv +
# apply_rope, _decode_attention + project_out, mlp_apply) so the kernels
# package stays model-independent while tests pin both implementations to
# one reference.


def fused_qkv_ref(
    x: jax.Array,                       # (B, d)
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    bq: Optional[jax.Array] = None,
    bk: Optional[jax.Array] = None,
    bv: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope: bool = True,
    theta: float = 1e4,
):
    """Oracle for :func:`decode.fused_qkv` (projection + bias + RoPE)."""
    b = x.shape[0]
    dt = x.dtype

    def proj(w, bias, h):
        y = x @ w.astype(dt)
        if bias is not None:
            y = y + bias.astype(dt)
        return y.reshape(b, h, head_dim)

    q = proj(wq, bq, n_heads)
    k = proj(wk, bk, n_kv_heads)
    v = proj(wv, bv, n_kv_heads)
    if rope:
        half = head_dim // 2
        freqs = 1.0 / (
            theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
        )
        ang = positions[:, None].astype(jnp.float32) * freqs
        cos = jnp.cos(ang)[:, None, :]
        sin = jnp.sin(ang)[:, None, :]

        def rot(t):
            tf = t.astype(jnp.float32)
            t1, t2 = tf[..., :half], tf[..., half:]
            return jnp.concatenate(
                [t1 * cos - t2 * sin, t2 * cos + t1 * sin], axis=-1
            ).astype(dt)

        q, k = rot(q), rot(k)
    return q, k, v


def decode_attention_ref(
    q: jax.Array,                       # (B, Hq, hd) post-rope, unscaled
    k: jax.Array,                       # (B, Sk, Hkv, hd)
    v: jax.Array,
    wo: jax.Array,                      # (Hq*hd, d)
    bo: Optional[jax.Array] = None,
    *,
    q_positions: jax.Array,
    kv_valid_len: Optional[jax.Array] = None,
    window: Optional[int] = None,
    window_arr: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    causal: bool = True,
) -> jax.Array:
    """Oracle for :func:`decode.fused_decode_attention` (attention + wo)."""
    b, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    dt = q.dtype
    scale = 1.0 / (hd ** 0.5)
    qg = (q * scale).reshape(b, hkv, g, hd)
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k, preferred_element_type=jnp.float32
    )
    if kv_positions is not None:
        col = jnp.broadcast_to(
            kv_positions.astype(jnp.int32).reshape(-1, sk), (b, sk)
        )
        valid = col >= 0
    else:
        col = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))
        limit = jnp.broadcast_to(
            jnp.asarray(
                sk if kv_valid_len is None else kv_valid_len, jnp.int32
            ),
            (b,),
        )
        valid = col < limit[:, None]
    if causal:
        row = q_positions.astype(jnp.int32)[:, None]
        if window_arr is not None:
            win = jnp.asarray(window_arr, jnp.int32)
        elif window is not None:
            win = jnp.asarray(window, jnp.int32)
        else:
            win = jnp.asarray(jnp.iinfo(jnp.int32).max, jnp.int32)
        valid = valid & (col <= row) & (col > row - win)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    ctx = jnp.einsum(
        "bkgs,bskd->bkgd", (p / denom).astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    ).astype(dt)
    y = ctx.reshape(b, hq * hd) @ wo.astype(dt)
    if bo is not None:
        y = y + bo.astype(dt)
    return y


def fused_mlp_ref(
    x: jax.Array,                       # (B, d)
    w_up: jax.Array,
    w_gate: Optional[jax.Array] = None,
    b_up: Optional[jax.Array] = None,
    w_down: Optional[jax.Array] = None,
    b_down: Optional[jax.Array] = None,
    *,
    act: str = "swiglu",
) -> jax.Array:
    """Oracle for :func:`decode.fused_mlp` (mirrors ``models.mlp.mlp_apply``)."""
    dt = x.dtype
    g = x @ (w_gate if w_gate is not None else w_up).astype(dt)
    if b_up is not None:
        g = g + b_up.astype(dt)
    if act == "swiglu":
        h = jax.nn.silu(g) * (x @ w_up.astype(dt))
    elif act == "gelu":
        h = jax.nn.gelu(g)
    elif act == "sq_relu":
        r = jax.nn.relu(g)
        h = r * r
    else:
        raise ValueError(act)
    y = h @ w_down.astype(dt)
    if b_down is not None:
        y = y + b_down.astype(dt)
    return y
