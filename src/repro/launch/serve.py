"""Serving launcher: batched requests against any assigned architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \
        --requests 16 --max-new 24 [--stream] [--multi-pu K] [--aimc]

The decode loop is device-resident by default (fused sample-append
blocks, bucketed batched prefill -- DESIGN.md SS7); ``--host-sampling``
falls back to the legacy host loop (per-token decode jit + numpy
sampling), ``--prefill-buckets 16,32,...`` overrides the power-of-two
prompt-length ladder, and ``--decode-block`` caps the fused rounds per
host sync.  ``--stream`` plans host->HBM weight streaming with the
paper's two-phase scheduler and prints the plan summary (stall
reduction, utilization);
``--multi-pu K`` partitions the model's GEMM sequence across K PU
profiles (repro.plan.partition) and, after the decode loop drains,
*executes* the partition through the stage-parallel streaming runtime
(runtime.pipeline_exec) -- the printed stats carry both the analytic
pipeline numbers and the measured (executed) throughput and bubble.
The executed microbatch depth (and handoff queue depth) is auto-tuned
against ``--target-bubble`` from the measured bubble by default; pass
an explicit ``--microbatches M`` to pin it.  ``--plan-search
beam|anneal`` upgrades the streaming/partition planners' adaptive
phase to schedule search (deterministic via ``--plan-search-seed``).
``--decode-kernels`` swaps the per-token hot ops for the fused Pallas
decode kernels (kernels/decode.py) while keeping the composed-XLA loop
as the A/B reference.  ``--aimc`` enables the SS VI noise-injection
emulation, refreshing weights with fresh PCM-style noise every round.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

import jax

from repro.analysis import sanitize
from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.core.aimc import AIMCNoiseModel
from repro.core.pu import host_offload_config, tpu_v5e_config
from repro.models import api as model_api
from repro.plan import SearchConfig
from repro.runtime.serving import ServeConfig, ServingEngine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--host-sampling", action="store_true",
                    help="legacy host-loop rounds (per-token decode jit, "
                         "numpy sampling, lane-isolated prefill) instead "
                         "of the device-resident decode loop")
    ap.add_argument("--prefill-buckets", default=None, metavar="N,N,...",
                    help="comma-separated prompt-length buckets for "
                         "batched prefill (default: power-of-two ladder "
                         "16,32,... capped at max_len)")
    ap.add_argument("--decode-block", type=int, default=32, metavar="R",
                    help="max fused decode rounds per host sync "
                         "(power-of-two blocks up to R; default 32)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip pre-compiling the prefill-bucket/decode-"
                         "block trace grid at startup")
    ap.add_argument("--stream", action="store_true",
                    help="plan weight streaming (two-phase scheduler)")
    ap.add_argument("--multi-pu", type=int, default=0, metavar="K",
                    help="partition the model across K PU profiles "
                         "(alternating host-offload / v5e) and run true "
                         "per-stage decode: every serving round streams "
                         "each stage's model-layer slice through the "
                         "stage pipeline; K=1 falls back to the "
                         "single-PU streaming path")
    ap.add_argument("--no-stage-decode", action="store_true",
                    help="with --multi-pu, keep the fused single-PU "
                         "decode loop and only attach the partition "
                         "analytically (parity-debugging escape hatch)")
    ap.add_argument("--microbatches", type=int, default=0, metavar="M",
                    help="lane-group / microbatch depth M with "
                         "--multi-pu: splits the decode slot batch into "
                         "M lane groups for the overlapped staged loop "
                         "and sets the executed tile pipeline's depth; "
                         "1 = serial reference, 0 (default) auto-tunes "
                         "M and the handoff queue depth against "
                         "--target-bubble using the executed bubble "
                         "measurement")
    ap.add_argument("--target-bubble", type=float, default=0.10,
                    help="target fill/drain bubble fraction for the "
                         "microbatch auto-tuner (default 0.10)")
    ap.add_argument("--plan-search", default="heuristic",
                    choices=["heuristic", "beam", "anneal"],
                    help="schedule-search strategy for the streaming/"
                         "partition planners (beam/anneal spend the "
                         "vectorized planner's budget on stall search)")
    ap.add_argument("--plan-search-seed", type=int, default=0,
                    help="deterministic seed for --plan-search anneal")
    ap.add_argument("--decode-kernels", action="store_true",
                    help="fused Pallas decode kernels (QKV+RoPE, GQA "
                         "attention + out-projection, gated MLP) on the "
                         "per-token hot path; default keeps the "
                         "composed-XLA decode as the A/B reference")
    ap.add_argument("--aimc", action="store_true",
                    help="AIMC noise emulation (SS VI NIU)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)

    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))

    serve_cfg = ServeConfig(
        max_batch=args.max_batch,
        max_len=args.prompt_len + args.max_new + 8,
        max_new_tokens=args.max_new,
        temperature=args.temperature,
        seed=args.seed,
        host_sampling=args.host_sampling,
        prefill_buckets=(
            tuple(int(b) for b in args.prefill_buckets.split(","))
            if args.prefill_buckets
            else None
        ),
        max_decode_block=args.decode_block,
        stream_pu=host_offload_config() if args.stream else None,
        stream_pus=(
            [
                host_offload_config() if k % 2 == 0 else tpu_v5e_config()
                for k in range(args.multi_pu)
            ]
            if args.multi_pu
            else None
        ),
        stage_decode=not args.no_stage_decode,
        decode_microbatches=args.microbatches,
        decode_kernels=args.decode_kernels,
        aimc=AIMCNoiseModel() if args.aimc else None,
        plan_search=(
            SearchConfig(
                strategy=args.plan_search, seed=args.plan_search_seed
            )
            if args.plan_search != "heuristic"
            else None
        ),
        target_bubble=args.target_bubble,
    )
    engine = ServingEngine(cfg, params, serve_cfg)
    if not args.no_warmup:
        engine.warmup()

    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32)
        engine.submit(prompt)

    engine.run_until_drained()
    if engine.partitioned_plan is not None:
        # --multi-pu decode executes the partition for real: the
        # stage-parallel runtime streams every stage's tiles in plan
        # issue order and measures pipeline throughput + fill bubble.
        # M=0 auto-tunes depth (and handoff queue depth) against the
        # requested bubble target from the executed measurement.
        engine.execute_partition(
            n_microbatches=args.microbatches or None
        )
    stats = engine.stats()
    print(json.dumps(stats, indent=1, default=float))
    if sanitize.enabled():
        violations = sanitize.lock_violations()
        for v in violations:
            print(f"sanitize: {v.kind} violation {v.first}->{v.second or '?'} at {v.site}")
        if violations:
            return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
