"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

On this CPU container ``--smoke`` shrinks the config to the reduced family
variant; on a real fleet the same entry point runs the full config on the
production mesh (``--mesh data,model=16,16``).  Fault tolerance, checkpoint
auto-resume, straggler detection and (optionally) gradient compression are
in the loop itself (runtime/train_loop.py).
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_config, smoke_variant
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh, single_device_mesh
from repro.optim import AdamWConfig
from repro.parallel.sharding import NAMED_RULES
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig


def parse_mesh(spec: str):
    """'data,model=2,2' -> mesh with those axes/sizes."""
    axes, sizes = spec.split("=")
    axes = tuple(a.strip() for a in axes.split(","))
    sizes = tuple(int(s) for s in sizes.split(","))
    return make_mesh(sizes, axes)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES_BY_NAME))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config + small shape (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--mesh", default=None, help="e.g. 'data,model=16,16'")
    ap.add_argument("--rules", default="fsdp_tp", choices=sorted(NAMED_RULES))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="failure-injection drill: crash at this step")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES_BY_NAME[args.shape]
    if args.smoke:
        cfg = smoke_variant(cfg)
        shape = ShapeConfig(
            "smoke",
            seq_len=args.seq_len or 128,
            global_batch=args.batch or 8,
            kind="train",
        )
    elif args.seq_len or args.batch:
        shape = ShapeConfig(
            shape.name,
            seq_len=args.seq_len or shape.seq_len,
            global_batch=args.batch or shape.global_batch,
            kind="train",
        )

    mesh = parse_mesh(args.mesh) if args.mesh else single_device_mesh()
    rules = NAMED_RULES[args.rules]

    loop = TrainLoop(
        cfg,
        shape,
        mesh,
        rules,
        TrainLoopConfig(
            steps=args.steps,
            ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir,
            metrics_path=args.metrics,
            crash_at_step=args.crash_at,
            seed=args.seed,
        ),
        opt_cfg=AdamWConfig(lr=args.lr, total_steps=max(args.steps, 10)),
    )
    result = loop.run()
    print(json.dumps({
        "arch": args.arch,
        "final_step": result["final_step"],
        "final_loss": result["final_loss"],
        "straggler_events": result["straggler_events"],
        "devices": jax.device_count(),
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
