import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Tests may shrink the placeholder device fleet (must happen pre-jax-init).
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run: lower + compile every (architecture x input shape)

cell on the production meshes, prove the memory fits, and extract the
roofline terms (FLOPs / bytes / collective bytes) from the compiled
artifact.  This is how the distribution config is proven coherent without
real hardware (no device allocation -- inputs are ShapeDtypeStructs).

Usage:
    python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--rules fsdp_tp]
Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>[__rules].json.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.analysis import roofline as rl
from repro.analysis.jaxpr_cost import step_flops
from repro.configs import (
    ARCH_IDS,
    SHAPES_BY_NAME,
    cell_applicable,
    get_config,
)
from repro.launch.mesh import make_production_mesh
from repro.parallel.sharding import NAMED_RULES
from repro.runtime.steps import make_step

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    from repro.configs import get_config as _gc
    from repro.launch.mesh import make_production_mesh as _mesh
    from repro.parallel.sharding import RULES_FSDP_TP

    cfg = _gc(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = _mesh()
    _, specs, _, _ = make_step(cfg, shape, mesh, RULES_FSDP_TP)
    return specs


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    rules_name: str = "fsdp_tp",
    save: bool = True,
    master_weights: bool = False,
    kv_quant: bool = False,
    kv_ring: bool = False,
    mesh_override=None,          # (data, model) sizes; e.g. (8, 8) for a
                                 # 64-chip independent serving slice
    accum_steps: int = 1,        # gradient accumulation (train cells)
) -> dict:
    import dataclasses

    cfg = get_config(arch)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if kv_ring:
        cfg = dataclasses.replace(cfg, kv_ring=True)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "rules": rules_name
        + ("+mw" if master_weights else "")
        + ("+kvq" if kv_quant else "")
        + ("+ring" if kv_ring else "")
        + (f"+acc{accum_steps}" if accum_steps > 1 else ""),
        "kind": shape.kind,
    }

    ok, why = cell_applicable(cfg, shape)
    if not ok:
        record.update(status="skipped", reason=why)
        return _finish(record, save)

    rules = NAMED_RULES[rules_name]
    if mesh_override:
        from repro.launch.mesh import make_mesh

        mesh = make_mesh(mesh_override, ("data", "model"))
        record["mesh"] = "pod" + "x".join(str(d) for d in mesh_override)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size

    opt_cfg = None
    if master_weights:
        from repro.optim import AdamWConfig

        opt_cfg = AdamWConfig(master_weights=True)

    t0 = time.time()
    try:
        step_fn, specs, in_sh, out_sh = make_step(
            cfg, shape, mesh, rules, opt_cfg=opt_cfg, accum_steps=accum_steps
        )
        # decode: donate the KV/SSM cache so the update aliases in place --
        # the output cache write then costs one token-slice, not the full
        # buffer (memory_analysis reports it as alias_bytes).
        donate = (1,) if shape.kind == "decode" else ()
        with mesh:
            lowered = jax.jit(
                step_fn, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate,
            ).lower(*specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()      # proves it fits
            cost = compiled.cost_analysis()       # raw XLA view (recorded)
            # jax < 0.5 returns one properties dict per program in a list
            if isinstance(cost, list):
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
            # Scan-aware global FLOPs from the jaxpr (see analysis docstring)
            flops_global = step_flops(step_fn, specs)
    except Exception as e:  # a failure here is a bug in our sharding
        record.update(
            status="failed",
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
        )
        return _finish(record, save)

    colls = rl.parse_collectives(hlo)
    terms = rl.roofline(
        flops_global, mem, colls, rl.model_flops_global(cfg, shape), n_dev
    )

    record.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        devices=n_dev,
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "total_per_device": (
                mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes
            ),
        },
        cost_xla={k: v for k, v in cost.items() if "bytes" in k or "flops" in k},
        flops_global_jaxpr=flops_global,
        collectives={
            "bytes": colls.op_bytes,
            "counts": colls.op_counts,
            "total": colls.total_bytes,
            "wire": colls.wire_bytes,
        },
        roofline=terms.as_dict(),
        params_global=cfg.param_count(),
        params_active=cfg.active_param_count(),
    )
    return _finish(record, save)


def _finish(record: dict, save: bool) -> dict:
    if save:
        ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
        suffix = "" if record["rules"] == "fsdp_tp" else f"__{record['rules']}"
        path = ARTIFACT_DIR / (
            f"{record['arch']}__{record['shape']}__{record['mesh']}{suffix}.json"
        )
        path.write_text(json.dumps(record, indent=1))
        record["artifact"] = str(path)
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES_BY_NAME))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="fsdp_tp", choices=sorted(NAMED_RULES))
    ap.add_argument("--master-weights", action="store_true",
                    help="bf16 params + f32 master in opt state (train)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache with power-of-two scales (decode)")
    ap.add_argument("--kv-ring", action="store_true",
                    help="window-sized ring-buffer KV cache (pure-SWA archs)")
    ap.add_argument("--mesh", default=None,
                    help="override mesh as 'data,model' (e.g. '8,8' = one "
                         "64-chip serving slice of the pod)")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES_BY_NAME:
                cells.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        suffix = "" if args.rules == "fsdp_tp" else f"__{args.rules}"
        path = ARTIFACT_DIR / f"{arch}__{shape}__{mesh_name}{suffix}.json"
        if args.skip_existing and path.exists():
            prev = json.loads(path.read_text())
            if prev.get("status") in ("ok", "skipped"):
                print(f"[skip-existing] {arch} {shape} {prev['status']}")
                continue
        rec = run_cell(
            arch, shape, args.multi_pod, args.rules,
            master_weights=args.master_weights,
            kv_quant=args.kv_quant,
            kv_ring=args.kv_ring,
            mesh_override=(
                tuple(int(x) for x in args.mesh.split(","))
                if args.mesh else None
            ),
            accum_steps=args.accum,
        )
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(
                f"[ok] {arch:22s} {shape:12s} {mesh_name}  "
                f"compile={rec['compile_s']:.0f}s  "
                f"mem/dev={rec['memory']['total_per_device']/2**30:.2f}GiB  "
                f"terms(ms): c={r['compute_s']*1e3:.2f} "
                f"m={r['memory_s']*1e3:.2f} n={r['collective_s']*1e3:.2f} "
                f"dom={r['dominant']}"
            )
        elif rec["status"] == "skipped":
            print(f"[skipped] {arch} {shape}: {rec['reason']}")
        else:
            failures += 1
            print(f"[FAILED] {arch} {shape}: {rec['error']}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
