"""Launchers: production mesh construction, the multi-pod dry-run,

training and serving entry points.
"""
