"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state -- jax locks the device count on first init, and
only launch/dryrun.py may set the 512-placeholder-device XLA flag.
"""
from __future__ import annotations

import enum
import inspect

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: sharding-in-types axis kinds
    from jax.sharding import AxisType
except ImportError:  # older jax: every mesh axis is implicitly "auto"
    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

# jax.make_mesh only grew `axis_types` alongside AxisType itself; probe the
# signature once so both call sites below stay version-agnostic.
_MAKE_MESH_HAS_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters
)


def _make_mesh(shape, axes) -> Mesh:
    if _MAKE_MESH_HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(tuple(shape), tuple(axes))


def abstract_mesh(shape, axes):
    """Device-free mesh for shape/sharding reasoning (tests, dry-run).

    jax >= 0.5 spells it ``AbstractMesh(axis_sizes, axis_names)``; 0.4.x
    wanted one ``((name, size), ...)`` tuple.  Try modern first.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(tuple(axes), tuple(shape))))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single-pod (data=16, model=16) = 256 chips, or two pods = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh (tests / elastic re-shard / hillclimb variants)."""
    return _make_mesh(shape, axes)


def single_device_mesh() -> Mesh:
    return make_mesh((1,), ("data",))


def stage_submeshes(mesh: Mesh, n_stages: int):
    """Split a mesh's devices into ``n_stages`` submeshes for pipeline
    stages, preserving the trailing axes so partitioned streaming
    composes with tensor sharding.

    The leading axis is divided when it splits evenly (e.g. a
    ``(data=4, model=4)`` mesh into 2 stages of ``(data=2, model=4)``);
    otherwise the flat device list is divided and each group becomes a
    1-D ``("model",)`` submesh (tensor sharding inside the stage).  When
    the device count cannot be split K ways (notably 1-device CPU), all
    stages *share* the full mesh -- returned as K references with
    ``shared=True`` -- so callers can still place per-stage computations
    without special-casing.

    Returns ``(submeshes, shared)``.
    """
    import numpy as np

    devices = np.asarray(mesh.devices)
    lead = devices.shape[0]
    total = devices.size
    if n_stages <= 1:
        return [mesh] * max(n_stages, 1), False
    if lead % n_stages == 0 and lead >= n_stages:
        groups = np.split(devices, n_stages, axis=0)
        return (
            [Mesh(g, mesh.axis_names) for g in groups],
            False,
        )
    if total % n_stages == 0 and total >= n_stages:
        flat = devices.reshape(-1)
        groups = np.split(flat, n_stages)
        return [Mesh(g, ("model",)) for g in groups], False
    return [mesh] * n_stages, True
