"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state -- jax locks the device count on first init, and
only launch/dryrun.py may set the 512-placeholder-device XLA flag.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single-pod (data=16, model=16) = 256 chips, or two pods = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh (tests / elastic re-shard / hillclimb variants)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes), axis_types=(AxisType.Auto,) * len(axes)
    )


def single_device_mesh() -> Mesh:
    return make_mesh((1,), ("data",))
