"""OLMo-1B [dense]: 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304,

non-parametric LayerNorm [arXiv:2402.00838].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="lm",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    rope_theta=1e4,
    norm="nonparam_ln",
    mlp="swiglu",
    tie_embeddings=True,
)
