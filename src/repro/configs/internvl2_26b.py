"""InternVL2-26B [vlm]: InternViT front-end (STUB) + InternLM2-20B backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 [arXiv:2404.16821].
The vision tower provides precomputed patch embeddings (256 patches) that
overwrite the leading token slots.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    rope_theta=1e6,
    norm="rmsnorm",
    mlp="swiglu",
    vision_patches=256,
)
