"""Zamba2-1.2B [hybrid]: 38 Mamba2 layers (d_model=2048, state=64) + a

shared attention block (32H MHA, d_ff=8192) applied every 6 layers
[arXiv:2411.15242].  long_500k RUNS (SSM + periodic shared attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    rope_theta=1e4,
    norm="rmsnorm",
    mlp="swiglu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_attn_every=6,
    tie_embeddings=True,
)
