"""Nemotron-4-15B [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576

vocab=256000, squared-ReLU MLP [arXiv:2402.16819].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="lm",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    rope_theta=1e4,
    norm="layernorm",
    mlp="sq_relu",
)
