"""Gemma3-12B [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360

vocab=262144, 5 local : 1 global attention (window 1024), 128k context
[hf:google/gemma-3-1b-pt].  long_500k is SKIPPED: the 1-in-6 global layers
attend over the full cache, making the arch effectively full-attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="lm",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab=262144,
    head_dim=256,
    rope_theta=1e6,
    window=1024,
    global_every=6,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=True,
)
