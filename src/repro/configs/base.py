"""Model and shape configuration dataclasses + the assigned shape sets."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # 'lm' | 'moe' | 'ssm' | 'hybrid' | 'encdec' | 'vlm'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # attention flavour
    rope_theta: float = 1e4
    window: Optional[int] = None        # sliding-window size (tokens)
    global_every: Optional[int] = None  # gemma3: every Nth layer is global
    attn_bias: bool = False
    mlp_bias: bool = False
    norm: str = "rmsnorm"       # 'rmsnorm' | 'layernorm' | 'nonparam_ln'
    mlp: str = "swiglu"         # 'swiglu' | 'gelu' | 'sq_relu'
    tie_embeddings: bool = False
    pos_embed: str = "rope"     # 'rope' | 'learned' | 'sinusoidal'
    max_position: int = 524288  # size of learned position tables if used
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # dispatch mode: 'auto' picks local (shard_map) dispatch when the token
    # traffic is large AND the expert bank is small enough to replicate per
    # device group; 'local'/'global' force a mode (see models/mlp.py)
    moe_dispatch: str = "auto"
    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (Zamba2): shared attention block applied every N ssm layers
    hybrid_attn_every: int = 0
    # encoder-decoder (Whisper): encoder depth + stub frame count
    encoder_layers: int = 0
    encoder_frames: int = 1500
    # VLM stub front-end: number of precomputed patch embeddings
    vision_patches: int = 0
    dtype: str = "bfloat16"
    # attention kv-chunk for the streaming-softmax scan
    attn_chunk: int = 512
    # INT8 KV cache with power-of-two scales (the paper's PU arithmetic
    # applied to decode-state traffic; halves the memory roofline term of
    # decode cells -- EXPERIMENTS.md SSPerf)
    kv_quant: bool = False
    # Ring-buffer KV cache for pure sliding-window models: allocate
    # min(max_len, window) slots written round-robin -- the paper's
    # adaptive-memory idea applied to decode state (8x smaller at 32k for
    # mixtral's 4k window, 128x at 500k).  Only valid when window is set
    # and there are no global layers.
    kv_ring: bool = False
    # Fused Pallas decode kernels (kernels/decode.py) on the single-token
    # serving hot path: QKV+RoPE, GQA attention + output projection, and
    # the (gated-)MLP each run as one weight-streaming kernel instead of
    # composed XLA primitives.  Threaded from ServeConfig.decode_kernels
    # by the serving engine; dense (non-MoE) MLPs only (kernels/dispatch.py).
    decode_kernels: bool = False
    # remat: 'none' | 'layer'
    remat: str = "layer"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Total parameters (analytic; used for roofline MODEL_FLOPS)."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.family in ("ssm",):
            attn = 0
        mlp_mats = 3 if self.mlp == "swiglu" else 2
        if self.is_moe:
            mlp = self.n_experts * mlp_mats * d * f + d * self.n_experts
        else:
            mlp = mlp_mats * d * f
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            din, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            g = 1  # single B/C group
            ssm = (
                d * (2 * din + 2 * g * ns + nh)   # in_proj (z,x,B,C,dt)
                + self.ssm_conv * (din + 2 * g * ns)
                + din * d                          # out_proj
                + 2 * nh + din                     # A, D, norm
            )
        if self.family == "ssm":
            return emb + l * (ssm + 2 * d) + d
        if self.family == "hybrid":
            shared = attn + mlp_mats * d * self.d_ff + 2 * d
            n_attn = l // max(self.hybrid_attn_every, 1)
            return emb + l * (ssm + 2 * d) + shared + d
        core = l * (attn + mlp + 2 * d) + d
        if self.family == "encdec":
            enc = self.encoder_layers * (attn + mlp + 2 * d) + d
            cross = self.n_layers * (attn + d)
            return emb + core + enc + cross
        return emb + core

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp_mats = 3 if self.mlp == "swiglu" else 2
        dense_like = self.param_count() - self.n_layers * self.n_experts * mlp_mats * d * f
        return dense_like + self.n_layers * self.top_k * mlp_mats * d * f


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train"),
    ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if not cfg.is_moe else 64,
        vocab=512,
        max_position=1024,
    )
    if cfg.is_moe:
        changes.update(n_experts=min(cfg.n_experts, 4), top_k=min(cfg.top_k, 2))
    if cfg.family in ("ssm", "hybrid"):
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        changes.update(n_layers=5, hybrid_attn_every=2)
    if cfg.family == "encdec":
        changes.update(encoder_layers=2, encoder_frames=16)
    if cfg.family == "vlm":
        changes.update(vision_patches=8)
    if cfg.window:
        changes.update(window=64)
    return dataclasses.replace(cfg, **changes)
