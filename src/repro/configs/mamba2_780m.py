"""Mamba2-780M [ssm]: 48L d_model=1536, attention-free, ssm_state=128,

SSD (state-space duality) [arXiv:2405.21060].  long_500k RUNS
(sub-quadratic by construction).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,     # unused by the ssm family (kept >0 for head_dim init)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    head_dim=64,
    norm="rmsnorm",
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
)
