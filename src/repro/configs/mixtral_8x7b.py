"""Mixtral-8x7B [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,

vocab=32000, 8 experts top-2, sliding-window attention [arXiv:2401.04088].
SWA makes long_500k decode sub-quadratic (window 4096) -> cell runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    rope_theta=1e6,
    window=4096,
    norm="rmsnorm",
    mlp="swiglu",
    n_experts=8,
    top_k=2,
)
