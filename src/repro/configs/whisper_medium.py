"""Whisper-medium [audio enc-dec]: 24L enc + 24L dec, d_model=1024 16H (MHA)

d_ff=4096 vocab=51865 [arXiv:2212.04356].  Conv/mel front-end is a STUB:
input_specs provide precomputed frame embeddings (B, 1500, D).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    norm="layernorm",
    mlp="gelu",
    attn_bias=True,
    mlp_bias=True,
    pos_embed="learned",
    encoder_layers=24,
    encoder_frames=1500,
    tie_embeddings=True,
)
