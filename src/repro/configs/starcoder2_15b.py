"""StarCoder2-15B [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576

vocab=49152, RoPE, attention biases, plain-GELU MLP [arXiv:2402.19173].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="lm",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    rope_theta=1e5,
    norm="layernorm",
    mlp="gelu",
    attn_bias=True,
    mlp_bias=True,
)
