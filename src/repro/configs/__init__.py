"""Config registry: ``get_config(arch_id)`` for every assigned architecture

(+ the paper's own ResNets), and the assigned shape sets.

Cell applicability (DESIGN.md SS4): ``long_500k`` requires sub-quadratic
attention and runs only for ssm/hybrid/SWA architectures; every other
(arch x shape) cell runs.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME, smoke_variant
from repro.configs import (
    gemma3_12b,
    granite_moe_3b_a800m,
    internvl2_26b,
    mamba2_780m,
    mixtral_8x7b,
    nemotron_4_15b,
    olmo_1b,
    starcoder2_15b,
    whisper_medium,
    zamba2_1_2b,
)
from repro.configs.resnet import RESNET18, RESNET50, ResNetConfig

_CONFIGS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        internvl2_26b.CONFIG,
        granite_moe_3b_a800m.CONFIG,
        mixtral_8x7b.CONFIG,
        starcoder2_15b.CONFIG,
        gemma3_12b.CONFIG,
        olmo_1b.CONFIG,
        nemotron_4_15b.CONFIG,
        whisper_medium.CONFIG,
        zamba2_1_2b.CONFIG,
        mamba2_780m.CONFIG,
    )
}

ARCH_IDS: Tuple[str, ...] = tuple(_CONFIGS)

RESNETS = {"resnet18": RESNET18, "resnet50": RESNET50}


def get_config(name: str) -> ModelConfig:
    if name not in _CONFIGS:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_CONFIGS)}")
    return _CONFIGS[name]


def long_context_capable(cfg: ModelConfig) -> bool:
    """Sub-quadratic attention -> long_500k cell runs (DESIGN.md SS4)."""
    if cfg.family in ("ssm", "hybrid"):
        return True
    # pure sliding-window (no global layers) is sub-quadratic
    return cfg.window is not None and not cfg.global_every


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for one (arch x shape) cell."""
    if shape.name == "long_500k" and not long_context_capable(cfg):
        return False, "full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""


def all_cells() -> List[Tuple[ModelConfig, ShapeConfig, bool, str]]:
    out = []
    for name in ARCH_IDS:
        cfg = get_config(name)
        for shape in SHAPES:
            ok, why = cell_applicable(cfg, shape)
            out.append((cfg, shape, ok, why))
    return out


__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "SHAPES_BY_NAME",
    "ARCH_IDS",
    "RESNETS",
    "ResNetConfig",
    "get_config",
    "smoke_variant",
    "long_context_capable",
    "cell_applicable",
    "all_cells",
]
