"""The paper's own evaluation models: INT8 ResNet-18 / ResNet-50 on

224x224 ImageNet inputs (SS V).  These are CNN configs consumed by
models/resnet.py and the PU simulator, not ModelConfig instances.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str
    variant: int          # 18 | 50
    image_size: int = 224
    num_classes: int = 1000


RESNET18 = ResNetConfig(name="resnet18", variant=18)
RESNET50 = ResNetConfig(name="resnet50", variant=50)
