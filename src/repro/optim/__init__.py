from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cast_params_bf16,
    cosine_schedule,
    global_norm,
    opt_state_axes,
)

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cast_params_bf16",
    "cosine_schedule",
    "global_norm",
    "opt_state_axes",
]
