"""AdamW with decoupled weight decay, global-norm clipping and a cosine

schedule -- written directly in JAX (no optax in this environment).  The
moment tensors shard exactly like their parameters (ZeRO): opt_state_axes
mirrors the model's logical-axes pytree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    # Mixed-precision at fleet scale: params live in bf16 (halving ZeRO-3
    # parameter all-gather bytes -- see EXPERIMENTS.md SSPerf), while a f32
    # master copy lives in the (sharded) optimizer state.
    master_weights: bool = False


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params: Any, master_weights: bool = False) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params
        )
    return state


def opt_state_axes(param_axes: Any, master_weights: bool = False) -> dict:
    """Logical axes for the optimizer state (moments shard like params)."""
    out = {"m": param_axes, "v": param_axes, "step": ()}
    if master_weights:
        out["master"] = param_axes
    return out


def cast_params_bf16(params: Any) -> Any:
    """Model-facing bf16 view of a float params tree."""
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16)
        if jnp.issubdtype(p.dtype, jnp.floating) else p,
        params,
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def _decay_mask(path: tuple, leaf) -> bool:
    """Decay only matrix-like weights; skip norms/biases/scalars."""
    if leaf.ndim < 2:
        return False
    name = str(path[-1]) if path else ""
    return "norm" not in name.lower()


def adamw_update(
    cfg: AdamWConfig,
    grads: Any,
    opt_state: dict,
    params: Any,
) -> Tuple[Any, dict, dict]:
    """One AdamW step -> (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)

    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
        opt_state["m"], grads,
    )
    new_v = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        opt_state["v"], grads,
    )

    # With master weights, decay/update apply to the f32 master copy and
    # the bf16 params are re-derived by casting (mixed precision at scale).
    masters = opt_state.get("master")
    base_tree = masters if masters is not None else params

    params_paths = jax.tree_util.tree_leaves_with_path(params)
    flat_base = jax.tree.leaves(base_tree)
    flat_m = jax.tree.leaves(new_m)
    flat_v = jax.tree.leaves(new_v)
    new_leaves = []
    new_masters = []
    for (path, p), base, m, v in zip(params_paths, flat_base, flat_m, flat_v):
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path, p):
            update = update + cfg.weight_decay * base.astype(jnp.float32)
        new_base = base.astype(jnp.float32) - lr * update
        new_masters.append(new_base)
        new_leaves.append(new_base.astype(p.dtype))
    treedef = jax.tree_util.tree_structure(params)
    new_params = jax.tree_util.tree_unflatten(treedef, new_leaves)

    new_state = {"m": new_m, "v": new_v, "step": step}
    if masters is not None:
        new_state["master"] = jax.tree_util.tree_unflatten(treedef, new_masters)

    metrics = {"lr": lr, "grad_norm": gnorm, "step": step}
    return new_params, new_state, metrics
