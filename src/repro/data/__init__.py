"""Data pipeline: deterministic, restartable, shardable token streams."""
from repro.data.pipeline import (
    DataConfig,
    SyntheticLMDataset,
    TokenFileDataset,
    build_dataset,
    shard_batch,
)

__all__ = [
    "DataConfig",
    "SyntheticLMDataset",
    "TokenFileDataset",
    "build_dataset",
    "shard_batch",
]
