"""Deterministic, restartable data pipeline.

Requirements at 1000+ node scale (DESIGN.md SS5):

- **Determinism**: batch ``i`` is a pure function of (seed, i).  Any worker
  can recompute any batch; there is no shared iterator state to lose.
- **Restartability**: the loader's full state is one integer (the next step
  index).  Checkpoints persist it; resume is exact.
- **Elasticity**: batches are generated *globally* then sliced per host, so
  changing the host count between restarts re-shards the same stream without
  skewing the data order.

Two sources are provided: a synthetic LM stream (zipfian tokens with a
learnable bigram structure, so a real training loop shows decreasing loss)
and a binary token-file reader (memory-mapped, windowed) for real corpora.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np

import jax


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    kind: str = "synthetic"          # 'synthetic' | 'token_file'
    path: Optional[str] = None       # token file for kind='token_file'
    zipf_a: float = 1.2              # synthetic token distribution
    bigram_period: int = 53          # synthetic learnable structure


class SyntheticLMDataset:
    """Zipfian tokens with deterministic bigram structure.

    Token t+1 depends on token t (periodic affine map) half of the time, so
    a model can learn real structure from the stream -- training loss drops,
    which the train-loop tests assert.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab
        # zipfian marginals, clipped into vocab
        base = rng.zipf(cfg.zipf_a, size=(b, s + 1)).astype(np.int64)
        base = np.minimum(base - 1, v - 1).astype(np.int32)
        # overwrite with bigram-following tokens on even positions
        follow = (base[:, :-1] * 31 + 7) % cfg.bigram_period % v
        mask = np.broadcast_to((np.arange(1, s + 1)[None, :] % 2) == 0, (b, s))
        seq = base[:, 1:].copy()
        seq[mask] = follow.astype(np.int32)[mask]
        tokens = np.concatenate([base[:, :1], seq], axis=1)
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
            "mask": np.ones((b, s), np.float32),
        }


class TokenFileDataset:
    """Windowed reader over a flat binary int32 token file (memory-mapped)."""

    def __init__(self, cfg: DataConfig):
        assert cfg.path, "token_file dataset needs cfg.path"
        self.cfg = cfg
        self._tokens = np.memmap(Path(cfg.path), dtype=np.int32, mode="r")
        n_windows = (len(self._tokens) - 1) // cfg.seq_len
        if n_windows < 1:
            raise ValueError(f"{cfg.path}: too few tokens for seq_len={cfg.seq_len}")
        self._n_windows = n_windows

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
        idx = rng.integers(0, self._n_windows, size=cfg.global_batch)
        starts = idx * cfg.seq_len
        rows = np.stack(
            [self._tokens[s : s + cfg.seq_len + 1] for s in starts]
        ).astype(np.int32)
        rows = np.minimum(rows, cfg.vocab - 1)
        return {
            "tokens": rows[:, :-1],
            "labels": rows[:, 1:],
            "mask": np.ones((cfg.global_batch, cfg.seq_len), np.float32),
        }


def build_dataset(cfg: DataConfig):
    if cfg.kind == "synthetic":
        return SyntheticLMDataset(cfg)
    if cfg.kind == "token_file":
        return TokenFileDataset(cfg)
    raise ValueError(cfg.kind)


def shard_batch(batch: Dict[str, np.ndarray], host_index: int, host_count: int):
    """Slice a global batch to this host's rows (elastic re-shard safe)."""
    out = {}
    for k, v in batch.items():
        b = v.shape[0]
        assert b % host_count == 0, (k, b, host_count)
        per = b // host_count
        out[k] = v[host_index * per : (host_index + 1) * per]
    return out


def batches(dataset, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield dataset.batch(step)
        step += 1
