"""Content-hashed plan cache.

``ServingEngine`` and the benchmark harness repeatedly plan identical
(tiles, capacity) pairs -- every engine restart, every benchmark repeat,
every fleet member sharing a PU profile.  Plans are pure functions of
their inputs, so they are cached under a content hash of the packed tile
costs plus the planner options.  ``ExecutionPlan`` is frozen and its
arrays are never mutated by consumers, so sharing one instance is safe.
"""
from __future__ import annotations

import hashlib
import struct
import threading
from collections import OrderedDict
from typing import Optional, Sequence

from repro.core.pu import TileCost
from repro.plan.ir import ExecutionPlan
from repro.plan.planner import plan as _plan


def plan_key(
    tiles: Sequence[TileCost],
    capacity: int,
    *,
    preload_first: bool = True,
    adaptive: bool = True,
    exhaustive: bool = False,
    max_window_scan: Optional[int] = None,
) -> str:
    """Content hash of everything the planner's output depends on."""
    h = hashlib.sha256()
    h.update(
        struct.pack(
            "<q???q",
            capacity,
            preload_first,
            adaptive,
            exhaustive,
            -1 if max_window_scan is None else max_window_scan,
        )
    )
    for t in tiles:
        h.update(struct.pack("<ddq", t.load_s, t.exec_s, t.mem_bytes))
    return h.hexdigest()


class PlanCache:
    """Thread-safe LRU keyed by :func:`plan_key`."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: "OrderedDict[str, ExecutionPlan]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get_or_plan(
        self, tiles: Sequence[TileCost], capacity: int, **opts
    ) -> ExecutionPlan:
        key = plan_key(tiles, capacity, **opts)
        with self._lock:
            cached = self._entries.get(key)
            if cached is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                return cached
            self.misses += 1
        result = _plan(tiles, capacity, **opts)
        with self._lock:
            self._entries[key] = result
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
        return result

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "hits": self.hits,
                "misses": self.misses,
            }


PLAN_CACHE = PlanCache()


def plan_cached(tiles: Sequence[TileCost], capacity: int, **opts) -> ExecutionPlan:
    """Module-level cache shared by serving, simulation, and benchmarks."""
    return PLAN_CACHE.get_or_plan(tiles, capacity, **opts)
