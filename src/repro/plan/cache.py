"""Content-hashed plan cache with cross-process persistence.

``ServingEngine`` and the benchmark harness repeatedly plan identical
(tiles, capacity) pairs -- every engine restart, every benchmark repeat,
every fleet member sharing a PU profile.  Plans are pure functions of
their inputs, so they are cached under a content hash of the packed tile
costs plus the planner options.  ``ExecutionPlan`` is frozen and its
arrays are never mutated by consumers, so sharing one instance is safe.

Beyond the in-memory LRU, a cache may *spill* plans to
``<persist_dir>/<hash>.json`` (atomic tmp+rename writes) and load them
back on a memory miss, so serving restarts and CI runs reuse plans
across processes.  The shared module-level ``PLAN_CACHE`` persists to
``experiments/plans/`` when launched from the repo root; set
``REPRO_PLAN_CACHE_DIR`` to relocate it, or to ``0``/empty to disable
persistence.  Corrupt or unreadable spill files are ignored (the plan
is simply recomputed and rewritten).
"""
from __future__ import annotations

import hashlib
import json
import os
import struct
import threading
from collections import OrderedDict
from pathlib import Path
from typing import Optional, Sequence, Union

from repro.core.pu import TileCost
from repro.plan.ir import ExecutionPlan
from repro.plan.planner import SearchConfig, plan as _plan


def _planner_fingerprint() -> bytes:
    """Hash of the planner implementation itself.

    Folded into every plan key so persisted spill files are invalidated
    when planner/engine/IR code changes -- without this, a PR that
    alters planning semantics would silently validate against stale
    on-disk plans produced by the old code.
    """
    h = hashlib.sha256()
    base = Path(__file__).resolve().parent
    for mod in ("planner.py", "engine.py", "ir.py"):
        try:
            h.update((base / mod).read_bytes())
        except OSError:          # zipapp / frozen install: no invalidation,
            h.update(mod.encode())   # but keys stay stable and correct
    return h.digest()


_PLANNER_FP = _planner_fingerprint()


def plan_key(
    tiles: Sequence[TileCost],
    capacity: int,
    *,
    preload_first: bool = True,
    adaptive: bool = True,
    exhaustive: bool = False,
    max_window_scan: Optional[int] = None,
    search: Optional[SearchConfig] = None,
) -> str:
    """Content hash of everything the planner's output depends on.

    The search descriptor (strategy, parameters, *and seed*) is part of
    the key: a heuristic plan, a beam plan, and two differently-seeded
    annealed plans of the same workload are distinct artifacts and must
    never alias in memory or on disk.
    """
    h = hashlib.sha256(_PLANNER_FP)
    h.update(
        struct.pack(
            "<q???q",
            capacity,
            preload_first,
            adaptive,
            exhaustive,
            -1 if max_window_scan is None else max_window_scan,
        )
    )
    if search is not None and search.strategy != "heuristic":
        h.update(search.key_bytes())
    for t in tiles:
        h.update(struct.pack("<ddq", t.load_s, t.exec_s, t.mem_bytes))
    return h.hexdigest()


class PlanCache:
    """Thread-safe LRU keyed by :func:`plan_key`, optionally persistent.

    ``persist_dir`` enables the disk tier: memory miss -> try
    ``<persist_dir>/<key>.json`` -> plan and spill.  Disk I/O failures
    never fail planning; they only cost a recompute.
    """

    def __init__(
        self,
        max_entries: int = 256,
        persist_dir: Optional[Union[str, Path]] = None,
    ):
        self.max_entries = max_entries
        self.persist_dir = Path(persist_dir) if persist_dir else None
        self._entries: "OrderedDict[str, ExecutionPlan]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0
        self.disk_errors = 0

    # -- disk tier ----------------------------------------------------------

    def _spill_path(self, key: str) -> Optional[Path]:
        return self.persist_dir / f"{key}.json" if self.persist_dir else None

    def _load_from_disk(self, key: str) -> Optional[ExecutionPlan]:
        path = self._spill_path(key)
        if path is None:
            return None
        try:
            plan = ExecutionPlan.from_json_dict(
                json.loads(path.read_text())
            )
        except FileNotFoundError:
            return None
        except (OSError, ValueError, KeyError, TypeError):
            with self._lock:
                self.disk_errors += 1
            return None
        with self._lock:
            self.disk_hits += 1
        return plan

    def _save_to_disk(self, key: str, plan: ExecutionPlan) -> None:
        path = self._spill_path(key)
        if path is None:
            return
        # pid+tid: concurrent same-key spills from different threads
        # must not share one tmp file (truncation/rename races)
        tmp = path.with_suffix(
            f".tmp.{os.getpid()}.{threading.get_ident()}"
        )
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            tmp.write_text(json.dumps(plan.to_json_dict()))
            tmp.replace(path)     # atomic: readers never see a torn file
        except (OSError, TypeError, ValueError):
            # TypeError/ValueError: unserializable tile fields (e.g.
            # numpy scalars) must not fail planning that already
            # succeeded -- the contract is spill failures only cost a
            # recompute next process
            with self._lock:
                self.disk_errors += 1
            try:
                tmp.unlink()      # don't accumulate stale partial spills
            except OSError:
                pass

    # -- lookup -------------------------------------------------------------

    def get_or_plan(
        self, tiles: Sequence[TileCost], capacity: int, **opts
    ) -> ExecutionPlan:
        key = plan_key(tiles, capacity, **opts)
        with self._lock:
            cached = self._entries.get(key)
            if cached is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                return cached
            self.misses += 1
        result = self._load_from_disk(key)
        if result is None:
            result = _plan(tiles, capacity, **opts)
            self._save_to_disk(key, result)
        with self._lock:
            self._entries[key] = result
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
        return result

    def clear(self) -> None:
        """Drop the in-memory tier (spill files are left on disk)."""
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0
            self.disk_hits = 0
            self.disk_errors = 0

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "hits": self.hits,
                "misses": self.misses,
                "disk_hits": self.disk_hits,
                "disk_errors": self.disk_errors,
            }


def _default_persist_dir() -> Optional[Path]:
    """Resolve the shared cache's spill directory.

    ``REPRO_PLAN_CACHE_DIR`` wins (``0``/empty disables); otherwise use
    ``experiments/plans`` when the cwd looks like the repo root.  The
    root check uses *tracked* markers (``src/repro`` + ``ROADMAP.md``)
    -- ``experiments/`` itself is gitignored, so fresh clones and CI
    checkouts don't have it yet and it is created on first spill.
    Ad-hoc invocations elsewhere don't litter spill files.
    """
    env = os.environ.get("REPRO_PLAN_CACHE_DIR")
    if env is not None:
        return None if env in ("", "0") else Path(env)
    if Path("src/repro").is_dir() and Path("ROADMAP.md").is_file():
        # absolute, so a later chdir (daemonized serving, per-job
        # scratch dirs) keeps reading/writing the repo-root spill tree
        return Path.cwd() / "experiments" / "plans"
    return None


PLAN_CACHE = PlanCache(persist_dir=_default_persist_dir())


def plan_cached(tiles: Sequence[TileCost], capacity: int, **opts) -> ExecutionPlan:
    """Module-level cache shared by serving, simulation, and benchmarks."""
    return PLAN_CACHE.get_or_plan(tiles, capacity, **opts)
