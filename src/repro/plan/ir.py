"""ExecutionPlan: the shared plan IR for scheduling/streaming/serving.

One ``ExecutionPlan`` captures everything the repo previously scattered
across three ad-hoc shapes (``core.scheduler.Schedule`` /
``core.streaming.StreamingPlan`` / ``core.simulator.TwoPhaseResult``):

- the costed tile sequence (``TileCost`` per tile) and the fast-memory
  capacity it was planned against;
- the window assignment for both phases (baseline prefetch + adaptive
  relocations) -- ``windows[j] = k`` issues tile *j*'s load during tile
  *k*'s execution window, ``-1`` preloads before t=0;
- the resolved timeline (load/exec start/end arrays) for both phases;
- a vectorized residency account (prefix sums over allocation edges).

Consumers (``core.scheduler``, ``core.streaming``, ``core.simulator``,
``runtime.serving``, the benchmark harness) all read this IR; the legacy
entry points convert it to their historical return types via
:meth:`ExecutionPlan.to_schedule` / :meth:`ExecutionPlan.to_two_phase`,
which are bit-identical to the original planners by construction (same
event arithmetic, see plan/engine.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.pu import TileCost


@dataclasses.dataclass(frozen=True)
class Timeline:
    """Resolved timing of one window assignment (arrays indexed by tile)."""

    load_start: np.ndarray     # float64 (n,)
    load_end: np.ndarray
    exec_start: np.ndarray
    exec_end: np.ndarray
    feasible: bool

    @property
    def n(self) -> int:
        return len(self.load_start)

    def stalls(self) -> np.ndarray:
        """Per-tile wait between the previous exec end and this exec start."""
        if not self.feasible or self.n == 0:
            return np.zeros(0, np.float64)
        prev_end = np.concatenate(([0.0], self.exec_end[:-1]))
        return np.maximum(0.0, self.exec_start - prev_end)

    @property
    def total_stall(self) -> float:
        # left-to-right summation: keeps parity with the reference
        # scheduler's ``sum(t.stall for t in tiles)``
        total = 0.0
        for s in self.stalls().tolist():
            total += s
        return total

    @property
    def makespan(self) -> float:
        if not self.feasible or self.n == 0:
            return 0.0
        return float(self.exec_end[-1])

    @property
    def busy_time(self) -> float:
        if not self.feasible:
            return 0.0
        return float(np.sum(self.exec_end - self.exec_start))

    @property
    def utilization(self) -> float:
        ms = self.makespan
        return self.busy_time / ms if ms > 0 else 1.0


def _empty_timeline(feasible: bool) -> Timeline:
    z = np.zeros(0, np.float64)
    return Timeline(z, z, z, z, feasible)


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A fully planned tile sequence on one PU's fast memory."""

    tiles: Tuple[TileCost, ...]
    capacity: int
    preload_first: bool
    baseline_windows: Tuple[int, ...]
    windows: Tuple[int, ...]               # final (adaptive) assignment
    baseline: Timeline
    timeline: Timeline                     # final (adaptive) timeline
    plan_wall_s: float = 0.0               # planner wall time (diagnostics)
    # descriptor of the schedule search that produced `windows`
    # ("heuristic", or a SearchConfig descriptor like "beam(w=4,...)")
    search: str = "heuristic"
    # the adaptive phase detected a load-bound workload (no execution
    # window can conceal any stalled load) and exited without trials
    skipped_load_bound: bool = False

    # ---- summary statistics -------------------------------------------

    @property
    def n(self) -> int:
        return len(self.tiles)

    @property
    def feasible(self) -> bool:
        return self.timeline.feasible

    @property
    def total_stall(self) -> float:
        return self.timeline.total_stall

    @property
    def baseline_stall(self) -> float:
        return self.baseline.total_stall

    @property
    def stall_reduction(self) -> float:
        b = self.baseline_stall
        if b <= 0:
            return 0.0
        return (b - self.total_stall) / b

    @property
    def makespan(self) -> float:
        return self.timeline.makespan

    @property
    def utilization(self) -> float:
        return self.timeline.utilization

    @property
    def weight_bytes(self) -> int:
        return int(sum(t.mem_bytes for t in self.tiles))

    def issue_order(self) -> List[int]:
        """Tile indices in load-channel issue order.

        The load channel is serial and drains its queue sorted by
        ``(window, tile)``; every executor must fetch in this order.
        """
        return sorted(range(self.n), key=lambda i: (self.windows[i], i))

    def relocations(self) -> List[Tuple[int, int, int]]:
        """(tile, from_window, to_window) moved by the adaptive phase."""
        return [
            (j, b, w)
            for j, (b, w) in enumerate(zip(self.baseline_windows, self.windows))
            if b != w
        ]

    # ---- residency account (vectorized prefix sums) -------------------

    def residency(self, which: str = "adaptive") -> Tuple[np.ndarray, np.ndarray]:
        """(edge_times, resident_bytes) after each allocation/release edge.

        Memory is held from ``load_start`` to ``exec_end``; releases at a
        shared timestamp apply before allocations (matches the hardware:
        the URAM slot frees the cycle the consuming round retires).
        """
        tl = self.baseline if which == "baseline" else self.timeline
        if not tl.feasible or tl.n == 0:
            return np.zeros(0, np.float64), np.zeros(0, np.float64)
        mem = np.array([t.mem_bytes for t in self.tiles], np.float64)
        times = np.concatenate((tl.load_start, tl.exec_end))
        deltas = np.concatenate((mem, -mem))
        # kind flag orders releases (0) before allocations (1) at ties
        kind = np.concatenate((np.ones(tl.n), np.zeros(tl.n)))
        order = np.lexsort((kind, times))
        return times[order], np.cumsum(deltas[order])

    def peak_memory(self, which: str = "adaptive") -> int:
        _, resident = self.residency(which)
        return int(resident.max()) if len(resident) else 0

    # ---- legacy views --------------------------------------------------

    def to_schedule(self, which: str = "adaptive"):
        """Convert one phase to the legacy ``core.scheduler.Schedule``."""
        from repro.core import scheduler as sched

        tl = self.baseline if which == "baseline" else self.timeline
        if not tl.feasible:
            return sched.Schedule(tiles=[], feasible=False, capacity=self.capacity)
        windows = (
            self.baseline_windows if which == "baseline" else self.windows
        )
        out = []
        prev_end = 0.0
        for i, t in enumerate(self.tiles):
            es = float(tl.exec_start[i])
            out.append(
                sched.TileSchedule(
                    index=i,
                    window=windows[i],
                    load_start=float(tl.load_start[i]),
                    load_end=float(tl.load_end[i]),
                    exec_start=es,
                    exec_end=float(tl.exec_end[i]),
                    stall=max(0.0, es - prev_end),
                    mem_bytes=t.mem_bytes,
                )
            )
            prev_end = float(tl.exec_end[i])
        return sched.Schedule(tiles=out, feasible=True, capacity=self.capacity)

    def to_two_phase(self):
        """Convert to the legacy ``core.scheduler.TwoPhaseResult``."""
        from repro.core import scheduler as sched

        return sched.TwoPhaseResult(
            baseline=self.to_schedule("baseline"),
            adaptive=self.to_schedule("adaptive"),
        )

    # ---- persistence ----------------------------------------------------

    def to_json_dict(self) -> dict:
        """Loss-free JSON form (floats round-trip exactly via repr)."""
        def tl(t: Timeline) -> dict:
            return {
                "load_start": t.load_start.tolist(),
                "load_end": t.load_end.tolist(),
                "exec_start": t.exec_start.tolist(),
                "exec_end": t.exec_end.tolist(),
                "feasible": t.feasible,
            }

        return {
            "version": 1,
            "tiles": [[t.load_s, t.exec_s, t.mem_bytes] for t in self.tiles],
            "capacity": self.capacity,
            "preload_first": self.preload_first,
            "baseline_windows": list(self.baseline_windows),
            "windows": list(self.windows),
            "baseline": tl(self.baseline),
            "timeline": tl(self.timeline),
            "plan_wall_s": self.plan_wall_s,
            "search": self.search,
            "skipped_load_bound": self.skipped_load_bound,
        }

    @staticmethod
    def from_json_dict(d: dict) -> "ExecutionPlan":
        """Parse a persisted plan, validating its structure.

        A spill file can be corrupt in ways ``json.loads`` cannot see --
        truncated arrays, mismatched tile counts, windows out of range.
        Serving such a plan would silently execute a wrong schedule, so
        shape inconsistencies raise ``ValueError`` (the cache treats
        that like any other corrupt spill: recompute and rewrite).
        """
        if d.get("version") != 1:
            raise ValueError(f"unknown plan version {d.get('version')!r}")
        n = len(d["tiles"])

        def tl(x: dict) -> Timeline:
            t = Timeline(
                load_start=np.asarray(x["load_start"], np.float64),
                load_end=np.asarray(x["load_end"], np.float64),
                exec_start=np.asarray(x["exec_start"], np.float64),
                exec_end=np.asarray(x["exec_end"], np.float64),
                feasible=bool(x["feasible"]),
            )
            lens = {
                len(t.load_start), len(t.load_end),
                len(t.exec_start), len(t.exec_end),
            }
            if t.feasible and lens != ({n} if n else {0}):
                raise ValueError(
                    f"timeline arrays of length {sorted(lens)} do not "
                    f"match {n} tiles"
                )
            return t

        def wins(key: str) -> Tuple[int, ...]:
            w = tuple(int(v) for v in d[key])
            if len(w) != n or any(not (-1 <= v < i) for i, v in enumerate(w)):
                raise ValueError(f"invalid {key} for {n} tiles")
            return w

        return ExecutionPlan(
            tiles=tuple(
                TileCost(load_s=l, exec_s=e, mem_bytes=int(m))
                for l, e, m in d["tiles"]
            ),
            capacity=int(d["capacity"]),
            preload_first=bool(d["preload_first"]),
            baseline_windows=wins("baseline_windows"),
            windows=wins("windows"),
            baseline=tl(d["baseline"]),
            timeline=tl(d["timeline"]),
            plan_wall_s=float(d.get("plan_wall_s", 0.0)),
            search=str(d.get("search", "heuristic")),
            skipped_load_bound=bool(d.get("skipped_load_bound", False)),
        )

    def summary(self) -> dict:
        return {
            "tiles": self.n,
            "capacity_bytes": float(self.capacity),
            "weight_bytes": float(self.weight_bytes),
            "feasible": self.feasible,
            "baseline_stall_s": self.baseline_stall,
            "adaptive_stall_s": self.total_stall,
            "stall_reduction": self.stall_reduction,
            "baseline_util": self.baseline.utilization,
            "adaptive_util": self.utilization,
            "makespan_s": self.makespan,
            "relocations": len(self.relocations()),
            "plan_wall_s": self.plan_wall_s,
            "search": self.search,
            "skipped_load_bound": self.skipped_load_bound,
        }


def infeasible_plan(
    tiles: Sequence[TileCost], capacity: int, preload_first: bool
) -> ExecutionPlan:
    n = len(tiles)
    base_windows = tuple(range(-1, n - 1))
    return ExecutionPlan(
        tiles=tuple(tiles),
        capacity=capacity,
        preload_first=preload_first,
        baseline_windows=base_windows,
        windows=base_windows,
        baseline=_empty_timeline(False),
        timeline=_empty_timeline(False),
    )
