"""Multi-PU plan partitioning: split one model across K PU profiles.

The paper scales throughput by instantiating several PUs; its evaluation
(SS V) runs one *frame per PU*, which makes fleet FPS purely additive
(the old ``FleetSim`` model).  Real scaling of a single stream -- the
N3H-Core observation -- comes from *partitioning* one network across
heterogeneous compute cores.  This module implements that as a pipeline:

1. **Contiguous layer-range partitioning balanced on exec time**: an
   exact DP over (layer boundary, stage) minimizes the bottleneck stage
   compute time, with per-stage costs evaluated under that stage's own
   PU cost model (profiles may be heterogeneous).
2. **Per-PU two-phase scheduling**: each stage plans its own tile
   sequence against its own fast-memory capacity and load channel with
   the standard two-phase planner, so weight streaming stalls are
   charged per stage.

Steady-state pipeline throughput is ``1 / max_k stage_time_k`` (frames
enter the pipeline at the bottleneck stage rate); single-frame latency
is the sum of stage times.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.pu import PUConfig
from repro.plan.ir import ExecutionPlan


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One pipeline stage: a contiguous layer range on one PU.

    Beyond the stage's own two-phase schedule, the stage carries the
    *handoff metadata* the stage-parallel runtime needs: named tiles
    grouped by consuming layer (so an executor can fold tile outputs
    back into per-layer activations) and the inbound-activation transfer
    charged on the stage boundary (the inter-stage buffering cost the
    FPGA survey flags as the pipeline-scalability bottleneck).
    """

    pu: PUConfig
    layer_start: int
    layer_stop: int                  # exclusive
    plan: ExecutionPlan              # two-phase plan of the stage's tiles
    compute_s: float                 # all-weights-resident stage latency
    tile_names: Tuple[str, ...] = ()       # one per plan tile, index order
    tiles_per_layer: Tuple[int, ...] = ()  # tile count per local layer
    handoff_in_bytes: int = 0        # activation bytes entering the stage
    handoff_in_s: float = 0.0        # inbound transfer time per frame
    # model-layer range the stage's *decode slice* covers (what the model
    # slicers consume -- distinct from layer_start/stop, which index the
    # schedulable unit sequence, e.g. GEMMs).  -1 until a model-aware
    # planner (serving.plan_partitioned_streaming) attaches it, snapped
    # to the family's allowed slice points.
    decode_layer_start: int = -1
    decode_layer_stop: int = -1

    @property
    def decode_layers(self) -> Tuple[int, int]:
        if self.decode_layer_start < 0:
            raise ValueError(
                "stage has no decode layer range attached (plan was built "
                "from a raw unit sequence, not via a model-aware planner)"
            )
        return (self.decode_layer_start, self.decode_layer_stop)

    @property
    def stage_s(self) -> float:
        """Stage time per frame: compute plus weight-streaming stalls."""
        return self.compute_s + self.plan.total_stall

    @property
    def stage_s_with_handoff(self) -> float:
        """Stage occupancy per frame including the inbound handoff."""
        return self.stage_s + self.handoff_in_s

    @property
    def n_layers(self) -> int:
        return self.layer_stop - self.layer_start


@dataclasses.dataclass(frozen=True)
class PartitionedPlan:
    """A model split across K PUs as a synchronous pipeline."""

    stages: Tuple[StagePlan, ...]

    @property
    def feasible(self) -> bool:
        return all(s.plan.feasible for s in self.stages)

    @property
    def bottleneck_s(self) -> float:
        return max(s.stage_s for s in self.stages)

    @property
    def fps(self) -> float:
        return 1.0 / self.bottleneck_s

    @property
    def latency_s(self) -> float:
        return sum(s.stage_s for s in self.stages)

    @property
    def tops(self) -> float:
        return sum(s.pu.peak_ops_per_s for s in self.stages) / 1e12

    @property
    def fps_per_tops(self) -> float:
        return self.fps / self.tops

    # ---- pipeline-dynamics predictions (microbatched execution) -------

    def bubble_prediction(self, n_microbatches: int) -> float:
        """GPipe fill/drain bubble floor: (K-1)/(M+K-1).

        Shared with ``parallel.pipeline.bubble_fraction`` so the
        executed pipeline and the shard_map runner are checked against
        the same analytic model.
        """
        from repro.parallel.pipeline import bubble_fraction

        return bubble_fraction(len(self.stages), n_microbatches)

    def pipeline_events(
        self, n_microbatches: int
    ) -> "np.ndarray":
        """Predicted (K, M) completion times of every (stage, frame).

        Exact recurrence of the synchronous pipeline the executor runs:
        ``done[k][f] = max(done[k][f-1], done[k-1][f] + handoff_k)
        + stage_s_k`` with all microbatches available to stage 0 at t=0.
        """
        K, M = len(self.stages), n_microbatches
        done = np.zeros((K, M))
        for k, s in enumerate(self.stages):
            for f in range(M):
                ready = done[k - 1, f] + s.handoff_in_s if k else 0.0
                prev = done[k, f - 1] if f else 0.0
                done[k, f] = max(ready, prev) + s.stage_s
        return done

    def decode_pipeline_events(
        self,
        n_groups: int,
        n_rounds: int,
        group_scale: float = None,
    ) -> "np.ndarray":
        """Predicted (K, R*M) completion times of the *overlapped staged
        decode* schedule: R rounds, each split into M lane-group frames
        carrying ``group_scale`` (default ``1/M``) of the slot batch.

        Frame ``i = r*M + g`` is lane group ``g`` of round ``r``.  Same
        recurrence as :meth:`pipeline_events` with stage/handoff times
        prorated by the group scale, plus the cross-round sampling
        dependency: group ``g`` of round ``r+1`` may enter stage 0 only
        after group ``g`` of round ``r`` drained the last stage (its
        logits feed the sampled token the next round consumes).  This is
        what the executed virtual clock must reproduce for ``clock_ok``.
        """
        K, M, R = len(self.stages), n_groups, n_rounds
        scale = (1.0 / n_groups) if group_scale is None else group_scale
        done = np.zeros((K, R * M))
        for i in range(R * M):
            for k, s in enumerate(self.stages):
                if k:
                    ready = done[k - 1, i] + s.handoff_in_s * scale
                else:
                    ready = done[K - 1, i - M] if i >= M else 0.0
                prev = done[k, i - 1] if i else 0.0
                done[k, i] = max(ready, prev) + s.stage_s * scale
        return done

    def pipeline_makespan(self, n_microbatches: int) -> float:
        return float(self.pipeline_events(n_microbatches)[-1, -1])

    def pipeline_fps(self, n_microbatches: int) -> float:
        """Predicted throughput of an M-microbatch burst (incl. fill)."""
        return n_microbatches / self.pipeline_makespan(n_microbatches)

    def summary(self) -> dict:
        return {
            "stages": [
                {
                    "pu": s.pu.name,
                    "layers": [s.layer_start, s.layer_stop],
                    "compute_s": s.compute_s,
                    "stall_s": s.plan.total_stall,
                    "stage_s": s.stage_s,
                    "tiles": s.plan.n,
                    "handoff_in_bytes": s.handoff_in_bytes,
                    "handoff_in_s": s.handoff_in_s,
                    "decode_layers": (
                        [s.decode_layer_start, s.decode_layer_stop]
                        if s.decode_layer_start >= 0
                        else None
                    ),
                }
                for s in self.stages
            ],
            "fps": self.fps,
            "latency_s": self.latency_s,
            "bottleneck_s": self.bottleneck_s,
            "fps_per_tops": self.fps_per_tops,
            "feasible": self.feasible,
        }


def snap_boundaries_nonempty(
    raw_bounds: Sequence[float],
    slice_points: Sequence[int],
    n_layers: int,
) -> List[int]:
    """Snap K-1 interior stage boundaries onto allowed slice points,
    keeping every stage non-empty whenever enough interior points exist.

    Each raw boundary picks the nearest *interior* slice point (strictly
    above the previous pick) that still leaves enough distinct interior
    points for the boundaries after it -- so a boundary never greedily
    grabs a point that forces a later stage empty.  Only when the
    feasibility lookahead fails (more boundaries than interior points
    remain, i.e. K exceeds what the slice grid can host) does a boundary
    fall back to the nearest monotone point, which may duplicate its
    neighbour and yield an empty stage -- the documented K-too-large
    degenerate case.
    """
    pts = sorted(set(slice_points))
    interior = [p for p in pts if 0 < p < n_layers]
    n_bounds = len(raw_bounds)
    out: List[int] = []
    prev = 0
    for i, b in enumerate(raw_bounds):
        after = n_bounds - i - 1
        feasible = [
            p for p in interior
            if p > prev and sum(1 for q in interior if q > p) >= after
        ]
        if feasible:
            c = min(feasible, key=lambda q: (abs(q - b), q))
        else:
            allowed = [p for p in pts if prev <= p <= n_layers]
            c = min(allowed, key=lambda q: (abs(q - b), q))
        out.append(c)
        prev = c
    return out


def balance_layer_ranges(
    stage_costs: np.ndarray,
) -> List[Tuple[int, int]]:
    """Min-bottleneck contiguous partition of L layers into K stages.

    ``stage_costs[k, i]`` is layer *i*'s cost on stage *k*'s PU.  Exact
    DP: ``f[k][i]`` = best bottleneck for layers[:i] on stages[:k+1],
    requiring every stage non-empty.  O(K * L^2).
    """
    K, L = stage_costs.shape
    if K > L:
        raise ValueError(f"cannot split {L} layers into {K} non-empty stages")
    prefix = np.zeros((K, L + 1))
    prefix[:, 1:] = np.cumsum(stage_costs, axis=1)

    INF = math.inf
    f = np.full((K, L + 1), INF)
    cut = np.zeros((K, L + 1), np.int64)
    f[0, 1:] = prefix[0, 1:]
    for k in range(1, K):
        for i in range(k + 1, L + 1):
            best, best_j = INF, k
            # stage k covers layers [j, i); previous stages cover [:j)
            for j in range(k, i):
                b = max(f[k - 1, j], prefix[k, i] - prefix[k, j])
                if b < best:
                    best, best_j = b, j
            f[k, i] = best
            cut[k, i] = best_j
    # recover boundaries
    bounds = [L]
    i = L
    for k in range(K - 1, 0, -1):
        i = int(cut[k, i])
        bounds.append(i)
    bounds.append(0)
    bounds.reverse()
    return [(bounds[s], bounds[s + 1]) for s in range(K)]


def partition_layers(
    layers: Sequence,
    pus: Sequence[PUConfig],
    *,
    latency_s,
    tiles_of,
    name_of=None,
    act_bytes_of=None,
    use_cache: bool = True,
    search=None,
) -> PartitionedPlan:
    """Partition an arbitrary layer sequence across ``pus``.

    ``latency_s(pu, layer) -> float`` costs one layer on one PU (drives
    the balancing DP and the stage compute account); ``tiles_of(pu,
    layer) -> [TileCost]`` produces the stage's schedulable tiles.
    ``name_of(layer) -> str`` names the layer's tiles (executor handoff
    metadata); ``act_bytes_of(layer) -> int`` sizes the layer's *input*
    activations, charged as the handoff into the stage that starts with
    that layer.  ``search`` (a ``repro.plan.SearchConfig``) selects the
    per-stage schedule-search strategy; it is part of each stage plan's
    cache key.

    Degenerate shapes fall back to the single-PU path rather than
    producing empty stages: K > L cannot fill K non-empty contiguous
    ranges, so the whole model is planned as one stage on ``pus[0]``
    (K = 1 is the same path via the trivial DP).
    """
    from repro.plan.cache import plan_cached
    from repro.plan.planner import plan as _plan

    K = len(pus)
    L = len(layers)
    if K == 0:
        raise ValueError("need at least one PU profile")
    if L == 0:
        raise ValueError("need at least one layer")
    if K > L:
        pus = pus[:1]
        K = 1
    if name_of is None:
        name_of = lambda l: getattr(l, "name", None) or f"layer{id(l)}"
    costs = np.array([[latency_s(pu, l) for l in layers] for pu in pus])
    ranges = balance_layer_ranges(costs)

    stages = []
    for s, (pu, (start, stop)) in enumerate(zip(pus, ranges)):
        tiles: List = []
        tile_names: List[str] = []
        tiles_per_layer: List[int] = []
        for li, layer in enumerate(layers[start:stop]):
            layer_tiles = tiles_of(pu, layer)
            base = name_of(layer)
            tiles_per_layer.append(len(layer_tiles))
            tile_names.extend(
                f"{base}/t{j}" for j in range(len(layer_tiles))
            )
            tiles.extend(layer_tiles)
        if use_cache:
            stage_plan = plan_cached(tiles, pu.fast_mem_bytes, search=search)
        else:
            stage_plan = _plan(tiles, pu.fast_mem_bytes, search=search)
        handoff_bytes = (
            int(act_bytes_of(layers[start]))
            if (s > 0 and act_bytes_of is not None)
            else 0
        )
        stages.append(
            StagePlan(
                pu=pu,
                layer_start=start,
                layer_stop=stop,
                plan=stage_plan,
                compute_s=float(costs[s, start:stop].sum()),
                tile_names=tuple(tile_names),
                tiles_per_layer=tuple(tiles_per_layer),
                handoff_in_bytes=handoff_bytes,
                handoff_in_s=handoff_bytes / pu.act_bw_bytes_per_s,
            )
        )
    return PartitionedPlan(stages=tuple(stages))


def partition_gemms(
    gemms: Sequence[Tuple[str, int, int, int]],
    pus: Sequence[PUConfig],
    *,
    layer_latency_s=None,
    use_cache: bool = True,
    search=None,
) -> PartitionedPlan:
    """Partition a (name, N, M, P) GEMM sequence across ``pus``.

    ``layer_latency_s(pu, (name, n, m, p)) -> float`` overrides the
    per-layer cost model; the default charges the PU's systolic-array
    execution time (the simulator layers richer I/O modelling on top via
    ``core.simulator.simulate_partitioned``).
    """
    if layer_latency_s is None:
        layer_latency_s = lambda pu, g: pu.exec_time(g[2], g[3], g[1])
    return partition_layers(
        list(gemms),
        pus,
        latency_s=layer_latency_s,
        tiles_of=lambda pu, g: pu.gemm_tiles(g[1], g[2], g[3]),
        name_of=lambda g: g[0],
        # inbound activations of (name, N, M, P): the M x P int8 operand
        act_bytes_of=lambda g: g[2] * g[3],
        use_cache=use_cache,
        search=search,
    )
