"""Two-phase planner plus a pluggable schedule-search layer.

Phase 1 (baseline): tile *i*'s load is issued during tile *i-1*'s
execution window.  Phase 2 (adaptive): stalled tiles, visited in
descending stall order, have their loads tentatively relocated into
earlier windows (nearest-first, windows able to conceal the load unless
``exhaustive``); any relocation reducing overall stall is retained.

The heuristic phase replicates ``core.scheduler.adaptive_schedule``
exactly -- same visit order, same acceptance test, same early exit --
so its windows and timelines are bit-identical to the reference; each
candidate is evaluated by the event-indexed engine (plan/engine.py):
an O(1) critical-path reject for provably-dominated relocations, suffix
re-simulation for the rest.  Two planner-level shortcuts preserve
bit-identity while skipping dead work:

- **candidate prefilter**: a prefix-max over execution times answers
  "can any window conceal this load" in O(1); a stalled tile with no
  concealing window would scan every window and try nothing, so it is
  skipped outright (identical decisions, zero trials).
- **load-bound early exit**: when *no* stalled tile has a concealing
  window -- the signature of decode-style workloads whose loads dwarf
  every execution -- the adaptive phase exits immediately and the plan
  is tagged ``skipped_load_bound`` so benchmarks and serving surface
  why no relocation happened.

On top of the (cheap) heuristic, :class:`SearchConfig` selects a
search strategy over *multi-tile* window reassignments, funded by the
engine's incremental evaluation:

- ``beam``: breadth-limited best-first search; each round expands the
  current beam's states by single-tile relocations (stall-descending
  tiles, nearest-first windows) and keeps the ``beam_width`` best
  distinct window vectors.  Deterministic by construction.
- ``anneal``: annealing with a geometric temperature ladder; proposals
  relocate one (biased-random) tile's load to a random earlier window.
  All randomness comes from ``numpy.random.default_rng(seed)`` -- no
  global state -- so a (workload, config) pair always reproduces the
  same schedule.  The acceptance rule is a *restricted* Metropolis:
  proposals the engine proves no better than the incumbent (its O(1)
  critical-path/dominance rejects) are discarded without replay, even
  though classic Metropolis would accept some of them as lateral or
  small-uphill moves; proposals it cannot prove worse replay to an
  exact stall and then pass the usual ``exp(-delta/T)`` test (with
  replays aborted past ``~12 T``, where acceptance probability is
  <= e^-12).  Uphill exploration therefore happens only through
  moves whose badness is not provable from the committed timeline --
  in practice most non-trivial proposals, and the measured gains over
  the heuristic (BENCH_plan.json search records) are the acceptance
  criterion for this variant.

Both searches start from the heuristic schedule and return the best
state ever visited, so they never return more stall than the heuristic
seed -- property-tested in tests/test_plan.py.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pu import TileCost
from repro.plan import engine as _engine
from repro.plan.ir import ExecutionPlan, infeasible_plan

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Schedule-search selection, threaded from serving and benchmarks
    down to the plan-cache key (so plans from different strategies or
    seeds never alias)."""

    strategy: str = "heuristic"          # heuristic | beam | anneal
    seed: int = 0
    # beam search
    beam_width: int = 4
    beam_rounds: int = 16
    candidates_per_tile: int = 12
    tiles_per_round: int = 12
    # simulated annealing
    anneal_steps: int = 800
    anneal_t0: float = 0.25              # T0 as a fraction of seed stall
    anneal_tmin: float = 1e-3            # final T as a fraction of T0
    # search may use windows that only partially conceal a load
    exhaustive_windows: bool = True

    def __post_init__(self):
        if self.strategy not in ("heuristic", "beam", "anneal"):
            raise ValueError(f"unknown search strategy {self.strategy!r}")

    def descriptor(self) -> str:
        """Stable string identifying the search (folded into cache keys
        and recorded on the resulting ExecutionPlan)."""
        if self.strategy == "heuristic":
            return "heuristic"
        if self.strategy == "beam":
            return (
                f"beam(w={self.beam_width},r={self.beam_rounds},"
                f"c={self.candidates_per_tile},t={self.tiles_per_round},"
                f"x={int(self.exhaustive_windows)},seed={self.seed})"
            )
        return (
            f"anneal(s={self.anneal_steps},t0={self.anneal_t0!r},"
            f"tmin={self.anneal_tmin!r},x={int(self.exhaustive_windows)},"
            f"seed={self.seed})"
        )

    def key_bytes(self) -> bytes:
        return self.descriptor().encode()


def plan(
    tiles: Sequence[TileCost],
    capacity: int,
    *,
    preload_first: bool = True,
    adaptive: bool = True,
    exhaustive: bool = False,
    max_window_scan: Optional[int] = None,
    search: Optional[SearchConfig] = None,
) -> ExecutionPlan:
    """Plan a costed tile sequence against one fast-memory capacity."""
    t_begin = time.perf_counter()
    n = len(tiles)
    load_s = [t.load_s for t in tiles]
    exec_s = [t.exec_s for t in tiles]
    mem = [t.mem_bytes for t in tiles]
    eng = _engine.PlanEngine(load_s, exec_s, mem, capacity, preload_first)

    baseline_windows = list(range(-1, n - 1))
    base = eng.simulate(baseline_windows)
    if not base.feasible:
        return infeasible_plan(tiles, capacity, preload_first)

    windows = list(baseline_windows)
    best = base
    best_stall = base.total_stall
    skipped_load_bound = False

    if adaptive and n:
        base_stalls = base.timeline().stalls()
        stalled = sorted(
            (i for i in range(n) if base_stalls[i] > _EPS),
            key=lambda i: -base_stalls[i],
        )
        # prefix max of exec times: pmax[k] answers "can any window
        # <= k conceal a load of duration l" with one comparison, using
        # the same floats the reference filter compares
        pmax = (
            np.maximum.accumulate(np.asarray(exec_s, np.float64)).tolist()
            if (stalled and not exhaustive)
            else None
        )
        any_candidates = False
        for j in stalled:
            if windows[j] <= 0:
                continue
            l_j = load_s[j]
            if pmax is not None and pmax[windows[j] - 1] < l_j - _EPS:
                # no window can conceal l_j: the reference would scan
                # every window and try nothing
                continue
            any_candidates = True
            scanned = 0
            for k in range(windows[j] - 1, -1, -1):
                if not exhaustive and exec_s[k] < l_j - _EPS:
                    continue  # paper: window k cannot conceal l_j
                if max_window_scan is not None and scanned >= max_window_scan:
                    break
                scanned += 1
                ok, trial_stall, stall_j = eng.try_relocation(
                    best, j, k, best_stall - _EPS
                )
                if ok and trial_stall < best_stall - _EPS:
                    windows[j] = k
                    # promote: full re-sim rebuilds the snapshots the next
                    # suffix replay resumes from
                    best = eng.simulate(windows)
                    best_stall = best.total_stall
                    if stall_j <= _EPS:
                        break
        skipped_load_bound = bool(stalled) and not any_candidates

    searcher = search if (search and search.strategy != "heuristic") else None
    if searcher is not None and adaptive and n:
        if searcher.strategy == "beam":
            best, windows = _beam_search(eng, windows, best, searcher)
        else:
            best, windows = _anneal_search(eng, windows, best, searcher)

    return ExecutionPlan(
        tiles=tuple(tiles),
        capacity=capacity,
        preload_first=preload_first,
        baseline_windows=tuple(base.windows),
        windows=tuple(best.windows),
        baseline=base.timeline(),
        timeline=best.timeline(),
        plan_wall_s=time.perf_counter() - t_begin,
        search=searcher.descriptor() if searcher else "heuristic",
        skipped_load_bound=skipped_load_bound,
    )


# ---------------------------------------------------------------- search --


def _stalled_tiles(
    eng: "_engine.PlanEngine", state, windows, limit: int
) -> List[int]:
    stalls = state.stalls()
    order = sorted(
        (i for i in range(eng.n) if stalls[i] > _EPS and windows[i] > 0),
        key=lambda i: (-stalls[i], i),
    )
    return order[:limit] if limit else order


def _window_candidates(
    eng: "_engine.PlanEngine", windows, j: int, cfg: SearchConfig
) -> List[int]:
    """Earlier windows for tile j: the nearest half of the candidate
    budget (where the heuristic searches) plus an evenly-strided sample
    of the remaining range (escape hatches past its local optimum),
    optionally filtered to windows able to fully conceal the load."""
    w = windows[j]
    l_j = eng.load_s[j]

    def admissible(k: int) -> bool:
        return cfg.exhaustive_windows or eng.exec_s[k] >= l_j - _EPS

    out: List[int] = []
    near = max(cfg.candidates_per_tile // 2, 1)
    k = w - 1
    while k >= 0 and len(out) < near:
        if admissible(k):
            out.append(k)
        k -= 1
    if k > 0:
        far_budget = cfg.candidates_per_tile - len(out)
        if far_budget > 0:
            stride = max(k // far_budget, 1)
            kk = k - 1
            while kk >= 0 and far_budget > 0:
                if admissible(kk):
                    out.append(kk)
                    far_budget -= 1
                kk -= stride
    return out


def _beam_search(
    eng: "_engine.PlanEngine", windows0: List[int], state0, cfg: SearchConfig
) -> Tuple[object, List[int]]:
    """Beam over multi-tile reassignments; monotone in the best state."""
    w0 = tuple(windows0)
    beam = [(state0.total_stall, w0, state0)]
    best_state, best_windows = state0, w0
    for _round in range(cfg.beam_rounds):
        candidates: dict = {}
        for stall_s, wins, st in beam:
            lw = list(wins)
            for j in _stalled_tiles(eng, st, lw, cfg.tiles_per_round):
                for k in _window_candidates(eng, lw, j, cfg):
                    ok, tstall, _sj = eng.try_relocation(
                        st, j, k, stall_s - _EPS
                    )
                    if ok and tstall < stall_s - _EPS:
                        nw = wins[:j] + (k,) + wins[j + 1:]
                        prev = candidates.get(nw)
                        if prev is None or tstall < prev:
                            candidates[nw] = tstall
        if not candidates:
            break
        ranked = sorted(candidates.items(), key=lambda kv: (kv[1], kv[0]))
        beam = []
        improved = False
        for nw, _tstall in ranked[: cfg.beam_width]:
            stt = eng.simulate(list(nw))
            if not stt.feasible:
                continue
            beam.append((stt.total_stall, nw, stt))
            if stt.total_stall < best_state.total_stall - _EPS:
                best_state, best_windows = stt, nw
                improved = True
        if not beam or not improved:
            break
    return best_state, list(best_windows)


def _anneal_search(
    eng: "_engine.PlanEngine", windows0: List[int], state0, cfg: SearchConfig
) -> Tuple[object, List[int]]:
    """Metropolis annealing over single-tile relocations (earlier
    windows only), geometric temperature ladder, best-ever retained."""
    rng = np.random.default_rng(cfg.seed)
    n = eng.n
    cur = state0
    cur_windows = list(windows0)
    cur_stall = state0.total_stall
    best_state, best_windows = state0, list(windows0)
    t0 = max(cfg.anneal_t0 * max(cur_stall, _EPS), 1e-300)
    stalls = None
    steps = max(cfg.anneal_steps, 1)
    for step in range(steps):
        temp = t0 * (cfg.anneal_tmin ** (step / max(steps - 1, 1)))
        if stalls is None:
            stalls = cur.stalls()
            stalled = [
                i for i in range(n)
                if stalls[i] > _EPS and cur_windows[i] > 0
            ]
            movable = [i for i in range(1, n) if cur_windows[i] > 0]
        if not movable:
            break
        if stalled and rng.random() < 0.7:
            j = stalled[int(rng.integers(len(stalled)))]
        else:
            j = movable[int(rng.integers(len(movable)))]
        k = int(rng.integers(0, cur_windows[j]))
        if not cfg.exhaustive_windows and eng.exec_s[k] < eng.load_s[j] - _EPS:
            continue
        # not-ok covers both the engine's O(1) provably-no-better
        # rejects (restricted Metropolis -- see the module docstring)
        # and replays aborted past ~12 T (acceptance <= e^-12)
        ok, tstall, _sj = eng.try_relocation(
            cur, j, k, cur_stall + 12.0 * temp
        )
        if not ok:
            continue
        delta = tstall - cur_stall
        if delta < 0 or rng.random() < math.exp(-delta / temp):
            cur_windows[j] = k
            cur = eng.simulate(cur_windows)
            if not cur.feasible:     # should not happen: trial was feasible
                cur = eng.simulate(best_windows)
                cur_windows = list(best_windows)
            cur_stall = cur.total_stall
            stalls = None
            if cur_stall < best_state.total_stall - _EPS:
                best_state, best_windows = cur, list(cur_windows)
    return best_state, best_windows
