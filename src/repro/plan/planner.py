"""Two-phase planner over the incremental engine -> ExecutionPlan.

Phase 1 (baseline): tile *i*'s load is issued during tile *i-1*'s
execution window.  Phase 2 (adaptive): stalled tiles, visited in
descending stall order, have their loads tentatively relocated into
earlier windows (nearest-first, windows able to conceal the load unless
``exhaustive``); any relocation reducing overall stall is retained.

Control flow replicates ``core.scheduler.adaptive_schedule`` exactly --
same visit order, same acceptance test, same early exit -- so the
resulting windows and timelines are bit-identical to the reference; the
difference is that each candidate is evaluated by suffix re-simulation
(plan/engine.py) instead of a full O(n^2) replay.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

from repro.core.pu import TileCost
from repro.plan import engine as _engine
from repro.plan.ir import ExecutionPlan, infeasible_plan

_EPS = 1e-12


def plan(
    tiles: Sequence[TileCost],
    capacity: int,
    *,
    preload_first: bool = True,
    adaptive: bool = True,
    exhaustive: bool = False,
    max_window_scan: Optional[int] = None,
) -> ExecutionPlan:
    """Plan a costed tile sequence against one fast-memory capacity."""
    t_begin = time.perf_counter()
    n = len(tiles)
    load_s = [t.load_s for t in tiles]
    exec_s = [t.exec_s for t in tiles]
    mem = [t.mem_bytes for t in tiles]
    eng = _engine.PlanEngine(load_s, exec_s, mem, capacity, preload_first)

    baseline_windows = list(range(-1, n - 1))
    base = eng.simulate(baseline_windows)
    if not base.feasible:
        return infeasible_plan(tiles, capacity, preload_first)

    windows = list(baseline_windows)
    best = base
    best_stall = base.total_stall

    if adaptive and n:
        base_stalls = base.timeline().stalls()
        stalled = sorted(
            (i for i in range(n) if base_stalls[i] > _EPS),
            key=lambda i: -base_stalls[i],
        )
        for j in stalled:
            if windows[j] <= 0:
                continue
            l_j = load_s[j]
            scanned = 0
            for k in range(windows[j] - 1, -1, -1):
                if not exhaustive and exec_s[k] < l_j - _EPS:
                    continue  # paper: window k cannot conceal l_j
                if max_window_scan is not None and scanned >= max_window_scan:
                    break
                scanned += 1
                ok, trial_stall, stall_j = eng.try_relocation(
                    best, j, k, best_stall - _EPS
                )
                if ok and trial_stall < best_stall - _EPS:
                    windows[j] = k
                    # promote: full re-sim rebuilds the snapshots the next
                    # suffix replay resumes from
                    best = eng.simulate(windows)
                    best_stall = best.total_stall
                    if stall_j <= _EPS:
                        break

    return ExecutionPlan(
        tiles=tuple(tiles),
        capacity=capacity,
        preload_first=preload_first,
        baseline_windows=tuple(base.windows),
        windows=tuple(best.windows),
        baseline=base.timeline(),
        timeline=best.timeline(),
        plan_wall_s=time.perf_counter() - t_begin,
    )
