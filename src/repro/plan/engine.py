"""Incremental event engine for window-assignment simulation.

The reference simulator (``core.scheduler.simulate``) replays the whole
event queue for every candidate schedule and answers each memory query
with an O(n) masked sum, making the adaptive phase ~O(n^3) on
ResNet-50-scale tile lists.  This engine produces *bit-identical*
timelines while cutting the planner's hot path by an order of magnitude:

- **memory account**: allocation edges (+bytes at ``load_start``) arrive
  in channel order and release edges (-bytes at ``exec_end``) in tile
  order, both with non-decreasing timestamps.  Keeping the two families
  separate turns ``usage_at(t)`` into two binary searches over prefix-sum
  lists.  All byte quantities are integers, so regrouping the sums is
  exact -- no float drift versus the reference's masked sum.

- **suffix re-simulation**: the adaptive phase relocates one tile's load
  into an earlier window.  In the serialized load queue (sorted by
  ``(window, tile)``) every entry before the relocated load's new
  position is untouched, so a trial restores the engine state snapshot
  taken just before that queue position and replays only the suffix.
  Scratch buffers are patched back slice-wise from the committed state
  (only the ranges the previous trial dirtied), so a trial costs
  O(suffix), not O(n).

- **monotone-stall early abort**: per-tile stalls are non-negative and
  accumulate left-to-right, so a trial whose partial stall already
  reaches the incumbent's can never be accepted and is abandoned
  mid-replay.  Rejected-trial outcomes are unaffected (both paths
  reject), keeping the planner's decision sequence identical to the
  reference.

Determinism note: event processing order, tie-breaks, and every float
operation mirror the reference implementation exactly; the only changes
are query data structures and replay extent.  ``tests/test_plan.py``
asserts equality against the reference on randomized tile sets.
"""
from __future__ import annotations

import dataclasses
import math
from bisect import bisect_left, bisect_right
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.plan.ir import Timeline

_NEG_INF = -math.inf


def _empty_timeline() -> Timeline:
    z = np.zeros(0, np.float64)
    return Timeline(z, z, z, z, False)


@dataclasses.dataclass
class SimState:
    """A completed full simulation plus the snapshots needed to resume."""

    windows: List[int]
    queue: List[int]                   # tile ids in channel (issue) order
    queue_keys: List[Tuple[int, int]]  # (window, tile) sorted keys
    qpos_of: List[int]                 # tile id -> queue position
    feasible: bool
    total_stall: float
    # per-tile timelines (valid only when feasible)
    load_start: List[float]
    load_end: List[float]
    exec_start: List[float]
    exec_end: List[float]
    # channel-order allocation edges: times + cumulative bytes
    edge_t: List[float]
    edge_cum: List[float]
    # stall_cum[i] = left-to-right sum of stalls of executions [0, i)
    stall_cum: List[float]
    # snaps[q] = (channel_free, prev_exec_end, i_exec, n_loads) just
    # before issuing queue position q
    snaps: List[Tuple[float, float, int, int]]

    def timeline(self) -> Timeline:
        if not self.feasible:
            return _empty_timeline()
        return Timeline(
            load_start=np.asarray(self.load_start, np.float64),
            load_end=np.asarray(self.load_end, np.float64),
            exec_start=np.asarray(self.exec_start, np.float64),
            exec_end=np.asarray(self.exec_end, np.float64),
            feasible=True,
        )


class PlanEngine:
    """Event engine over one costed tile sequence and capacity."""

    def __init__(
        self,
        load_s: Sequence[float],
        exec_s: Sequence[float],
        mem_bytes: Sequence[int],
        capacity: int,
        preload_first: bool = True,
    ):
        self.n = len(load_s)
        self.load_s = [float(v) for v in load_s]
        self.exec_s = [float(v) for v in exec_s]
        self.mem = [float(v) for v in mem_bytes]
        self.capacity = capacity
        self.preload_first = preload_first
        # releases retire in tile order: bytes released after the first k
        # executions is a static prefix sum
        rel = [0.0]
        for m in self.mem:
            rel.append(rel[-1] + m)
        self.rel_cum = rel
        self.any_oversized = any(m > capacity for m in self.mem)
        # trial scratch, patched from the committed state between trials
        n = self.n
        self._s_le: List[float] = [0.0] * n
        self._s_es: List[float] = [0.0] * n
        self._s_ee: List[float] = [0.0] * n
        self._s_edge_t: List[float] = [0.0] * n
        self._s_edge_cum: List[float] = [0.0] * n
        self._scratch_of: Optional[SimState] = None
        self._dirty_exec: Tuple[int, int] = (0, 0)
        self._dirty_edges: Tuple[int, int] = (0, 0)
        self._dirty_loads: List[int] = []

    # ---- full simulation (with resume snapshots) ----------------------

    def simulate(self, windows: Sequence[int]) -> SimState:
        n = self.n
        windows = list(windows)
        if self.preload_first and n:
            windows[0] = -1
        for j, w in enumerate(windows):
            if not (-1 <= w < j):
                raise ValueError(f"window[{j}]={w} must be in [-1, {j-1}]")

        queue = sorted(range(n), key=lambda j: (windows[j], j))
        queue_keys = [(windows[j], j) for j in queue]
        qpos_of = [0] * n
        for pos, j in enumerate(queue):
            qpos_of[j] = pos

        state = SimState(
            windows=windows,
            queue=queue,
            queue_keys=queue_keys,
            qpos_of=qpos_of,
            feasible=True,
            total_stall=0.0,
            load_start=[math.nan] * n,
            load_end=[math.nan] * n,
            exec_start=[math.nan] * n,
            exec_end=[math.nan] * n,
            edge_t=[0.0] * n,
            edge_cum=[0.0] * n,
            stall_cum=[0.0] * (n + 1),
            snaps=[(0.0, 0.0, 0, 0)] * n,
        )
        if n == 0:
            return state
        if self.any_oversized:
            state.feasible = False
            return state

        load_s, exec_s, mem = self.load_s, self.exec_s, self.mem
        rel_cum, capacity = self.rel_cum, self.capacity
        ls, le = state.load_start, state.load_end
        es, ee = state.exec_start, state.exec_end
        edge_t, edge_cum = state.edge_t, state.edge_cum
        stall_cum, snaps = state.stall_cum, state.snaps
        loaded = [False] * n

        channel_free = _NEG_INF
        prev_exec_end = 0.0
        stall_acc = 0.0
        i_exec = 0
        qpos = 0
        nl = 0

        while i_exec < n:
            if loaded[i_exec]:
                le_i = le[i_exec]
                start = prev_exec_end if prev_exec_end >= le_i else le_i
                s = start - prev_exec_end
                if s > 0.0:
                    stall_acc += s
                es[i_exec] = start
                end = start + exec_s[i_exec]
                ee[i_exec] = end
                prev_exec_end = end
                i_exec += 1
                stall_cum[i_exec] = stall_acc
                continue
            if qpos >= n:
                state.feasible = False
                return state
            snaps[qpos] = (channel_free, prev_exec_end, i_exec, nl)
            j = queue[qpos]
            w = windows[j]
            if w == -1:
                open_t = -load_s[j]
            elif w < i_exec:
                open_t = es[w]
            else:
                # window tile has not executed: its load is queued behind
                # this one => deadlock
                state.feasible = False
                return state
            t0 = open_t if open_t >= channel_free else channel_free
            t_issue = self._earliest_fit(
                t0, mem[j], nl, i_exec, edge_t, edge_cum, ee
            )
            if t_issue is None:
                state.feasible = False
                return state
            ls[j] = t_issue
            le[j] = t_issue + load_s[j]
            channel_free = le[j]
            loaded[j] = True
            edge_t[nl] = t_issue
            edge_cum[nl] = (edge_cum[nl - 1] if nl else 0.0) + mem[j]
            nl += 1
            qpos += 1

        state.total_stall = stall_acc
        return state

    def _earliest_fit(
        self, t0: float, need: float, nl: int, ne: int,
        edge_t: List[float], edge_cum: List[float], ee: List[float],
    ) -> Optional[float]:
        capacity = self.capacity
        rel_cum = self.rel_cum

        # resident bytes at t0
        i = bisect_right(edge_t, t0, 0, nl)
        usage = edge_cum[i - 1] if i else 0.0
        usage -= rel_cum[bisect_right(ee, t0, 0, ne)]
        if usage + need <= capacity:
            return t0
        # scan release times strictly after t0, in order
        k = bisect_right(ee, t0, 0, ne)
        while k < ne:
            ts = ee[k]
            i = bisect_right(edge_t, ts, 0, nl)
            usage = edge_cum[i - 1] if i else 0.0
            usage -= rel_cum[bisect_right(ee, ts, 0, ne)]
            if usage + need <= capacity:
                return ts
            k += 1
        return None

    # ---- suffix re-simulation ------------------------------------------

    def _sync_scratch(self, base: SimState) -> None:
        if self._scratch_of is not base:
            # new committed state: refresh the whole scratch
            self._s_le[:] = base.load_end
            self._s_es[:] = base.exec_start
            self._s_ee[:] = base.exec_end
            self._s_edge_t[:] = base.edge_t
            self._s_edge_cum[:] = base.edge_cum
            self._scratch_of = base
        else:
            # patch back only what the previous trial overwrote
            e0, e1 = self._dirty_exec
            if e1 > e0:
                self._s_es[e0:e1] = base.exec_start[e0:e1]
                self._s_ee[e0:e1] = base.exec_end[e0:e1]
            g0, g1 = self._dirty_edges
            if g1 > g0:
                self._s_edge_t[g0:g1] = base.edge_t[g0:g1]
                self._s_edge_cum[g0:g1] = base.edge_cum[g0:g1]
            for x in self._dirty_loads:
                self._s_le[x] = base.load_end[x]
        self._dirty_exec = (0, 0)
        self._dirty_edges = (0, 0)
        self._dirty_loads = []

    def try_relocation(
        self, base: SimState, j: int, new_window: int, abort_stall: float
    ) -> Tuple[bool, float, float]:
        """Re-simulate ``base`` with tile j's load moved to ``new_window``.

        Replays only the queue suffix from the relocated load's new
        position, abandoning the trial as soon as its accumulated stall
        reaches ``abort_stall`` (it could no longer be accepted).
        Returns (acceptable, total_stall, stall_of_j); on early abort or
        infeasibility, (False, inf, inf).
        """
        n = self.n
        p = bisect_left(base.queue_keys, (new_window, j))
        channel_free, prev_exec_end, i_exec, nl = base.snaps[p]
        i_exec0, nl0 = i_exec, nl
        stall_acc = base.stall_cum[i_exec]
        stall_j = math.inf

        self._sync_scratch(base)
        le, es, ee = self._s_le, self._s_es, self._s_ee
        edge_t, edge_cum = self._s_edge_t, self._s_edge_cum
        dirty_loads = self._dirty_loads

        qpos_of = base.qpos_of
        loaded = [q < p for q in qpos_of]
        loaded[j] = False

        suffix = [j]
        suffix.extend(x for x in base.queue[p:] if x != j)
        qidx = 0
        n_suffix = len(suffix)
        base_windows = base.windows
        load_s, exec_s, mem = self.load_s, self.exec_s, self.mem

        feasible = True
        while i_exec < n:
            if loaded[i_exec]:
                le_i = le[i_exec]
                start = prev_exec_end if prev_exec_end >= le_i else le_i
                s = start - prev_exec_end
                if s > 0.0:
                    stall_acc += s
                if i_exec == j:
                    stall_j = s if s > 0.0 else 0.0
                if stall_acc >= abort_stall:
                    feasible = False
                    break
                es[i_exec] = start
                end = start + exec_s[i_exec]
                ee[i_exec] = end
                prev_exec_end = end
                i_exec += 1
                continue
            if qidx >= n_suffix:
                feasible = False
                break
            x = suffix[qidx]
            w = new_window if x == j else base_windows[x]
            if w == -1:
                open_t = -load_s[x]
            elif w < i_exec:
                open_t = es[w]
            else:
                feasible = False
                break
            t0 = open_t if open_t >= channel_free else channel_free
            t_issue = self._earliest_fit(
                t0, mem[x], nl, i_exec, edge_t, edge_cum, ee
            )
            if t_issue is None:
                feasible = False
                break
            le[x] = t_issue + load_s[x]
            dirty_loads.append(x)
            channel_free = le[x]
            loaded[x] = True
            edge_t[nl] = t_issue
            edge_cum[nl] = (edge_cum[nl - 1] if nl else 0.0) + mem[x]
            nl += 1
            qidx += 1

        self._dirty_exec = (i_exec0, i_exec)
        self._dirty_edges = (nl0, nl)
        if not feasible:
            return False, math.inf, math.inf
        return True, stall_acc, stall_j
    # NOTE: ``stall_j`` above is exact because tile j's execution always
    # lies inside the replayed suffix: at the snapshot its load is not yet
    # issued, so its execution cannot have been scheduled.
