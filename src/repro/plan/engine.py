"""Vectorized event-indexed engine for window-assignment simulation.

The reference simulator (``core.scheduler.simulate``) replays the whole
event queue for every candidate schedule and answers each memory query
with an O(n) masked sum, making the adaptive phase ~O(n^3) on
ResNet-50-scale tile lists.  This engine produces *bit-identical*
timelines while cutting the planner's hot path to near O(changed-tiles
* log n) per candidate:

- **prefix-sum memory account**: release edges retire in tile order, so
  bytes freed after the first k executions is a static prefix sum
  (``rel_cum``, built once with numpy).  Allocation edges arrive in
  channel order with non-decreasing issue times, and every memory query
  the simulation makes happens at ``t >= channel_free`` -- *after* all
  issued loads -- so the allocation side collapses to one running
  ``issued_bytes`` scalar.  All byte quantities are integers, so
  regrouping the sums is exact: no float drift versus the reference's
  masked sum.

- **closed-form earliest-fit**: with the allocation side constant over
  a query, residency at successive release times is *monotone
  decreasing*, so "earliest release time with room for ``need`` bytes"
  is a single ``bisect`` over the release prefix-sum -- the interval
  index over the residency timeline.  (A segment tree is unnecessary:
  the monotone account makes the interval query a binary search.)  The
  returned time is identical to the reference's linear release scan,
  including release-time ties, because tied releases share one
  timestamp.

- **suffix re-simulation**: the adaptive phase relocates one tile's load
  into an earlier window.  In the serialized load queue (sorted by
  ``(window, tile)``) every entry before the relocated load's new
  position is untouched, so a trial restores the engine state snapshot
  taken just before that queue position and replays only the suffix.
  Scratch buffers are patched back slice-wise from the committed state
  (only the ranges the previous trial dirtied), so a trial costs
  O(suffix), not O(n).

- **dominance abort**: a trial whose replay state is pointwise no
  earlier than the committed state -- aligned issued-load set, scalars
  ``>=`` the committed snapshot, and no live event time earlier than
  the committed one -- can only finish with a makespan (and therefore a
  total stall) ``>=`` the committed total, so it is terminated
  immediately as a reject (the planner's acceptance test
  ``trial < best`` fails either way).  Most rejected relocations
  trip this a few events past the *old* queue position of the moved
  load, so their cost is O(queue distance moved), not O(n).

- **monotone-stall early abort**: per-tile stalls are non-negative and
  accumulate left-to-right, so a trial whose partial stall already
  reaches the incumbent's can never be accepted and is abandoned
  mid-replay.  Rejected-trial outcomes are unaffected (both paths
  reject), keeping the planner's decision sequence identical to the
  reference.

Determinism note: event processing order, tie-breaks, and every float
operation mirror the reference implementation exactly; the only changes
are query data structures and replay extent.  Every comparison the
closed-form earliest-fit answers is between exact integer-valued
doubles, so it is equivalence, not approximation.  ``tests/test_plan.py``
asserts equality against the reference on randomized tile sets and
randomized window assignments.
"""
from __future__ import annotations

import dataclasses
import math
from bisect import bisect_left, bisect_right
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.plan.ir import Timeline

_NEG_INF = -math.inf


def _empty_timeline() -> Timeline:
    z = np.zeros(0, np.float64)
    return Timeline(z, z, z, z, False)


@dataclasses.dataclass
class SimState:
    """A completed full simulation plus the snapshots needed to resume."""

    windows: List[int]
    queue: List[int]                   # tile ids in channel (issue) order
    queue_keys: List[Tuple[int, int]]  # (window, tile) sorted keys
    qpos_of: List[int]                 # tile id -> queue position
    feasible: bool
    total_stall: float
    # per-tile timelines (valid only when feasible)
    load_start: List[float]
    load_end: List[float]
    exec_start: List[float]
    exec_end: List[float]
    # stall_cum[i] = left-to-right sum of stalls of executions [0, i)
    stall_cum: List[float]
    # snaps[q] = (channel_free, prev_exec_end, i_exec, issued_bytes)
    # just before issuing queue position q
    snaps: List[Tuple[float, float, int, float]]
    # last_read_q[i] = last queue position whose load opens on window i
    # (-1 if none): liveness bound for early-exec divergences, computed
    # lazily by PlanEngine.try_relocation
    last_read_q: Optional[List[int]] = None
    # win_readers[w] = queue positions whose load opens on window w,
    # computed lazily by PlanEngine.try_relocation
    win_readers: Optional[List[List[int]]] = None

    def timeline(self) -> Timeline:
        if not self.feasible:
            return _empty_timeline()
        return Timeline(
            load_start=np.asarray(self.load_start, np.float64),
            load_end=np.asarray(self.load_end, np.float64),
            exec_start=np.asarray(self.exec_start, np.float64),
            exec_end=np.asarray(self.exec_end, np.float64),
            feasible=True,
        )

    def stalls(self) -> List[float]:
        """Per-execution stall via successive differences of the running
        sum -- used for search-tile selection, where tiny rounding in a
        difference cannot change any decision (acceptance always uses
        the exact totals)."""
        cum = self.stall_cum
        return [cum[i + 1] - cum[i] for i in range(len(cum) - 1)]


class PlanEngine:
    """Event engine over one costed tile sequence and capacity."""

    def __init__(
        self,
        load_s: Sequence[float],
        exec_s: Sequence[float],
        mem_bytes: Sequence[int],
        capacity: int,
        preload_first: bool = True,
    ):
        self.n = len(load_s)
        self.load_s = [float(v) for v in load_s]
        self.exec_s = [float(v) for v in exec_s]
        self.mem = [float(v) for v in mem_bytes]
        self.capacity = capacity
        self.preload_first = preload_first
        # releases retire in tile order: bytes released after the first k
        # executions is a static prefix sum (numpy cumsum is exact here:
        # integer-valued doubles)
        rel = np.zeros(self.n + 1, np.float64)
        np.cumsum(np.asarray(self.mem, np.float64), out=rel[1:])
        self.rel_cum: List[float] = rel.tolist()
        # exec_cum[t] = sum of exec_s[:t] (chain-bound critical path)
        ec = np.zeros(self.n + 1, np.float64)
        np.cumsum(np.asarray(self.exec_s, np.float64), out=ec[1:])
        self.exec_cum: List[float] = ec.tolist()
        self.any_oversized = any(m > capacity for m in self.mem)
        # trial scratch, patched from the committed state between trials
        n = self.n
        self._s_le: List[float] = [0.0] * n
        self._s_es: List[float] = [0.0] * n
        self._s_ee: List[float] = [0.0] * n
        self._scratch_of: Optional[SimState] = None
        self._dirty_exec: Tuple[int, int] = (0, 0)
        self._dirty_loads: List[int] = []
        # critical-path scan state for one (committed state, tile) scan
        self._scan_base: Optional[SimState] = None
        self._scan_j: int = -1
        self._scan_D: List[float] = []
        self._scan_abs: List[float] = []
        self._scan_nofit: int = -1
        self._scan_margin: float = 0.0
        self._load_sum = float(np.sum(np.asarray(self.load_s, np.float64)))

    # ---- residency queries ---------------------------------------------

    def _earliest_fit(
        self, t0: float, need: float, issued: float, ne: int, ee: List[float]
    ) -> Optional[float]:
        """Earliest t >= t0 with room for ``need`` bytes.

        ``issued`` is the byte total of every load issued so far.  All
        queries happen at ``t >= channel_free`` >= every allocation edge,
        so residency(t) = issued - rel_cum[#releases <= t]; it only drops
        at release times, monotonically, which turns the earliest-fit
        interval query into two binary searches.
        """
        rel_cum = self.rel_cum
        if issued - rel_cum[bisect_right(ee, t0, 0, ne)] + need <= self.capacity:
            return t0
        # first release index idx (in (r0, ne]) freeing enough; ties on
        # the release timestamp share the time value, so returning
        # ee[idx-1] matches the reference's scan over distinct times
        idx = bisect_left(
            rel_cum,
            issued + need - self.capacity,
            bisect_right(ee, t0, 0, ne) + 1,
            ne + 1,
        )
        if idx > ne:
            return None
        return ee[idx - 1]

    # ---- full simulation (with resume snapshots) ----------------------

    def simulate(self, windows: Sequence[int]) -> SimState:
        n = self.n
        windows = list(windows)
        if self.preload_first and n:
            windows[0] = -1
        for j, w in enumerate(windows):
            if not (-1 <= w < j):
                raise ValueError(f"window[{j}]={w} must be in [-1, {j-1}]")

        queue = sorted(range(n), key=lambda j: (windows[j], j))
        queue_keys = [(windows[j], j) for j in queue]
        qpos_of = [0] * n
        for pos, j in enumerate(queue):
            qpos_of[j] = pos

        state = SimState(
            windows=windows,
            queue=queue,
            queue_keys=queue_keys,
            qpos_of=qpos_of,
            feasible=True,
            total_stall=0.0,
            load_start=[math.nan] * n,
            load_end=[math.nan] * n,
            exec_start=[math.nan] * n,
            exec_end=[math.nan] * n,
            stall_cum=[0.0] * (n + 1),
            snaps=[(0.0, 0.0, 0, 0.0)] * n,
        )
        if n == 0:
            return state
        if self.any_oversized:
            state.feasible = False
            return state

        load_s, exec_s, mem = self.load_s, self.exec_s, self.mem
        ls, le = state.load_start, state.load_end
        es, ee = state.exec_start, state.exec_end
        stall_cum, snaps = state.stall_cum, state.snaps
        loaded = [False] * n

        channel_free = _NEG_INF
        prev_exec_end = 0.0
        stall_acc = 0.0
        issued = 0.0
        i_exec = 0
        qpos = 0

        while i_exec < n:
            if loaded[i_exec]:
                le_i = le[i_exec]
                start = prev_exec_end if prev_exec_end >= le_i else le_i
                s = start - prev_exec_end
                if s > 0.0:
                    stall_acc += s
                es[i_exec] = start
                end = start + exec_s[i_exec]
                ee[i_exec] = end
                prev_exec_end = end
                i_exec += 1
                stall_cum[i_exec] = stall_acc
                continue
            if qpos >= n:
                state.feasible = False
                return state
            snaps[qpos] = (channel_free, prev_exec_end, i_exec, issued)
            j = queue[qpos]
            w = windows[j]
            if w == -1:
                open_t = -load_s[j]
            elif w < i_exec:
                open_t = es[w]
            else:
                # window tile has not executed: its load is queued behind
                # this one => deadlock
                state.feasible = False
                return state
            t0 = open_t if open_t >= channel_free else channel_free
            t_issue = self._earliest_fit(t0, mem[j], issued, i_exec, ee)
            if t_issue is None:
                state.feasible = False
                return state
            ls[j] = t_issue
            le[j] = t_issue + load_s[j]
            channel_free = le[j]
            loaded[j] = True
            issued += mem[j]
            qpos += 1

        state.total_stall = stall_acc
        return state

    # ---- critical-path index -------------------------------------------

    def _scan_build(self, base: SimState, j: int, p_old: int) -> None:
        """Longest constraint path from every issue node to ``ee[j-1]``.

        The committed event system is a max-plus DAG; its constraint
        edges also hold in any relocation trial of tile *j* (with event
        times pointwise >= committed), so longest paths computed here
        lower-bound the trial's timing.  Edges:

        - channel:    issue(q) --l(x_q)--> issue(q')   (next queue slot;
                      position p_old -- tile j's old load -- is skipped,
                      it no longer sits between its neighbours)
        - load->exec: issue(q) --l(x_q)--> es(x_q)
        - exec chain: es(i)    --e(i)-->   es(i+1)
        - window:     es(w)    --0-->      issue(q), q reading window w
        - memory fit: es(r)    --e(r)-->   issue(q), where release r is
                      the first leaving room for x_q's bytes given the
                      trial's byte account (displaced positions carry
                      j's bytes as extra residency)

        Positions whose load can never fit alongside j's bytes make any
        trial displacing them infeasible; ``_scan_nofit`` records the
        largest such position.
        """
        n = self.n
        load_s, exec_s, mem = self.load_s, self.exec_s, self.mem
        rel_cum, capacity = self.rel_cum, self.capacity
        queue = base.queue
        snaps = base.snaps
        ls_of = base.load_start     # issue time by tile
        es_b = base.exec_start
        if base.win_readers is None:
            wr: List[List[int]] = [[] for _ in range(n)]
            for q, x in enumerate(queue):
                w = base.windows[x]
                if w >= 0:
                    wr[w].append(q)
            base.win_readers = wr
        win_readers = base.win_readers

        mem_j = mem[j]
        fit_readers: List[List[int]] = [[] for _ in range(n)]
        fit_rel_t: List[float] = [_NEG_INF] * (p_old + 1)
        q_nofit = -1
        for q in range(n):
            if q == p_old:
                continue
            x = queue[q]
            target = snaps[q][3] + mem[x] - capacity
            if q < p_old:
                target += mem_j
            if target <= 0.0:
                continue
            idx = bisect_left(rel_cum, target, 1, n + 1)
            if idx > n:
                if q < p_old and q > q_nofit:
                    q_nofit = q
            else:
                fit_readers[idx - 1].append(q)
                if q < p_old:
                    # absolute anchor: with j's bytes resident, this
                    # displaced load cannot issue before the committed
                    # time of its binding release
                    fit_rel_t[q] = base.exec_end[idx - 1]

        D_i = [_NEG_INF] * n        # issue nodes, by queue position
        D_e = [_NEG_INF] * (n + 1)  # exec-start nodes, by tile index
        # reverse-topological sweep: two sorted node families merged by
        # committed event time, descending.  At ties the issue node goes
        # first so a window-bound load (t_issue == es of its window, the
        # common base pattern) keeps its window edge; the opposite tie
        # (issue == exec-start of the same tile) needs a zero-duration
        # load and merely under-estimates -- the bound stays sound.
        qi = n - 1
        ei = n - 1
        while qi >= 0 or ei >= 0:
            t_q = ls_of[queue[qi]] if qi >= 0 else _NEG_INF
            if ei >= 0 and (qi < 0 or es_b[ei] > t_q):
                i = ei
                if i == j - 1:
                    d = exec_s[i]           # the probe: ee[j-1] itself
                else:
                    d = _NEG_INF
                    dn = D_e[i + 1]
                    if dn > _NEG_INF:
                        d = exec_s[i] + dn
                for q in win_readers[i]:
                    if q != p_old and D_i[q] > d:
                        d = D_i[q]
                for q in fit_readers[i]:
                    dq = D_i[q]
                    if dq > _NEG_INF and exec_s[i] + dq > d:
                        d = exec_s[i] + dq
                D_e[i] = d
                ei -= 1
            else:
                q = qi
                if q == p_old:
                    qi -= 1
                    continue                # skipped: D_i stays -inf
                x = queue[q]
                lw = load_s[x]
                d = _NEG_INF
                qn = q + 1 if q + 1 != p_old else q + 2
                if qn < n:
                    dn = D_i[qn]
                    if dn > _NEG_INF:
                        d = lw + dn
                de = D_e[x]
                if de > _NEG_INF and lw + de > d:
                    d = lw + de
                D_i[q] = d
                qi -= 1

        # suffix max of the absolute (le_j-independent) fit anchors:
        # displaced position q' >= p forces ee[j-1] >= release time +
        # LP(issue(q') -> ee[j-1]) whatever the relocated load's timing
        asuf = [_NEG_INF] * (p_old + 1)
        best = _NEG_INF
        for q in range(p_old - 1, -1, -1):
            ft = fit_rel_t[q]
            if ft > _NEG_INF and D_i[q] > _NEG_INF and ft + D_i[q] > best:
                best = ft + D_i[q]
            asuf[q] = best

        self._scan_base = base
        self._scan_j = j
        self._scan_D = D_i
        self._scan_abs = asuf
        self._scan_nofit = q_nofit
        # conservative float-error margin: LP regroups sums the replay
        # would do sequentially; discount worst-case accumulation error
        self._scan_margin = 1e-11 * (
            self._load_sum + self.exec_cum[-1] + abs(base.exec_end[-1])
        )

    # ---- suffix re-simulation ------------------------------------------

    def _sync_scratch(self, base: SimState) -> None:
        if self._scratch_of is not base:
            # new committed state: refresh the whole scratch
            self._s_le[:] = base.load_end
            self._s_es[:] = base.exec_start
            self._s_ee[:] = base.exec_end
            self._scratch_of = base
        else:
            # patch back only what the previous trial overwrote
            e0, e1 = self._dirty_exec
            if e1 > e0:
                self._s_es[e0:e1] = base.exec_start[e0:e1]
                self._s_ee[e0:e1] = base.exec_end[e0:e1]
            for x in self._dirty_loads:
                self._s_le[x] = base.load_end[x]
        self._dirty_exec = (0, 0)
        self._dirty_loads = []

    def try_relocation(
        self, base: SimState, j: int, new_window: int, abort_stall: float
    ) -> Tuple[bool, float, float]:
        """Re-simulate ``base`` with tile j's load moved to ``new_window``.

        Replays only the queue suffix from the relocated load's new
        position.  The trial is abandoned as soon as either

        (a) its accumulated stall reaches ``abort_stall`` (it could no
            longer be accepted), or
        (b) it is *dominated* by the committed state: at an aligned
            queue position (both sides have issued the same load set)
            with no live event earlier than the committed one, every
            remaining trial event is pointwise >= the committed event,
            so the trial's final makespan -- and therefore its total
            stall (makespan minus the fixed execution sum) -- is >= the
            committed total and the acceptance test must fail.

        For (b) the replay tracks *early* divergences only: a load end
        earlier than committed is live until its tile executes (the exec
        start consumes it), an exec time earlier than committed is live
        forever (window opens and release queries read it).  Equal or
        later event times preserve dominance by the monotonicity of
        ``max``, ``+``, and the release account.  Most rejected
        relocations therefore cost O(queue distance moved), not O(n).

        Returns (acceptable, total_stall, stall_of_j); on abort,
        dominance, or infeasibility, (False, inf, inf).
        """
        n = self.n
        p = bisect_left(base.queue_keys, (new_window, j))
        p_old = base.qpos_of[j]
        channel_free, prev_exec_end, i_exec, issued = base.snaps[p]
        i_exec0 = i_exec
        stall_acc = base.stall_cum[i_exec]
        stall_j = math.inf
        load_s, exec_s, mem = self.load_s, self.exec_s, self.mem
        rel_cum, capacity = self.rel_cum, self.capacity
        base_le, base_es, base_ee = base.load_end, base.exec_start, base.exec_end

        # ---- step 0 against committed state: tile j's relocated load --
        # Nothing is replayed yet, so the issue time of the moved load is
        # computable exactly from the committed arrays in O(log n).
        if new_window == -1:
            open_t = -load_s[j]
        elif new_window < i_exec:
            open_t = base_es[new_window]
        else:
            return False, math.inf, math.inf    # window not executed yet
        t0 = open_t if open_t >= channel_free else channel_free
        mem_j = mem[j]
        r0 = bisect_right(base_ee, t0, 0, i_exec)
        if issued - rel_cum[r0] + mem_j <= capacity:
            t_issue_j = t0
        else:
            idx = bisect_left(
                rel_cum, issued + mem_j - capacity, r0 + 1, i_exec + 1
            )
            if idx > i_exec:
                return False, math.inf, math.inf
            t_issue_j = base_ee[idx - 1]
        le_j = t_issue_j + load_s[j]
        if le_j >= base_le[j]:
            # the relocated load cannot finish earlier than committed, so
            # tile j's execution -- and by the dominance induction every
            # other event -- is >= the committed one: never accepted
            return False, math.inf, math.inf

        # ---- critical-path reject (zero replay) ------------------------
        # A relocation inserts j's load at queue position p, so the
        # trial adds one constraint to the committed event system: the
        # load at position p starts no earlier than le_j.  All committed
        # constraint edges (serial channel, load->exec, serial exec
        # chain, window opens, memory fits) still hold in the trial with
        # event times pointwise >= committed, so the longest constraint
        # path from position p's issue node to ee[j-1] lower-bounds the
        # trial's exec chain into j:
        #
        #     ee_t[j-1] >= le_j + LP(issue(p) -> ee[j-1])
        #
        # If that already reaches the committed exec start of j, tile
        # j's execution cannot improve and (by the dominance induction)
        # neither can the total: the trial is rejected without replaying
        # anything.  LP over all positions is one O(n log n) backward
        # pass per (committed state, tile) scan; each candidate window
        # then costs O(1).  This is the payoff of the event-indexed
        # engine: the planner's scan queries an index instead of
        # replaying the timeline.
        if self._scan_base is not base or self._scan_j != j:
            self._scan_build(base, j, p_old)
        if p < p_old:
            if p <= self._scan_nofit:
                return False, math.inf, math.inf    # can never fit with j
            margin = self._scan_margin
            d = self._scan_D[p]
            if (
                d > _NEG_INF
                and le_j + d - margin >= base_es[j]
            ):
                return False, math.inf, math.inf
            if self._scan_abs[p] - margin >= base_es[j]:
                return False, math.inf, math.inf

        self._sync_scratch(base)
        le, es, ee = self._s_le, self._s_es, self._s_ee
        dirty_loads = self._dirty_loads

        qpos_of = base.qpos_of

        # trial queue = base queue with j's load moved from p_old to p;
        # resolved lazily so a short replay never pays O(n) setup.  A
        # tile's *trial* queue position is derived from its base
        # position (entries in [p, p_old) shift one slot later), so the
        # loaded test needs no per-trial structure at all.
        base_windows = base.windows
        base_queue = base.queue
        base_snaps = base.snaps

        # early-divergence liveness: a load end earlier than committed is
        # live until its tile executes; an exec earlier than committed is
        # live until (a) no future load opens on its window and (b) the
        # committed channel frontier has passed its committed release
        # time (then both sides count the release identically in every
        # future memory query)
        if base.last_read_q is None:
            lr = [-1] * n
            for pos, x in enumerate(base_queue):
                w = base_windows[x]
                if w >= 0:
                    lr[w] = pos
            base.last_read_q = lr
        last_read_q = base.last_read_q
        early_exec: set = set()   # execs earlier than committed, maybe live
        early_le: set = set()     # loads ending earlier, not yet executed

        qfront = p                # p + qidx: trial channel frontier
        feasible = True
        while i_exec < n:
            q_i = qpos_of[i_exec]
            if (q_i + 1 if p <= q_i < p_old else (p if i_exec == j else q_i)) < qfront:
                le_i = le[i_exec]
                start = prev_exec_end if prev_exec_end >= le_i else le_i
                s = start - prev_exec_end
                if s > 0.0:
                    stall_acc += s
                if i_exec == j:
                    stall_j = s if s > 0.0 else 0.0
                if stall_acc >= abort_stall:
                    feasible = False
                    break
                end = start + exec_s[i_exec]
                # start < committed start iff end < committed end: both
                # add the same exec_s with the same rounding
                if start < base_es[i_exec]:
                    early_exec.add(i_exec)
                if early_le:
                    early_le.discard(i_exec)
                es[i_exec] = start
                ee[i_exec] = end
                prev_exec_end = end
                i_exec += 1
                continue
            if qfront > p_old and qfront < n and not early_le:
                sc, sp, si, sb = base_snaps[qfront]
                if (
                    channel_free >= sc
                    and prev_exec_end >= sp
                    and i_exec == si
                    and issued == sb
                ):
                    for i in early_exec:
                        if last_read_q[i] >= qfront or base_ee[i] > sc:
                            break
                    else:
                        # every early divergence is dead and every
                        # remaining event is >= the committed one: total
                        # stall >= committed total, reject now
                        feasible = False
                        break
            if qfront >= n:
                feasible = False
                break
            if qfront == p:
                x = j
                w = new_window
            else:
                qa = qfront - 1
                x = base_queue[qa] if qa < p_old else base_queue[qa + 1]
                w = base_windows[x]
            if w == -1:
                open_t = -load_s[x]
            elif w < i_exec:
                open_t = es[w]
            else:
                feasible = False
                break
            t0 = open_t if open_t >= channel_free else channel_free
            # inlined earliest-fit over the release prefix-sum
            mem_x = mem[x]
            r0 = bisect_right(ee, t0, 0, i_exec)
            if issued - rel_cum[r0] + mem_x <= capacity:
                t_issue = t0
            else:
                idx = bisect_left(
                    rel_cum, issued + mem_x - capacity, r0 + 1, i_exec + 1
                )
                if idx > i_exec:
                    feasible = False
                    break
                t_issue = ee[idx - 1]
            le_x = t_issue + load_s[x]
            le[x] = le_x
            dirty_loads.append(x)
            if le_x < base_le[x]:
                early_le.add(x)
            channel_free = le_x
            issued += mem_x
            qfront += 1

        self._dirty_exec = (i_exec0, i_exec)
        if not feasible:
            return False, math.inf, math.inf
        return True, stall_acc, stall_j
    # NOTE: ``stall_j`` above is exact because tile j's execution always
    # lies inside the replayed suffix: at the snapshot its load is not yet
    # issued, so its execution cannot have been scheduled.  The dominance
    # abort never fires while tile j's relocated (usually earlier) load
    # end is live, so a trial that actually improves j runs to completion
    # and reports its exact stall.
