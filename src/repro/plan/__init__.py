"""repro.plan: the unified planning subsystem.

Single planning path for the whole repo -- the two-phase weight-transfer
heuristic (paper SS III) planned once into an :class:`ExecutionPlan` IR
that scheduling (``core.scheduler``), streaming (``core.streaming``),
simulation (``core.simulator``), and serving (``runtime.serving``) all
consume.  See DESIGN.md.

- ``ir``:        ExecutionPlan / Timeline (tiles + windows + resolved
                 timeline + vectorized residency account)
- ``engine``:    event-indexed engine (critical-path trial rejection,
                 suffix re-simulation, prefix-sum memory queries)
- ``planner``:   two-phase planner (bit-identical to the reference)
                 plus the SearchConfig beam/anneal search layer
- ``partition``: multi-PU pipeline partitioning (contiguous layer
                 ranges balanced on exec time, per-PU scheduling)
- ``cache``:     content-hashed plan cache (search-strategy aware)
"""
from repro.plan.cache import PLAN_CACHE, PlanCache, plan_cached, plan_key
from repro.plan.ir import ExecutionPlan, Timeline, infeasible_plan
from repro.plan.partition import (
    PartitionedPlan,
    StagePlan,
    balance_layer_ranges,
    partition_gemms,
    partition_layers,
    snap_boundaries_nonempty,
)
from repro.plan.planner import SearchConfig, plan

__all__ = [
    "ExecutionPlan",
    "Timeline",
    "infeasible_plan",
    "plan",
    "SearchConfig",
    "plan_cached",
    "plan_key",
    "PlanCache",
    "PLAN_CACHE",
    "PartitionedPlan",
    "StagePlan",
    "balance_layer_ranges",
    "partition_gemms",
    "partition_layers",
    "snap_boundaries_nonempty",
]
