"""Distributed runtime: step factories, fault-tolerant train loop,

batched serving engine with the paper's weight-streaming scheduler, and
the stage-parallel multi-PU streaming executor (``pipeline_exec``) that
runs partitioned plans for real.
"""
