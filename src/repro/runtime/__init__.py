"""Distributed runtime: step factories, fault-tolerant train loop,

batched serving engine with the paper's weight-streaming scheduler.
"""
