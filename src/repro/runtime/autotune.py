"""Auto-tune microbatch depth M and handoff queue depth per partition.

The GPipe bubble floor (K-1)/(M+K-1) says how deep a microbatch burst
must be before the fill/drain cost amortizes, but the *executed* bubble
of a real partition also carries handoff transfers and stage imbalance
(``BENCH_stream.json`` measures ~1.1-1.2x the analytic floor).  Instead
of hard-coding M, :func:`tune_pipeline` closes the loop with the
runtime: it seeds M from the analytic floor for the requested target
bubble, then *measures* the executed bubble through
:class:`runtime.pipeline_exec.StagePipelineExecutor` and walks M until
the measurement lands inside the tolerance band (or the measurement is
as close to it as the discrete M grid allows).  Measured bubble is
monotone non-increasing in M, so the walk terminates after a handful of
executor runs (each is a full microbatched execution of the partition).

Queue depth is tuned second, at the chosen M: the virtual-time account
is depth-invariant by construction (bounded queues pace *real* threads,
not the event clock), so depth selection uses the real wall time of the
threaded run and keeps the smallest depth within ``wall_tolerance`` of
the best -- deeper queues only buy memory pressure.

Every trial is recorded in the result so benchmarks and serving stats
can show the tuning trajectory, not just the outcome.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, List, Optional, Sequence, Tuple

from repro.plan.partition import PartitionedPlan
from repro.runtime.pipeline_exec import (
    FetchFn,
    PipelineReport,
    RunTileFn,
    StagePipelineExecutor,
    execute_partitioned_plan,
)


@dataclasses.dataclass(frozen=True)
class AutotuneConfig:
    target_bubble: float = 0.10    # requested fill/drain bubble fraction
    # acceptance is one-sided: measured bubble <= target * (1 + tol)
    # (undershoot costs nothing; the walk still steps M down toward the
    # band so depth is not overspent)
    tolerance: float = 0.10
    m_min: int = 1
    m_max: int = 64
    max_trials: int = 12           # executor runs spent on the M walk
    queue_depths: Tuple[int, ...] = (2, 3, 4)
    wall_tolerance: float = 0.25   # depth must be within 25% of best wall


@dataclasses.dataclass
class AutotuneResult:
    n_microbatches: int
    queue_depth: int
    bubble_measured: float
    target_bubble: float
    within_tolerance: bool
    measured_fps: float
    analytic_m: int                # the GPipe seed the walk started from
    trials: List[dict]             # every (M, bubble, fps) evaluated
    depth_trials: List[dict]       # every (depth, wall_s) evaluated
    report: PipelineReport         # executor report at the chosen point

    def summary(self) -> dict:
        return {
            "n_microbatches": float(self.n_microbatches),
            "queue_depth": float(self.queue_depth),
            "bubble_measured": self.bubble_measured,
            "target_bubble": self.target_bubble,
            "within_tolerance": self.within_tolerance,
            "measured_fps": self.measured_fps,
            "analytic_m": float(self.analytic_m),
            "trials": self.trials,
            "depth_trials": self.depth_trials,
        }


def analytic_microbatches(n_stages: int, target_bubble: float) -> int:
    """Smallest M with the GPipe floor (K-1)/(M+K-1) <= target."""
    if n_stages <= 1 or target_bubble >= 1.0:
        return 1
    if target_bubble <= 0.0:
        raise ValueError("target_bubble must be positive")
    return max(1, math.ceil((n_stages - 1) * (1.0 - target_bubble)
                            / target_bubble))


@dataclasses.dataclass
class StagedDecodeTune:
    """Result of tuning the *overlapped staged decode* schedule: lane
    groups M (must divide the slot batch) and handoff queue depth."""

    n_groups: int
    queue_depth: int
    lanes: int
    bubble_measured: float          # executed bubble at the chosen point
    target_bubble: float
    within_tolerance: bool
    virtual_fps: float              # lane-group frames / virtual makespan
    trials: List[dict]              # every (m, bubble, fps) evaluated
    depth_trials: List[dict]
    report: PipelineReport

    def summary(self) -> dict:
        return {
            "n_groups": float(self.n_groups),
            "queue_depth": float(self.queue_depth),
            "lanes": float(self.lanes),
            "bubble_measured": self.bubble_measured,
            "target_bubble": self.target_bubble,
            "within_tolerance": self.within_tolerance,
            "virtual_fps": self.virtual_fps,
            "trials": self.trials,
            "depth_trials": self.depth_trials,
        }


def _probe_staged_decode(
    plan: PartitionedPlan, m: int, rounds: int, queue_depth: int
) -> PipelineReport:
    """One functional overlapped-decode block: R rounds of M lane-group
    frames with the real cross-round dependency chain (round r+1 of a
    group enters stage 0 at the virtual time round r drained), no model
    compute.  Measures the executed bubble the schedule actually
    achieves -- fill, imbalance, and the sampling round-trip included."""
    ex = StagePipelineExecutor(plan, queue_depth=queue_depth)
    session = ex.open_session()
    scale = 1.0 / m
    try:
        for g in range(m):
            session.put(g, ready_t=0.0, scale=scale, round_id=0)
        for i in range(rounds * m):
            frame, _payload, end_t = session.get()
            r, g = divmod(frame, m)
            if r + 1 < rounds:
                session.put(g, ready_t=end_t, scale=scale, round_id=r + 1)
    except BaseException:
        session.abort()
        raise
    return session.close()


def tune_staged_decode(
    plan: PartitionedPlan,
    lanes: int,
    cfg: AutotuneConfig = AutotuneConfig(),
    *,
    probe_rounds: int = 16,
) -> StagedDecodeTune:
    """Tune lane-group count M and queue depth for overlapped staged
    decode on ``plan`` with a ``lanes``-slot batch.

    Candidate M are the divisors of ``lanes`` (lane groups must tile the
    slot batch so per-group state slices stay static shapes).  Every
    candidate is probed with a functional overlapped block and the
    *executed* bubble decides, exactly like :func:`tune_pipeline`: the
    smallest M inside the one-sided tolerance band wins.  When no
    candidate reaches the band (an imbalance- or stall-dominated plan
    whose bubble floor no M can cross), the knee rule applies: the
    smallest M whose bubble is within a quarter of the observed spread
    of the best -- deeper lane splitting costs real dispatch overhead
    per frame, so it must buy measurable bubble to be worth it.  Queue
    depth is then picked by real wall time at the chosen M (virtual
    metrics are depth-invariant)."""
    if lanes < 1:
        raise ValueError("lanes must be >= 1")
    hi_band = cfg.target_bubble * (1.0 + cfg.tolerance)
    divisors = [m for m in range(1, lanes + 1) if lanes % m == 0]
    divisors = [m for m in divisors if cfg.m_min <= m <= cfg.m_max] or [1]

    trials: List[dict] = []
    reps = {}
    best_m = None
    for m in divisors:
        rep = _probe_staged_decode(plan, m, probe_rounds, queue_depth=2)
        reps[m] = rep
        trials.append(
            {"m": m, "bubble": rep.bubble_measured,
             "fps": rep.measured_fps, "wall_s": rep.wall_s}
        )
        if rep.bubble_measured <= hi_band:
            # divisors ascend, so the first M inside the band is the
            # smallest -- stop before spending probes on deeper splits
            best_m = m
            break
    if best_m is None:
        # knee rule over the full probe set
        bubbles = {m: reps[m].bubble_measured for m in reps}
        b_min, b_max = min(bubbles.values()), max(bubbles.values())
        knee = b_min + 0.25 * (b_max - b_min)
        best_m = min(m for m, b in bubbles.items() if b <= knee)
    best_rep = reps[best_m]
    within = best_rep.bubble_measured <= hi_band

    depth_trials: List[dict] = []
    depths = sorted(set(cfg.queue_depths)) or [2]
    chosen_depth, chosen_rep = depths[0], None
    if depths == [2]:
        chosen_rep = best_rep
    else:
        drep = {}
        for d in depths:
            r = best_rep if d == 2 else _probe_staged_decode(
                plan, best_m, probe_rounds, queue_depth=d
            )
            drep[d] = r
            depth_trials.append({"depth": d, "wall_s": r.wall_s,
                                 "bubble": r.bubble_measured})
        best_wall = min(r.wall_s for r in drep.values())
        for d in depths:
            if drep[d].wall_s <= best_wall * (1.0 + cfg.wall_tolerance):
                chosen_depth, chosen_rep = d, drep[d]
                break
    assert chosen_rep is not None

    return StagedDecodeTune(
        n_groups=best_m,
        queue_depth=chosen_depth,
        lanes=lanes,
        bubble_measured=chosen_rep.bubble_measured,
        target_bubble=cfg.target_bubble,
        within_tolerance=within,
        virtual_fps=chosen_rep.measured_fps,
        trials=trials,
        depth_trials=depth_trials,
        report=chosen_rep,
    )


def tune_pipeline(
    plan: PartitionedPlan,
    cfg: AutotuneConfig = AutotuneConfig(),
    *,
    fetch: Optional[FetchFn] = None,
    run_tile: Optional[RunTileFn] = None,
    payloads_of: Optional[Callable[[int], Sequence[Any]]] = None,
) -> AutotuneResult:
    """Tune (M, queue depth) for ``plan`` against ``cfg.target_bubble``.

    ``payloads_of(M)`` supplies the microbatch payloads for a trial at
    depth M (defaults to ``range(M)`` -- the functional-validation mode
    the stream bench uses).
    """
    K = len(plan.stages)
    lo_band = cfg.target_bubble * (1.0 - cfg.tolerance)
    hi_band = cfg.target_bubble * (1.0 + cfg.tolerance)
    seen: dict = {}
    trials: List[dict] = []

    def run_m(m: int) -> PipelineReport:
        if m in seen:
            return seen[m]
        payloads = list(payloads_of(m)) if payloads_of else list(range(m))
        # depth pinned explicitly: the depth-tuning loop reuses these
        # reports as the depth-2 trials
        rep = execute_partitioned_plan(
            plan, n_microbatches=m, fetch=fetch, run_tile=run_tile,
            payloads=payloads, queue_depth=2,
        )
        seen[m] = rep
        trials.append(
            {"m": m, "bubble": rep.bubble_measured,
             "fps": rep.measured_fps, "wall_s": rep.wall_s}
        )
        return rep

    m = min(max(analytic_microbatches(K, cfg.target_bubble), cfg.m_min),
            cfg.m_max)
    analytic_m = m
    best_m, best_rep, best_err = None, None, math.inf

    rep = run_m(m)
    while True:
        b = rep.bubble_measured
        err = abs(b - cfg.target_bubble)
        if err < best_err or (err == best_err and (best_m is None or m < best_m)):
            best_m, best_rep, best_err = m, rep, err
        if lo_band <= b <= hi_band:
            break
        if len(trials) >= cfg.max_trials:
            break
        if b > hi_band:
            # too much fill cost: deepen the burst (bubble ~ (K-1)/(M+K-1),
            # so jump to the M that analytic scaling predicts, minimum +1)
            if m >= cfg.m_max:
                break
            nxt = max(m + 1, math.ceil((m + K - 1) * b / cfg.target_bubble)
                      - (K - 1))
            m = min(nxt, cfg.m_max)
        else:
            # bubble below band: a shallower burst frees latency/memory
            if m <= cfg.m_min:
                break
            m = max(m - 1, cfg.m_min)
        if m in seen:
            break
        rep = run_m(m)

    assert best_m is not None and best_rep is not None
    # the band may be unreachable on the discrete M grid (or capped by
    # m_min/m_max), so "within tolerance" is one-sided: the executed
    # bubble must not exceed the band's upper edge (undershoot is free)
    within = best_rep.bubble_measured <= hi_band

    # queue depth: virtual metrics are depth-invariant, so pick the
    # smallest configured depth whose real wall time is within tolerance
    # of the best.  The M walk already executed at depth 2, so that
    # configuration is reused rather than re-run.
    depth_trials: List[dict] = []
    depths = sorted(set(cfg.queue_depths)) or [2]
    chosen_depth = depths[0]
    chosen_rep = best_rep if depths[0] == 2 else None
    if depths != [2]:
        reps = {}
        for d in depths:
            if d == 2:
                r = best_rep
            else:
                payloads = (
                    list(payloads_of(best_m)) if payloads_of
                    else list(range(best_m))
                )
                r = execute_partitioned_plan(
                    plan, n_microbatches=best_m, fetch=fetch,
                    run_tile=run_tile, payloads=payloads, queue_depth=d,
                )
            reps[d] = r
            depth_trials.append({"depth": d, "wall_s": r.wall_s,
                                 "bubble": r.bubble_measured})
        best_wall = min(r.wall_s for r in reps.values())
        for d in depths:
            if reps[d].wall_s <= best_wall * (1.0 + cfg.wall_tolerance):
                chosen_depth = d
                chosen_rep = reps[d]
                break
    assert chosen_rep is not None

    return AutotuneResult(
        n_microbatches=best_m,
        queue_depth=chosen_depth,
        bubble_measured=chosen_rep.bubble_measured,
        target_bubble=cfg.target_bubble,
        within_tolerance=within,
        measured_fps=chosen_rep.measured_fps,
        analytic_m=analytic_m,
        trials=trials,
        depth_trials=depth_trials,
        report=chosen_rep,
    )
