"""True per-stage decode: each pipeline stage runs its model-layer slice.

``ServingEngine.execute_partition()`` validated the stage-parallel
runtime with functional tiles; this module closes the gap between "we
report pipeline throughput" and "we serve tokens through the pipeline".
A :class:`StagedDecodeRunner` binds a :class:`PartitionedPlan` whose
stages carry ``decode_layer_start/stop`` (attached by
``serving.plan_partitioned_streaming``, snapped to the family's
``decode_slice_points``) to the model's layer-sliced decode entry points
(``ModelAPI.slice_params`` / ``slice_cache`` / ``decode_embed`` /
``decode_stage`` / ``decode_unembed``).  The runner is agnostic to
``cfg.decode_kernels``: the fused Pallas decode kernels live *below*
``decode_stage`` (models dispatch per-op via ``repro.kernels.dispatch``),
so both the serial reference and the overlapped schedule pick them up
with no changes here.

Entry points:

- per-stage **param slices** are materialized once (and re-sliced when
  the bound params change, e.g. an AIMC NIU refresh);
- per-stage **KV/state caches** are sliced from the engine's master
  cache when a decode block starts and concatenated back before the next
  admission scatters fresh lanes (``load_cache`` / ``export_cache``).
  With lane groups (``n_groups > 1``) each stage's slice is further
  split along the *lane* axis into M static per-group slices, so the
  same jitted stage cell serves every group;
- decode rounds push live hidden states through
  :class:`runtime.pipeline_exec.StagePipelineExecutor`: the first stage
  embeds the token batch, every stage folds its layer slice (updating
  its cache slice in place), the last stage unembeds to logits.

Two schedules drive the executor:

- :meth:`decode_round` -- the **serial M=1 reference**: one full-batch
  frame per round through its own pipeline run, with separate
  embed/stage/unembed cells and the post-decode update applied by the
  caller.  All fill bubble, but structurally bit-identical to the fused
  single-PU ``decode_step`` by construction (every family implements
  ``decode_step`` as exactly the one-stage composition of the same
  entry points).  Kept as the A/B reference the way ``--host-sampling``
  is.
- :meth:`decode_block` -- the **overlapped schedule**: each round is M
  lane-group frames flowing through a *persistent*
  :class:`~repro.runtime.pipeline_exec.PipelineSession` that stays open
  across consecutive blocks (between admission barriers), with round
  r+1 of a group entering stage 0 as soon as round r of that group
  drains (its sampled token is the next round's input).  Stage s
  computes group g while stage s-1 computes g+1 *and* rounds overlap
  across the boundary, so the fill bubble is paid once per barrier
  interval, not once per round or block.  The hot path is two fused
  jitted cells per frame -- embed folds into the first stage's cell and
  unembed + the post-decode state transition fold into the last
  stage's -- dispatched from the stage threads; the coordinator does
  pure queue work.  Greedy sampling is per-lane argmax, so splitting
  the batch along lanes preserves bit-identity with the fused loop on
  dense configs.

Both schedules keep the executor's weight-streaming account and virtual
clock; the clock is cross-checked per frame against the plan's
recurrence (``pipeline_events`` / ``decode_pipeline_events`` --
``clock_ok``), with the persistent session's clock rebased by the last
drain time at each block boundary (the host sync between blocks is a
true barrier, so the rebased recurrence is exact).

When every stage lives on the *same* physical device (the single-host
simulation, or shared stage submeshes), the threaded schedule cannot
overlap anything real: one execution stream serializes all stage
compute, and each extra lane-group frame re-traverses the full weight
working set, so wall clock strictly degrades with M while the virtual
clock improves.  ``coalesce=True`` keeps the overlapped *schedule*
(frame order, virtual account, recurrence cross-check at warmup) but
executes each block as one jitted ``lax.scan`` over rounds whose body
chains every stage's cell back-to-back per lane group --
numerically the same staged computation (per-stage param/cache
slices), dispatched once per block instead of twice per frame.  The
virtual account for coalesced blocks is the analytic recurrence
itself, which the threaded warmup block has already validated
(``clock_ok``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.plan.partition import PartitionedPlan
from repro.runtime.pipeline_exec import (
    PipelineReport,
    PipelineSession,
    StagePipelineExecutor,
)


class StagedDecodeRunner:
    """Drive decode rounds through the stage-parallel pipeline executor.

    ``on_trace(kind)`` (optional) is called whenever one of the runner's
    jitted cells traces, so the owning engine's retrace accounting covers
    the staged path too.  ``n_groups`` is the lane-group microbatch
    count M (1 = the serial reference schedule); ``configure`` changes
    it between blocks (caches must be re-loaded after).

    ``postdecode(state, logits) -> state`` (optional) is the pure
    per-lane post-decode transition; when given, it is fused into the
    last stage's jitted cell so the overlapped schedule's frames carry
    their own state update (2 dispatches per frame instead of 5, none
    on the coordinator thread).  Without it, :meth:`decode_block`
    applies its ``update`` callback on the last-stage thread instead.
    """

    def __init__(
        self,
        cfg,
        api,
        params,
        plan: PartitionedPlan,
        *,
        stage_meshes: Optional[Sequence[Any]] = None,
        n_groups: int = 1,
        queue_depth: int = 2,
        on_trace=None,
        postdecode: Optional[Callable[[Any, Any], Any]] = None,
        coalesce: bool = False,
    ):
        self.cfg = cfg
        self.api = api
        self.plan = plan
        self.ranges: List[Tuple[int, int]] = [
            s.decode_layers for s in plan.stages
        ]
        L = cfg.n_layers
        pts = set(api.decode_slice_points(cfg))
        cursor = 0
        for start, stop in self.ranges:
            if start != cursor or stop < start or stop > L:
                raise ValueError(
                    f"stage decode ranges {self.ranges} do not tile "
                    f"[0, {L}) contiguously"
                )
            if start not in pts or stop not in pts:
                raise ValueError(
                    f"stage range ({start}, {stop}) not on the family's "
                    f"slice points {sorted(pts)}"
                )
            cursor = stop
        if cursor != L:
            raise ValueError(
                f"stage decode ranges {self.ranges} do not cover all "
                f"{L} layers"
            )
        self._on_trace = on_trace or (lambda kind: None)
        self._postdecode = postdecode

        def _embed(p, tokens, pos):
            self._on_trace("decode")
            return api.decode_embed(cfg, p, tokens, pos)

        def _stage(sp, h, sc, pos):
            self._on_trace("decode")
            return api.decode_stage(cfg, sp, h, sc, pos)

        def _unembed(p, h):
            self._on_trace("decode")
            return api.decode_unembed(cfg, p, h)

        # the serial M=1 reference path keeps separate cells (the same
        # jit-boundary structure the staged path originally shipped with)
        self._embed_fn = jax.jit(_embed)
        self._stage_fn = jax.jit(_stage, donate_argnums=(2,))
        self._unembed_fn = jax.jit(_unembed)

        # fused cells for the overlapped schedule: embed belongs to the
        # first stage, unembed (and the post-decode transition, when
        # bound) to the last -- one dispatch per (stage, frame)
        def _cell_first(p, sp, sc, tokens, pos):
            self._on_trace("decode")
            h = api.decode_embed(cfg, p, tokens, pos)
            return api.decode_stage(cfg, sp, h, sc, pos)

        def _cell_last(p, sp, x, sc, state):
            self._on_trace("decode")
            h, sc = api.decode_stage(cfg, sp, x, sc, state["pos"])
            logits = api.decode_unembed(cfg, p, h)
            if postdecode is not None:
                return postdecode(state, logits), sc
            return logits, sc

        def _cell_single(p, sp, sc, state):
            self._on_trace("decode")
            h = api.decode_embed(cfg, p, state["tokens"], state["pos"])
            h, sc = api.decode_stage(cfg, sp, h, sc, state["pos"])
            logits = api.decode_unembed(cfg, p, h)
            if postdecode is not None:
                return postdecode(state, logits), sc
            return logits, sc

        # cache slices (and, when the transition is fused, the group
        # state) are donated: like the fused single-PU block, the KV
        # slice lives in the same device buffers round after round
        # instead of being copied through every scatter.  Without a
        # bound postdecode the cells return logits and the caller still
        # owns the state, so only the cache is donated.
        fused = postdecode is not None
        self._cell_first = jax.jit(_cell_first, donate_argnums=(2,))
        self._cell_last = jax.jit(
            _cell_last, donate_argnums=(3, 4) if fused else (3,)
        )
        self._cell_single = jax.jit(
            _cell_single, donate_argnums=(2, 3) if fused else (2,)
        )

        self.bound_params = None
        self.stage_params: List[Any] = []
        self.rebind(params)
        # stage_caches[k][g]: stage k's cache slice for lane group g
        # (n_groups == 1 keeps the whole stage slice in group 0)
        self.stage_caches: Optional[List[List[Any]]] = None
        self.n_groups = int(n_groups)
        self.queue_depth = int(queue_depth)
        self.rounds_executed = 0
        self.clock_ok = True
        self.last_report: Optional[PipelineReport] = None
        # cumulative virtual account across rounds/blocks, so the
        # executed bubble of a whole serving run is reportable
        self.virtual_busy_s = 0.0
        self.virtual_span_s = 0.0
        self._executor = StagePipelineExecutor(
            plan,
            run_stage=self._run_stage,
            stage_meshes=stage_meshes,
            queue_depth=queue_depth,
        )
        # the M=1 recurrence: one frame through all K stages
        self._expected_done_t = float(plan.pipeline_events(1)[-1, 0])
        # (n_groups, n_rounds) -> last-stage drain times of one
        # overlapped block's recurrence; block shapes come from a small
        # pow2 ladder, so the cache stays tiny
        self._expected_block: Dict[Tuple[int, int], Any] = {}
        # the persistent overlapped session (None between barriers):
        # _session_t is the virtual clock offset (last drain end),
        # _session_rounds the global round counter that keeps the
        # per-round tile loop amortization monotone across blocks
        self._session: Optional[PipelineSession] = None
        self._session_t = 0.0
        self._session_rounds = 0
        # block-mode context read by _run_stage from the stage threads
        # (queue handoffs order every access -- see decode_block)
        self._block_groups: Optional[List[Dict[str, Any]]] = None
        self._block_update: Optional[Callable] = None
        # single-device fast path: execute blocks as one scan per block
        # (see module docstring); jitted block fns keyed by
        # (n_groups, n_rounds) -- block lengths come from the engine's
        # pow2 ladder, so the cache stays tiny
        self.coalesce = bool(coalesce)
        self._co_fns: Dict[Tuple[int, int], Any] = {}
        # coalesced rounds / span not yet folded into the virtual
        # account; the span accrues per block because the host sync
        # between blocks rebases the next block's round-0 frames at the
        # previous block's last drain (same mini-barrier the threaded
        # session pays)
        self._co_rounds = 0
        self._co_span = 0.0
        # the barrier transforms (master cache -> per-stage/per-group
        # slices and back) are jitted: the eager ops would re-specialize
        # against the donated block outputs' layouts at *every* barrier
        # (tens of ms of recompilation per admission); compiled once at
        # warmup they dispatch in microseconds
        self._load_fns: Dict[int, Any] = {}
        self._export_fn = None

    # -- configuration ------------------------------------------------------

    def configure(
        self,
        n_groups: Optional[int] = None,
        queue_depth: Optional[int] = None,
    ) -> None:
        """Change the lane-group count / handoff queue depth (e.g. from
        the staged-decode autotuner).  Any open session is flushed and
        loaded caches are dropped: the group split is part of the cache
        layout."""
        self.flush()
        if n_groups is not None:
            if n_groups < 1:
                raise ValueError("n_groups must be >= 1")
            self.n_groups = int(n_groups)
        if queue_depth is not None:
            self.queue_depth = int(queue_depth)
            self._executor.queue_depth = int(queue_depth)
        self.stage_caches = None

    # -- param/cache residency ---------------------------------------------

    def rebind(self, params) -> None:
        """(Re-)slice per-stage params from ``params`` (cheap device
        slices; called once at construction and on NIU refreshes)."""
        self.bound_params = params
        self.stage_params = [
            self.api.slice_params(self.cfg, params, r) for r in self.ranges
        ]

    def load_cache(self, cache) -> None:
        """Slice the engine's master cache into per-stage, per-lane-group
        cache slices.  Cache leaves are layer-leading ``(L, B, ...)``:
        stage slices cut axis 0, lane groups cut axis 1 into M static
        chunks so every group's stage cell compiles once at the group
        width."""
        self.flush()
        M = self.n_groups
        lanes = {
            leaf.shape[1] for leaf in jax.tree.leaves(cache)
            if getattr(leaf, "ndim", 0) >= 2
        }
        B = max(lanes) if lanes else 0
        if M > 1 and B % M:
            raise ValueError(
                f"n_groups={M} does not divide the {B}-lane slot batch"
            )
        fn = self._load_fns.get(M)
        if fn is None:
            api, cfg, ranges = self.api, self.cfg, self.ranges

            def _load(cache):
                stage_slices = [
                    api.slice_cache(cfg, cache, r) for r in ranges
                ]
                if M == 1:
                    return [[s] for s in stage_slices]

                def _group(leaf, i):
                    g = leaf.shape[1] // M
                    return leaf[:, i * g:(i + 1) * g]

                return [
                    [
                        jax.tree.map(lambda leaf: _group(leaf, i), s)
                        for i in range(M)
                    ]
                    for s in stage_slices
                ]

            fn = jax.jit(_load)
            self._load_fns[M] = fn
        self.stage_caches = fn(cache)

    def export_cache(self):
        """Concatenate the per-stage (and per-lane-group) cache slices
        back into the master layout: lane groups rejoin on axis 1 in
        group order, stage slices on axis 0 in stage order.  Flushes the
        overlapped session first -- exporting IS the round-boundary
        barrier admissions synchronize on."""
        self.flush()
        if self.stage_caches is None:
            raise ValueError("no stage caches loaded")
        if self._export_fn is None:

            def _export(stage_caches):
                merged = [
                    groups[0] if len(groups) == 1 else jax.tree.map(
                        lambda *leaves: jnp.concatenate(leaves, axis=1),
                        *groups,
                    )
                    for groups in stage_caches
                ]
                return jax.tree.map(
                    lambda *leaves: jnp.concatenate(leaves, axis=0),
                    *merged,
                )

            self._export_fn = jax.jit(_export)
        return self._export_fn(self.stage_caches)

    # -- the decode schedules -----------------------------------------------

    def _run_stage(self, k: int, payload):
        K = len(self.ranges)
        if self._block_groups is None:
            # legacy / M=1 reference frame: the payload IS the
            # inter-stage handoff -- (tokens, pos, g) entering stage 0,
            # (hidden, pos, g) between stages, (logits, pos, g)
            # draining.  pos rides along because every stage's KV
            # scatter needs the per-lane positions, g selects the
            # stage's lane-group cache slice
            x, pos, g = payload
            if k == 0:
                x = self._embed_fn(self.bound_params, x, pos)
            x, self.stage_caches[k][g] = self._stage_fn(
                self.stage_params[k], x, self.stage_caches[k][g], pos
            )
            if k == K - 1:
                x = self._unembed_fn(self.bound_params, x)
            return (x, pos, g)

        # overlapped block mode: stage 0 frames carry only the group
        # index -- the group's decode state lives in _block_groups[g],
        # written solely by the last stage and re-read by stage 0 one
        # queue round-trip later (the handoff queues order every
        # cross-thread access)
        if k == 0:
            g = payload
            st = self._block_groups[g]
            if K == 1:
                out, self.stage_caches[0][g] = self._cell_single(
                    self.bound_params, self.stage_params[0],
                    self.stage_caches[0][g], st,
                )
                self._finish_group(g, out)
                return g
            x, self.stage_caches[0][g] = self._cell_first(
                self.bound_params, self.stage_params[0],
                self.stage_caches[0][g], st["tokens"], st["pos"],
            )
            return (x, st["pos"], g)
        x, pos, g = payload
        if k < K - 1:
            x, self.stage_caches[k][g] = self._stage_fn(
                self.stage_params[k], x, self.stage_caches[k][g], pos
            )
            return (x, pos, g)
        st = self._block_groups[g]
        out, self.stage_caches[k][g] = self._cell_last(
            self.bound_params, self.stage_params[k], x,
            self.stage_caches[k][g], st,
        )
        self._finish_group(g, out)
        return g

    def _finish_group(self, g: int, out) -> None:
        """Apply the frame's state transition on the last-stage thread:
        ``out`` is the fused new state when ``postdecode`` is bound,
        else the logits handed to the block's ``update`` callback.
        The non-fused branch re-reads the group state from
        ``_block_groups[g]`` itself: the cells only donate the state
        when the transition is fused, so the slot still holds live
        buffers here, and not threading ``st`` through the caller keeps
        every read on the safe side of the donation."""
        if self._postdecode is not None:
            self._block_groups[g] = out
        elif self._block_update is not None:
            st = self._block_groups[g]
            self._block_groups[g] = self._block_update(g, st, out)
        else:
            raise ValueError(
                "decode_block needs either a bound postdecode transition "
                "or an update callback"
            )

    def decode_round(self, tokens, pos):
        """One serial staged decode round -> logits (B, V): the M=1
        reference schedule (one full-batch frame through all K stages,
        its own pipeline run, all fill bubble).

        The token batch enters stage 0 (which embeds it), the hidden
        state flows through every stage's layer slice via the executor's
        handoff queues, and the last stage's unembed output drains as the
        frame payload.  Stage caches update in place."""
        if self.stage_caches is None:
            raise ValueError("load_cache() before decode_round()")
        if self.n_groups != 1:
            raise ValueError(
                "decode_round is the serial M=1 reference; use "
                "decode_block with n_groups > 1"
            )
        if self._session is not None:
            raise ValueError("flush() the overlapped session first")
        report = self._executor.run(
            [(tokens, jnp.asarray(pos, jnp.int32), 0)]
        )
        self.rounds_executed += 1
        self.last_report = report
        self.virtual_busy_s += sum(t.busy_s for t in report.stages)
        self.virtual_span_s += report.makespan_s
        # virtual-clock cross-check: the executed event stream must
        # reproduce the plan's single-frame recurrence
        tol = 1e-9 * max(1.0, abs(self._expected_done_t))
        if abs(report.frame_done_t[0] - self._expected_done_t) > tol:
            self.clock_ok = False
        logits, _, _ = report.outputs[0]
        return logits

    def _expected_drains(self, M: int, n_rounds: int) -> Tuple[float, ...]:
        """Per-frame expected drain times of an (M, n_rounds) block as
        host floats.  The analytic recurrence yields numpy scalars;
        converting once here, when a block shape is first seen, keeps
        per-frame clock checks free of host conversions on the decode
        hot path."""
        key = (M, n_rounds)
        cached = self._expected_block.get(key)
        if cached is None:
            drains = self.plan.decode_pipeline_events(
                M, n_rounds, 1.0 / M
            )[-1]
            # lint: disable=RPL002 -- one-time fill per block shape, a compile-like boundary, not per-frame
            cached = tuple(float(t) for t in drains)
            self._expected_block[key] = cached
        return cached

    def decode_block(
        self,
        groups: List[Dict[str, Any]],
        n_rounds: int,
        update: Optional[
            Callable[[int, Dict[str, Any], Any], Dict[str, Any]]
        ] = None,
        force_threaded: bool = False,
    ) -> List[Dict[str, Any]]:
        """``n_rounds`` overlapped rounds over M lane-group states.

        ``groups[g]`` is lane group g's decode-state dict (at least
        ``tokens`` (gsize, 1) and ``pos`` (gsize,)); the post-decode
        transition (the constructor's ``postdecode``, or else
        ``update(g, state_g, logits)``) runs on the last-stage thread
        and its result's ``tokens``/``pos`` feed the group's next
        round.  Returns the final group states (the same list, updated
        in place).

        Schedule: all M groups of round 0 are injected up front (they
        fill the pipeline); thereafter group g of round r+1 is injected
        the moment group g of round r drains -- the cross-round overlap.
        The session persists across consecutive blocks: the fill bubble
        is paid once per barrier interval (``flush`` / ``load_cache`` /
        ``export_cache`` close it), and each block's frames rebase the
        virtual clock by the previous block's last drain time -- the
        host sync between blocks is a true barrier, so the rebased
        ``PartitionedPlan.decode_pipeline_events`` recurrence stays an
        exact cross-check (``clock_ok``).  The handoff queues are FIFO,
        so frames drain in injection order and the block-local frame
        index is ``i = r*M + g``.

        With ``coalesce`` set (all stages on one physical device) the
        same schedule executes as a single jitted scan per block
        (``force_threaded=True`` overrides, e.g. for the warmup block
        that cross-checks the virtual clock through the real
        executor)."""
        M = self.n_groups
        if self.stage_caches is None:
            raise ValueError("load_cache() before decode_block()")
        if len(groups) != M:
            raise ValueError(f"got {len(groups)} group states for M={M}")
        if (
            self.coalesce
            and not force_threaded
            and update is None
            and self._postdecode is not None
        ):
            return self._decode_block_coalesced(groups, n_rounds)
        scale = 1.0 / M
        expected = self._expected_drains(M, n_rounds)

        if self._session is None:
            self._session = self._executor.open_session(
                queue_depth=self.queue_depth
            )
            self._session_t = 0.0
            self._session_rounds = 0
        session = self._session
        base = session.frames_in
        t0 = self._session_t
        r0 = self._session_rounds
        self._block_groups = groups
        self._block_update = update
        last_end = t0
        try:
            for g in range(M):
                session.put(g, ready_t=t0, scale=scale, round_id=r0)
            for _ in range(n_rounds * M):
                frame, g, end_t = session.get()
                r = (frame - base) // M
                want = t0 + expected[frame - base]
                tol = 1e-9 * max(1.0, abs(want))
                if abs(end_t - want) > tol:
                    self.clock_ok = False
                last_end = end_t
                if r + 1 < n_rounds:
                    session.put(
                        g, ready_t=end_t, scale=scale,
                        round_id=r0 + r + 1,
                    )
        except BaseException:
            self._session = None
            self._block_groups = None
            self._block_update = None
            session.abort()
            raise
        self._block_update = None
        self._session_t = last_end
        self._session_rounds += n_rounds
        self.rounds_executed += n_rounds
        return groups

    def _co_fn(self, M: int, n_rounds: int):
        """The jitted coalesced block for (M, n_rounds): a scan over
        rounds whose body chains every stage's layer slice (with the
        fused embed / unembed / post-decode transition) per lane group
        -- the overlapped schedule's work, one dispatch per block.
        Cache slices and group states are donated like the threaded
        cells'."""
        key = (M, n_rounds)
        fn = self._co_fns.get(key)
        if fn is not None:
            return fn
        api, cfg, post = self.api, self.cfg, self._postdecode
        K = len(self.ranges)

        def _block(p, sps, scs, groups):
            self._on_trace("decode")

            def body(carry, _):
                scs, groups = carry
                new_scs = [list(s) for s in scs]
                new_groups = list(groups)
                for g in range(M):
                    st = groups[g]
                    x = api.decode_embed(cfg, p, st["tokens"], st["pos"])
                    for k in range(K):
                        x, new_scs[k][g] = api.decode_stage(
                            cfg, sps[k], x, scs[k][g], st["pos"]
                        )
                    logits = api.decode_unembed(cfg, p, x)
                    new_groups[g] = post(st, logits)
                return (
                    tuple(tuple(s) for s in new_scs),
                    tuple(new_groups),
                ), None

            (scs, groups), _ = jax.lax.scan(
                body, (scs, groups), None, length=n_rounds
            )
            return scs, groups

        fn = jax.jit(_block, donate_argnums=(2, 3))
        self._co_fns[key] = fn
        return fn

    def _decode_block_coalesced(
        self, groups: List[Dict[str, Any]], n_rounds: int
    ) -> List[Dict[str, Any]]:
        """Run one overlapped block as a single scan (see module
        docstring).  The virtual account is the analytic recurrence,
        folded in at :meth:`flush` -- exactly what the threaded
        executor's clock reproduces (``clock_ok`` from the warmup
        block)."""
        M = self.n_groups
        if self._session is not None:
            # a threaded session epoch ends here: fold its account
            # before the coalesced rounds start their own
            self.flush()
        fn = self._co_fn(M, n_rounds)
        scs = tuple(tuple(s) for s in self.stage_caches)
        new_scs, new_groups = fn(
            self.bound_params, tuple(self.stage_params), scs, tuple(groups)
        )
        self.stage_caches = [list(s) for s in new_scs]
        for g in range(M):
            groups[g] = new_groups[g]
        self.rounds_executed += n_rounds
        self._co_rounds += n_rounds
        # span folds per block: between blocks the host syncs (the
        # engine inspects drained state), so the next block's recurrence
        # starts with all M frames ready at the previous block's last
        # drain -- spans of consecutive blocks simply add
        self._co_span += self._expected_drains(M, n_rounds)[-1]
        return groups

    def flush(self) -> None:
        """Close the persistent overlapped session (if open) and fold
        its executed trace into the cumulative virtual account.  The
        round-boundary barrier: admissions/evictions (which mutate slot
        membership) and reconfiguration call this, paying the next
        block's fill bubble exactly where the schedule requires it."""
        if self._co_rounds:
            # fold pending coalesced rounds analytically: M*R frames at
            # scale 1/M give each stage R * stage_s of busy time; the
            # span accrued per block (see _decode_block_coalesced)
            R = self._co_rounds
            self._co_rounds = 0
            self.virtual_busy_s += R * sum(
                s.stage_s for s in self.plan.stages
            )
            self.virtual_span_s += self._co_span
            self._co_span = 0.0
        session, self._session = self._session, None
        self._block_groups = None
        self._block_update = None
        if session is None:
            return
        report = session.close()
        self.last_report = report
        self.virtual_busy_s += sum(t.busy_s for t in report.stages)
        self.virtual_span_s += report.makespan_s

    @property
    def bubble_fraction(self) -> float:
        """Cumulative executed bubble across every round/block so far:
        1 - busy / (K * span) over the accumulated virtual account.
        (``flush()`` first to fold an open session.)"""
        K = len(self.plan.stages)
        if self.virtual_span_s <= 0:
            return 0.0
        return 1.0 - self.virtual_busy_s / (K * self.virtual_span_s)
