"""True per-stage decode: each pipeline stage runs its model-layer slice.

``ServingEngine.execute_partition()`` validated the stage-parallel
runtime with functional tiles; this module closes the gap between "we
report pipeline throughput" and "we serve tokens through the pipeline".
A :class:`StagedDecodeRunner` binds a :class:`PartitionedPlan` whose
stages carry ``decode_layer_start/stop`` (attached by
``serving.plan_partitioned_streaming``, snapped to the family's
``decode_slice_points``) to the model's layer-sliced decode entry points
(``ModelAPI.slice_params`` / ``slice_cache`` / ``decode_embed`` /
``decode_stage`` / ``decode_unembed``):

- per-stage **param slices** are materialized once (and re-sliced when
  the bound params change, e.g. an AIMC NIU refresh);
- per-stage **KV/state caches** are sliced from the engine's master
  cache when a decode block starts and concatenated back before the next
  admission scatters fresh lanes (``load_cache`` / ``export_cache``);
- each decode round pushes the live ``(B, 1, d_model)`` hidden state
  through :class:`runtime.pipeline_exec.StagePipelineExecutor` -- the
  first stage embeds the token batch, every stage folds its layer slice
  (updating its cache slice in place), the last stage unembeds to
  logits.  The executor's tile loop keeps the weight-streaming account
  and the virtual clock, which is cross-checked per round against the
  plan's pipeline recurrence (``clock_ok``).

The composition is bit-identical to the fused single-PU
``decode_step`` by construction: every family implements ``decode_step``
as exactly the one-stage composition of the same entry points.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.plan.partition import PartitionedPlan
from repro.runtime.pipeline_exec import PipelineReport, StagePipelineExecutor


class StagedDecodeRunner:
    """Drive decode rounds through the stage-parallel pipeline executor.

    ``on_trace(kind)`` (optional) is called whenever one of the runner's
    jitted cells traces, so the owning engine's retrace accounting covers
    the staged path too.
    """

    def __init__(
        self,
        cfg,
        api,
        params,
        plan: PartitionedPlan,
        *,
        stage_meshes: Optional[Sequence[Any]] = None,
        queue_depth: int = 2,
        on_trace=None,
    ):
        self.cfg = cfg
        self.api = api
        self.plan = plan
        self.ranges: List[Tuple[int, int]] = [
            s.decode_layers for s in plan.stages
        ]
        L = cfg.n_layers
        pts = set(api.decode_slice_points(cfg))
        cursor = 0
        for start, stop in self.ranges:
            if start != cursor or stop < start or stop > L:
                raise ValueError(
                    f"stage decode ranges {self.ranges} do not tile "
                    f"[0, {L}) contiguously"
                )
            if start not in pts or stop not in pts:
                raise ValueError(
                    f"stage range ({start}, {stop}) not on the family's "
                    f"slice points {sorted(pts)}"
                )
            cursor = stop
        if cursor != L:
            raise ValueError(
                f"stage decode ranges {self.ranges} do not cover all "
                f"{L} layers"
            )
        self._on_trace = on_trace or (lambda kind: None)

        def _embed(p, tokens, pos):
            self._on_trace("decode")
            return api.decode_embed(cfg, p, tokens, pos)

        def _stage(sp, h, sc, pos):
            self._on_trace("decode")
            return api.decode_stage(cfg, sp, h, sc, pos)

        def _unembed(p, h):
            self._on_trace("decode")
            return api.decode_unembed(cfg, p, h)

        self._embed_fn = jax.jit(_embed)
        self._stage_fn = jax.jit(_stage)
        self._unembed_fn = jax.jit(_unembed)

        self.bound_params = None
        self.stage_params: List[Any] = []
        self.rebind(params)
        self.stage_caches: Optional[List[Any]] = None
        self.rounds_executed = 0
        self.clock_ok = True
        self.last_report: Optional[PipelineReport] = None
        self._executor = StagePipelineExecutor(
            plan,
            run_stage=self._run_stage,
            stage_meshes=stage_meshes,
            queue_depth=queue_depth,
        )
        # the M=1 recurrence: one frame through all K stages
        self._expected_done_t = float(plan.pipeline_events(1)[-1, 0])

    # -- param/cache residency ---------------------------------------------

    def rebind(self, params) -> None:
        """(Re-)slice per-stage params from ``params`` (cheap device
        slices; called once at construction and on NIU refreshes)."""
        self.bound_params = params
        self.stage_params = [
            self.api.slice_params(self.cfg, params, r) for r in self.ranges
        ]

    def load_cache(self, cache) -> None:
        """Slice the engine's master cache into per-stage cache slices."""
        self.stage_caches = [
            self.api.slice_cache(self.cfg, cache, r) for r in self.ranges
        ]

    def export_cache(self):
        """Concatenate the per-stage cache slices back into the master
        layout (each family's cache leaves are layer-leading, so stage
        slices concatenate on axis 0 in stage order)."""
        if self.stage_caches is None:
            raise ValueError("no stage caches loaded")
        return jax.tree.map(
            lambda *leaves: jnp.concatenate(leaves, axis=0),
            *self.stage_caches,
        )

    # -- the decode round ---------------------------------------------------

    def _run_stage(self, k: int, payload):
        # the frame payload IS the inter-stage handoff: (tokens, pos)
        # entering stage 0, (hidden, pos) between stages, (logits, pos)
        # draining -- pos rides along because every stage's KV scatter
        # needs the per-lane positions
        x, pos = payload
        if k == 0:
            x = self._embed_fn(self.bound_params, x, pos)
        x, self.stage_caches[k] = self._stage_fn(
            self.stage_params[k], x, self.stage_caches[k], pos
        )
        if k == len(self.ranges) - 1:
            x = self._unembed_fn(self.bound_params, x)
        return (x, pos)

    def decode_round(self, tokens, pos):
        """One staged decode round -> logits (B, V).

        The token batch enters stage 0 (which embeds it), the hidden
        state flows through every stage's layer slice via the executor's
        handoff queues, and the last stage's unembed output drains as the
        frame payload.  Stage caches update in place."""
        if self.stage_caches is None:
            raise ValueError("load_cache() before decode_round()")
        report = self._executor.run([(tokens, jnp.asarray(pos, jnp.int32))])
        self.rounds_executed += 1
        self.last_report = report
        # virtual-clock cross-check: the executed event stream must
        # reproduce the plan's single-frame recurrence
        tol = 1e-9 * max(1.0, abs(self._expected_done_t))
        if abs(report.frame_done_t[0] - self._expected_done_t) > tol:
            self.clock_ok = False
        logits, _ = report.outputs[0]
        return logits
