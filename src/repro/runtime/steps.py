"""Step factories shared by the dry-run, the train loop and the servers.

Each factory returns (step_fn, abstract_inputs, in_shardings, out_shardings)
for a (config, shape, mesh, rules) cell, so the launchers and the dry-run
lower exactly the same computation.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api as model_api
from repro.optim import AdamWConfig, adamw_init, adamw_update, opt_state_axes
from repro.parallel.sharding import (
    activation_sharding_ctx,
    resolve_spec,
    specs_for_tree,
)


def abstract_params(cfg: ModelConfig, api) -> Any:
    return jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))


def _named(mesh, spec):
    return NamedSharding(mesh, spec)


def make_train_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    rules,
    opt_cfg: Optional[AdamWConfig] = None,
    accum_steps: int = 1,
):
    """train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``opt_cfg.master_weights`` the live params are bf16 (halving the
    ZeRO-3 parameter all-gather bytes) and the f32 master copy lives in the
    sharded optimizer state.

    ``accum_steps > 1`` splits the global batch into microbatches scanned
    inside the step (gradient accumulation in f32): live activation memory
    scales ~1/accum_steps while the optimizer sees the same global batch --
    the memory-feasibility lever for train cells whose activations exceed
    per-chip HBM (EXPERIMENTS.md SSPerf memory pass).
    """
    from repro.optim import cast_params_bf16

    api = model_api.get_api(cfg)
    opt_cfg = opt_cfg or AdamWConfig()
    mw = opt_cfg.master_weights
    assert shape.global_batch % accum_steps == 0, (shape.global_batch, accum_steps)

    def _loss(p, b):
        with activation_sharding_ctx(mesh, rules):
            return jax.value_and_grad(
                lambda q: api.train_loss(cfg, q, b)
            )(p)

    if accum_steps == 1:

        def train_step(params, opt_state, batch):
            loss, grads = _loss(params, batch)
            new_params, new_opt, metrics = adamw_update(
                opt_cfg, grads, opt_state, params
            )
            metrics = dict(metrics, loss=loss)
            return new_params, new_opt, metrics

    else:

        def train_step(params, opt_state, batch):
            mb = jax.tree.map(
                lambda x: x.reshape(
                    (accum_steps, x.shape[0] // accum_steps) + x.shape[1:]
                ),
                batch,
            )
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(carry, mbatch):
                loss_sum, gsum = carry
                loss, grads = _loss(params, mbatch)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads
                )
                return (loss_sum + loss, gsum), None

            (loss_sum, gsum), _ = jax.lax.scan(body, (0.0, g0), mb)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            new_params, new_opt, metrics = adamw_update(
                opt_cfg, grads, opt_state, params
            )
            metrics = dict(metrics, loss=loss_sum / accum_steps)
            return new_params, new_opt, metrics

    params_s = abstract_params(cfg, api)
    if mw:
        params_s = jax.eval_shape(cast_params_bf16, params_s)
    opt_s = jax.eval_shape(
        functools.partial(adamw_init, master_weights=mw), params_s
    )
    batch_s = model_api.batch_struct(cfg, shape)

    p_axes = api.param_axes(cfg)
    p_shard = specs_for_tree(p_axes, mesh, rules, params_s)
    o_shard = specs_for_tree(
        opt_state_axes(p_axes, master_weights=mw), mesh, rules, opt_s
    )
    b_shard = specs_for_tree(model_api.batch_axes(cfg, shape), mesh, rules, batch_s)
    scalar = _named(mesh, P())
    m_shard = {"lr": scalar, "grad_norm": scalar, "step": scalar, "loss": scalar}

    return (
        train_step,
        (params_s, opt_s, batch_s),
        (p_shard, o_shard, b_shard),
        (p_shard, o_shard, m_shard),
    )


def make_compressed_train_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    rules,
    opt_cfg: Optional[AdamWConfig] = None,
):
    """train_step with int8 error-feedback gradient compression.

    State gains an ``ef`` tree (error feedback, shards like params); the
    gradient all-reduce inside the jit carries int8 payloads -- 4x fewer
    wire bytes than f32 master grads (see parallel/compression.py).
    """
    from repro.parallel.compression import compressed_grads, init_error_state

    api = model_api.get_api(cfg)
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, ef, batch):
        with activation_sharding_ctx(mesh, rules):
            loss, grads = jax.value_and_grad(
                lambda p: api.train_loss(cfg, p, batch)
            )(params)
        grads, ef = compressed_grads(grads, ef)
        new_params, new_opt, metrics = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, ef, metrics

    params_s = abstract_params(cfg, api)
    opt_s = jax.eval_shape(adamw_init, params_s)
    ef_s = jax.eval_shape(init_error_state, params_s)
    batch_s = model_api.batch_struct(cfg, shape)

    p_axes = api.param_axes(cfg)
    p_shard = specs_for_tree(p_axes, mesh, rules, params_s)
    o_shard = specs_for_tree(opt_state_axes(p_axes), mesh, rules, opt_s)
    e_shard = specs_for_tree(p_axes, mesh, rules, ef_s)
    b_shard = specs_for_tree(model_api.batch_axes(cfg, shape), mesh, rules, batch_s)
    scalar = _named(mesh, P())
    m_shard = {"lr": scalar, "grad_norm": scalar, "step": scalar, "loss": scalar}

    return (
        train_step,
        (params_s, opt_s, ef_s, batch_s),
        (p_shard, o_shard, e_shard, b_shard),
        (p_shard, o_shard, e_shard, m_shard),
    )


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules):
    """prefill(params, batch) -> (logits, cache)."""
    api = model_api.get_api(cfg)

    def prefill(params, batch):
        with activation_sharding_ctx(mesh, rules):
            return api.prefill(cfg, params, batch)

    params_s = abstract_params(cfg, api)
    batch_s = model_api.batch_struct(cfg, shape)
    p_shard = specs_for_tree(api.param_axes(cfg), mesh, rules, params_s)
    b_shard = specs_for_tree(model_api.batch_axes(cfg, shape), mesh, rules, batch_s)

    cache_s = jax.eval_shape(
        lambda p, b: api.prefill(cfg, p, b)[1], params_s, batch_s
    )
    c_shard = specs_for_tree(api.cache_axes(cfg), mesh, rules, cache_s)
    logits_shard = _named(
        mesh,
        resolve_spec(
            ("batch", "vocab"), mesh, rules,
            dims=(shape.global_batch, cfg.vocab),
        ),
    )

    return (
        prefill,
        (params_s, batch_s),
        (p_shard, b_shard),
        (logits_shard, c_shard),
    )


def make_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules):
    """decode(params, cache, tokens, pos) -> (logits, cache).

    ``pos`` is the per-slot position vector (B,), sharded like the token
    batch -- each lane decodes (and writes its KV) at its own position.
    """
    api = model_api.get_api(cfg)

    def decode(params, cache, tokens, pos):
        with activation_sharding_ctx(mesh, rules):
            return api.decode_step(cfg, params, cache, tokens, pos)

    params_s = abstract_params(cfg, api)
    cache_s, tokens_s, pos_s = model_api.decode_inputs_struct(cfg, shape)
    p_shard = specs_for_tree(api.param_axes(cfg), mesh, rules, params_s)
    c_shard = specs_for_tree(api.cache_axes(cfg), mesh, rules, cache_s)
    t_shard = _named(
        mesh,
        resolve_spec(("batch", None), mesh, rules, dims=(shape.global_batch, 1)),
    )
    pos_shard = _named(
        mesh,
        resolve_spec(("batch",), mesh, rules, dims=(shape.global_batch,)),
    )
    logits_shard = _named(
        mesh,
        resolve_spec(
            ("batch", "vocab"), mesh, rules,
            dims=(shape.global_batch, cfg.vocab),
        ),
    )

    return (
        decode,
        (params_s, cache_s, tokens_s, pos_s),
        (p_shard, c_shard, t_shard, pos_shard),
        (logits_shard, c_shard),
    )


def make_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    rules,
    opt_cfg: Optional[AdamWConfig] = None,
    accum_steps: int = 1,
):
    """Dispatch on the shape kind (train / prefill / decode)."""
    if shape.kind == "train":
        return make_train_step(cfg, shape, mesh, rules, opt_cfg, accum_steps)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, mesh, rules)
    if shape.kind == "decode":
        return make_decode_step(cfg, shape, mesh, rules)
    raise ValueError(shape.kind)
