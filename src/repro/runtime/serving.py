"""Batched serving engine with the paper's adaptive weight streaming.

The paper's scenario: model weights live off-chip (HBM@FPGA); a scheduler
streams weight tiles into fast memory (URAM) while inference runs, hiding
load latency behind compute (SS III).  The TPU serving analogue implemented
here has two streaming levels:

- **HBM -> VMEM** (per-layer weight residency inside a step) is Pallas's
  block pipeline -- the int8 GEMM kernel already double-buffers tiles.
- **host -> HBM** (whole-model residency across steps) is where the paper's
  scheduler runs at serving scale: when a model's weights exceed device
  HBM, layer-group tiles are prefetched from host memory under the
  two-phase schedule; `ServingEngine` plans this with the same
  `core.scheduler` used for the FPGA reproduction (PUConfig =
  `host_offload_config()`).

The engine also carries the paper's SS VI AIMC emulation: an optional
NoiseInjectionUnit refreshes weights with fresh device-noise instances
every inference round, exactly the NIU read-modify-write loop.

Request flow (continuous batching, decode-centric):

    submit(prompt tokens) -> queue
    engine round: admit waiting requests into free slots (bucketed batched
                  prefill, one call per length bucket), then run a fused
                  block of decode rounds entirely on device.

The decode hot path is **device-resident** (DESIGN.md SS7): sampling,
append, per-slot position/remaining bookkeeping and termination flags all
live inside one jitted ``lax.scan`` block; between host syncs the engine
only moves a handful of scalars per slot.  ``ServeConfig.host_sampling``
keeps the legacy host-loop round (one decode jit per token, numpy
sampling) as an escape hatch and as the reference for the greedy
bit-identity property tests.

With ``stream_pus`` (K >= 2) the engine runs **true per-stage decode**
(DESIGN.md SS8): each round's hidden state flows through the stage
pipeline, every stage executing its model-layer slice against its own
KV-cache slice (``runtime.stage_decode``), with greedy streams
bit-identical to the fused single-PU loop.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis import sanitize
from repro.configs.base import ModelConfig
from repro.core.aimc import AIMCNoiseModel, NoiseInjectionUnit
from repro.core.pu import PUConfig, host_offload_config
from repro.core.streaming import StreamingPlan, WeightTile, plan_streaming
from repro.models import api as model_api
from repro.plan import (
    PartitionedPlan,
    SearchConfig,
    partition_gemms,
    snap_boundaries_nonempty,
)


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8             # decode slots
    max_len: int = 512             # KV capacity per slot
    max_new_tokens: int = 32
    eos_token: int = -1            # -1: never stop on a token
    temperature: float = 0.0       # 0 => greedy
    seed: int = 0
    # --- device-resident round knobs ---------------------------------------
    # escape hatch: legacy host-loop round (per-token decode jit, numpy
    # sampling, lane-isolated eager prefill) -- the pre-device-resident
    # engine, kept for A/B benchmarking and bit-identity tests
    host_sampling: bool = False
    # prompt length buckets for batched prefill; None -> power-of-two
    # ladder 16, 32, ... capped at max_len.  Prompts are right-padded to
    # the smallest bucket >= their length so warm traffic reuses a
    # handful of compiled traces.
    prefill_buckets: Optional[Sequence[int]] = None
    # max fused decode rounds per host sync (block sizes are the powers
    # of two <= this, so traces stay bounded); 1 degenerates to one
    # round per sync
    max_decode_block: int = 32
    pad_token: int = 0             # token fed to inactive/padded lanes
    # weight streaming (host->HBM level); None disables planning
    stream_pu: Optional[PUConfig] = None
    # multi-PU partitioned streaming: the model's layer sequence is split
    # across these profiles (contiguous ranges balanced on exec time, one
    # two-phase schedule per PU -- repro.plan.partition); overrides the
    # single-PU plan when set
    stream_pus: Optional[List[PUConfig]] = None
    # schedule-search strategy for the streaming/partition planners
    # (None/heuristic = the paper's one-shot heuristic; beam/anneal run
    # the richer search funded by the event-indexed engine)
    plan_search: Optional[SearchConfig] = None
    # multi-PU decode rounds run each stage's *model layer slice* through
    # the stage pipeline (true per-stage decode: real activations in the
    # handoff queues, per-stage KV cache slices); False falls back to the
    # fused single-PU decode loop with the partition kept analytic-only
    stage_decode: bool = True
    # lane-group microbatches for the *overlapped* staged decode
    # schedule: each decode round is split into M groups along the slot
    # batch so stages and rounds overlap (runtime.stage_decode).
    # 0 auto-tunes M (and the handoff queue depth) against the executed
    # bubble at engine construction (runtime.autotune.tune_staged_decode);
    # 1 pins the serial reference schedule (the A/B bit-identity path);
    # >1 pins M, clamped to the largest divisor of max_batch <= the
    # request (lane groups must tile the slot batch)
    decode_microbatches: int = 0
    # handoff queue depth for the staged-decode pipeline when M is
    # pinned (auto-tune picks its own depth)
    stage_queue_depth: int = 2
    # target fill/drain bubble fraction for the auto-tuned microbatch
    # depth when execute_partition() is called without an explicit M
    target_bubble: float = 0.10
    # AIMC emulation
    aimc: Optional[AIMCNoiseModel] = None
    aimc_refresh_every: int = 1    # refresh noise every N engine rounds
    # Fused Pallas decode kernels (kernels/decode.py) on the per-token hot
    # path.  Threaded into ModelConfig.decode_kernels at engine
    # construction so the fused _decode_block scan, the per-stage loops,
    # and the coalesced staged path all pick them up through the model
    # forward; the stock-XLA path (False) stays the A/B reference.
    decode_kernels: bool = False


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def tpot_s(self) -> Optional[float]:
        """Time per output token after the first (decode steady state)."""
        if self.first_token_at is None or self.done_at is None:
            return None
        if len(self.out_tokens) < 2:
            return None
        return (self.done_at - self.first_token_at) / (
            len(self.out_tokens) - 1
        )


def _pow2_ceil(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def default_prefill_buckets(max_len: int) -> Tuple[int, ...]:
    """Power-of-two ladder 16, 32, ... capped at ``max_len``."""
    out, b = [], 16
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(sorted(set(out)))


class ServingEngine:
    """Continuous-batching LM server over the uniform model API."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        serve_cfg: ServeConfig,
        mesh=None,
        rules=None,
    ):
        if serve_cfg.decode_kernels and not cfg.decode_kernels:
            cfg = dataclasses.replace(cfg, decode_kernels=True)
        self.cfg = cfg
        self.api = model_api.get_api(cfg)
        self.serve_cfg = serve_cfg
        self.mesh = mesh
        self.rules = rules
        self._pristine = params
        self.params = params
        self._rng = np.random.default_rng(serve_cfg.seed)
        self._key = jax.random.PRNGKey(serve_cfg.seed)

        # request/slot state (host bookkeeping)
        self._queue: deque[Request] = deque()
        self._uid = 0
        self._slots: List[Optional[Request]] = [None] * serve_cfg.max_batch
        self._slot_pos = np.zeros(serve_cfg.max_batch, np.int32)
        self._slot_remaining = np.zeros(serve_cfg.max_batch, np.int32)
        self._slot_emitted = np.zeros(serve_cfg.max_batch, np.int32)
        self.completed: List[Request] = []
        self.rounds = 0

        # batched KV cache for all slots
        self._cache = self.api.init_cache(
            cfg, serve_cfg.max_batch, serve_cfg.max_len
        )

        # trace bookkeeping (repro.analysis.sanitize.TraceCounter): each
        # counter increments only while jit is *tracing* the wrapped
        # function, so steady-state traffic that reuses compiled buckets
        # leaves them flat.  trace_counts aliases the live counter dict
        # for stats() and the benchmarks.
        self.tracing = sanitize.TraceCounter(("decode", "prefill"))
        self.trace_counts: Dict[str, int] = self.tracing.counts
        # wall-clock per admitted prefill call, keyed by bucket length
        self.prefill_bucket_s: Dict[int, List[float]] = {}

        # ring caches re-layout the whole sequence at prefill time, which
        # does not compose with per-lane padded lengths; recurrent
        # families must see exact-length prompts (api flag)
        ring = bool(cfg.kv_ring and cfg.window and not cfg.global_every)
        self.bucketed_prefill = self.api.supports_bucketed_prefill and not ring
        ladder = [
            b for b in (
                serve_cfg.prefill_buckets
                or default_prefill_buckets(serve_cfg.max_len)
            )
            if b <= serve_cfg.max_len
        ]
        # max_len always terminates the ladder so every admissible prompt
        # (truncated to < max_len) has a bucket
        self._buckets = tuple(sorted(set(ladder + [serve_cfg.max_len])))

        # legacy host-loop decode step (also the host_sampling path)
        def _decode_step(p, c, t, pos):
            return self.api.decode_step(cfg, p, c, t, pos)

        self._decode = self.tracing.jit(_decode_step, kind="decode")

        # device-resident decode state: everything the steady-state loop
        # needs lives here between host syncs
        B = serve_cfg.max_batch
        self._state: Dict[str, jax.Array] = {
            "tokens": jnp.zeros((B, 1), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32),
            "remaining": jnp.zeros((B,), jnp.int32),
            "active": jnp.zeros((B,), jnp.bool_),
            "out_buf": jnp.zeros((B, serve_cfg.max_len), jnp.int32),
            "out_len": jnp.zeros((B,), jnp.int32),
            "key": jax.random.PRNGKey(serve_cfg.seed),
        }

        # cache and decode state are donated: the KV cache never crosses
        # the jit boundary by copy, it lives in the same device buffers
        # round after round (the "device-resident" in the name)
        self._decode_block = self.tracing.jit(
            self._decode_block_impl, kind="decode",
            static_argnums=3, donate_argnums=(1, 2),
        )

        self._admit_block = self.tracing.jit(
            self._admit_impl, kind="prefill", donate_argnums=(1, 2)
        )

        # per-round state transition for the staged (multi-PU) decode
        # path: exactly the fused block's post-decode update, jitted
        # standalone so the pipeline's logits feed the same bookkeeping
        self._staged_update = self.tracing.jit(
            self._postdecode_update, kind="decode"
        )

        # --- paper machinery ------------------------------------------------
        self.streaming_plan: Optional[StreamingPlan] = None
        self.partitioned_plan: Optional[PartitionedPlan] = None
        self.stage_meshes = None
        self.stage_meshes_shared = False
        self.last_pipeline_report = None
        self.last_autotune = None
        if serve_cfg.stream_pus and len(serve_cfg.stream_pus) == 1:
            # K=1 degenerates to the single-PU path: one "partition
            # stage" would only re-wrap the plain streaming plan.
            self.streaming_plan = plan_model_streaming(
                cfg, serve_cfg.stream_pus[0],
                batch_tokens=serve_cfg.max_batch,
                search=serve_cfg.plan_search,
            )
        elif serve_cfg.stream_pus:
            self.partitioned_plan = plan_partitioned_streaming(
                cfg, serve_cfg.stream_pus,
                batch_tokens=serve_cfg.max_batch,
                search=serve_cfg.plan_search,
            )
            if mesh is not None:
                from repro.launch.mesh import stage_submeshes

                self.stage_meshes, self.stage_meshes_shared = stage_submeshes(
                    mesh, len(self.partitioned_plan.stages)
                )
        # true per-stage decode: multi-PU device-path rounds run each
        # stage's model-layer slice through the stage pipeline, with
        # per-stage KV cache slices and real activation handoffs
        self._staged = None
        self._staged_live = False
        # M > 1 keeps the decode state split into per-lane-group dicts
        # between barriers (slicing/merging the full state every block
        # costs more than the decode itself on small models); _state is
        # stale while _staged_groups is set, except "key"
        self._staged_groups: Optional[List[Dict[str, jax.Array]]] = None
        self._staged_merged_key: Optional[jax.Array] = None
        # jitted lane-group state split/merge (built on first use)
        self._staged_split = None
        self._staged_merge = None
        self.staged_tune = None
        if (
            self.partitioned_plan is not None
            and serve_cfg.stage_decode
            and not serve_cfg.host_sampling
        ):
            from repro.runtime.stage_decode import StagedDecodeRunner

            # stages on one physical device (the single-host sim, or
            # shared submeshes) cannot overlap real compute -- one
            # execution stream serializes every stage.  Keep the
            # overlapped schedule but execute each block as a single
            # scan (StagedDecodeRunner.coalesce); distinct per-stage
            # device sets run the threaded executor for real overlap
            same_device = self.stage_meshes is None or self.stage_meshes_shared
            self._staged = StagedDecodeRunner(
                cfg, self.api, params, self.partitioned_plan,
                stage_meshes=None if same_device else self.stage_meshes,
                on_trace=self.tracing.bump,
                # fused into the last stage's cell: overlapped frames
                # carry their own sample-append transition, so the
                # coordinator thread does pure queue work
                postdecode=self._postdecode_update,
                coalesce=same_device,
            )
            # close the M loop: lane-group count and handoff queue depth
            # for the overlapped schedule come from the *executed* bubble
            # of a functional probe block (0 = auto), or are pinned by
            # the config (1 = the serial bit-identity reference)
            m_req = serve_cfg.decode_microbatches
            if m_req == 0:
                from repro.runtime.autotune import (
                    AutotuneConfig,
                    tune_staged_decode,
                )

                self.staged_tune = tune_staged_decode(
                    self.partitioned_plan, serve_cfg.max_batch,
                    AutotuneConfig(target_bubble=serve_cfg.target_bubble),
                )
                self._staged.configure(
                    n_groups=self.staged_tune.n_groups,
                    queue_depth=self.staged_tune.queue_depth,
                )
            else:
                m = max(
                    d for d in range(1, serve_cfg.max_batch + 1)
                    if serve_cfg.max_batch % d == 0 and d <= m_req
                )
                self._staged.configure(
                    n_groups=m, queue_depth=serve_cfg.stage_queue_depth
                )
        if serve_cfg.stream_pu is not None and not serve_cfg.stream_pus:
            self.streaming_plan = plan_model_streaming(
                cfg, serve_cfg.stream_pu,
                batch_tokens=serve_cfg.max_batch,
                search=serve_cfg.plan_search,
            )
        self.niu: Optional[NoiseInjectionUnit] = None
        if serve_cfg.aimc is not None and serve_cfg.aimc.enabled():
            self.niu = NoiseInjectionUnit(params, serve_cfg.aimc)

    # -- client API --------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None) -> int:
        # a request can never generate past the cache: clamp the budget to
        # max_len - 2 so at least two prompt tokens survive truncation
        # (keep = max_len - budget, see _truncated_prompt) and the
        # pos >= max_len - 1 stop can never cut a clamped budget short
        budget = max_new_tokens or self.serve_cfg.max_new_tokens
        req = Request(
            uid=self._uid,
            prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max(1, min(budget, self.serve_cfg.max_len - 2)),
            submitted_at=time.perf_counter(),
        )
        self._uid += 1
        self._queue.append(req)
        return req.uid

    @property
    def active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def pending(self) -> int:
        return len(self._queue)

    def run_until_drained(self, max_rounds: int = 10_000) -> List[Request]:
        while (self.pending or self.active) and self.rounds < max_rounds:
            self.step()
        return self.completed

    def warmup(self):
        """Pre-compile the bounded trace grid so live traffic never
        retraces: every (prompt bucket x pow2 admit width) prefill shape
        and every pow2 decode-block length.  Warmup admission rows
        scatter out of bounds and no slot is active, so the served state
        is untouched -- except the sampling PRNG stream, which each
        warmup call advances exactly like a live call when
        ``temperature > 0`` (the engine stays deterministic for a fixed
        warmup + traffic sequence)."""
        sc = self.serve_cfg
        if sc.host_sampling:
            tokens = jnp.zeros((sc.max_batch, 1), jnp.int32)
            self._decode(
                self.params, self._cache, tokens,
                jnp.zeros((sc.max_batch,), jnp.int32),
            )
            return
        if self.bucketed_prefill:
            nbs, nb = [], 1
            while nb < _pow2_ceil(sc.max_batch):
                nbs.append(nb)
                nb *= 2
            nbs.append(_pow2_ceil(sc.max_batch))
            for S in self._buckets:
                for nb in nbs:
                    # cache/state are donated into the call: reassign
                    self._cache, self._state, _, _ = self._admit_block(
                        self.params, self._cache, self._state,
                        jnp.full((nb, S), sc.pad_token, jnp.int32),
                        jnp.ones((nb,), jnp.int32),
                        jnp.full((nb,), sc.max_batch, jnp.int32),  # dropped
                        jnp.ones((nb,), jnp.int32),
                    )
        if self._staged is not None:
            # warm the per-stage cells and the state update at the live
            # schedule's lane-group width on throwaway cache slices,
            # then drop them.  The state is *kept* -- no lane is active,
            # so the transition is the identity except for the PRNG key,
            # which advances exactly like a live round (the warmup
            # contract above).  The first block is forced through the
            # threaded executor: its per-frame virtual clock is
            # cross-checked against the overlapped recurrence
            # (clock_ok), which the coalesced fast path then inherits
            self._staged_decode_block(2, force_threaded=True)
            if self._staged.coalesce and self._staged.n_groups > 1:
                # the coalesced path compiles one scan per pow2 block
                # length, like the fused single-PU ladder
                R = 1
                while R <= sc.max_decode_block:
                    self._staged_decode_block(R)
                    R *= 2
            self._staged_sync_state()
            # compile the barrier transform (slices -> master cache)
            # too: the first admission would otherwise pay it live
            self._staged.export_cache()
            self._staged.flush()
            self._staged.stage_caches = None
            self._staged_live = False
            self._staged.rounds_executed = 0
            self._staged.virtual_busy_s = 0.0
            self._staged.virtual_span_s = 0.0
            self._staged.last_report = None
            return
        R = 1
        while R <= sc.max_decode_block:
            self._cache, self._state = self._decode_block(
                self.params, self._cache, self._state, R
            )
            R *= 2

    # -- engine round -------------------------------------------------------
    def step(self):
        """One engine round (host path) or one fused block (device path)."""
        sc = self.serve_cfg
        if self.niu is not None and self.rounds % sc.aimc_refresh_every == 0:
            self._key, sub = jax.random.split(self._key)
            self.params = self.niu.refresh(sub)
        if sc.host_sampling:
            self._step_host()
        else:
            self._step_device()

    # ======================================================================
    # device-resident path
    # ======================================================================

    def _sample_device(self, key, logits):
        """On-device sampling shared by admission and decode: greedy
        argmax, or temperature categorical consuming the threaded key."""
        sc = self.serve_cfg
        if sc.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits.astype(jnp.float32) / sc.temperature, axis=-1
            ).astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return key, tok

    def _apply_eos(self, done, tok):
        """Fold eos termination into ``done``.  The single definition of
        "eos is enabled" for every path (host scalars and device
        vectors): any non-negative ``eos_token`` -- including 0 -- is a
        real stop token; only negative values disable the check."""
        if self.serve_cfg.eos_token >= 0:
            return done | (tok == self.serve_cfg.eos_token)
        return done

    def _postdecode_update(self, state, logits):
        """Sample-append bookkeeping after one decode round's logits:
        the single state transition shared by the fused device block and
        the staged per-round loop, so both paths terminate, append, and
        thread the PRNG identically.  Width-polymorphic: the lane count
        comes from the state, so the same transition serves the full
        slot batch and a 1/M lane-group slice (every operation is
        per-lane, which is why lane-group splitting preserves greedy
        bit-identity)."""
        sc = self.serve_cfg
        lane = jnp.arange(state["active"].shape[0])
        key, tok = self._sample_device(state["key"], logits)
        act = state["active"]
        acti = act.astype(jnp.int32)
        tok = jnp.where(act, tok, sc.pad_token)
        # inactive lanes write at an out-of-bounds column -> dropped
        col = jnp.where(act, state["out_len"], sc.max_len)
        out_buf = state["out_buf"].at[lane, col].set(tok, mode="drop")
        out_len = state["out_len"] + acti
        pos = state["pos"] + acti
        rem = state["remaining"] - acti
        done = (rem <= 0) | (pos >= sc.max_len - 1)
        done = self._apply_eos(done, tok)
        return {
            "tokens": tok[:, None],
            "pos": pos,
            "remaining": rem,
            "active": act & ~done,
            "out_buf": out_buf,
            "out_len": out_len,
            "key": key,
        }

    def _prefill_batch(self, tokens, lengths=None):
        """Model-API prefill batch for ``tokens``, with the stub modality
        inputs each family expects (shared by both admission paths)."""
        dt = jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32
        batch: Dict[str, jax.Array] = {"tokens": tokens}
        if lengths is not None:
            batch["lengths"] = lengths
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (tokens.shape[0], self.cfg.vision_patches, self.cfg.d_model),
                dt,
            )
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (tokens.shape[0], self.cfg.encoder_frames, self.cfg.d_model),
                dt,
            )
        return batch

    def _decode_block_impl(self, params, cache, state, n_rounds: int):
        """``n_rounds`` fused decode rounds: sample-append, per-slot
        position/remaining bookkeeping and done flags all stay on device;
        generated tokens land in the device-side ``out_buf`` ring so the
        host only reads them at request completion."""

        def one(carry, _):
            cache, st = carry
            logits, cache = self.api.decode_step(
                self.cfg, params, cache, st["tokens"], st["pos"]
            )
            return (cache, self._postdecode_update(st, logits)), None

        (cache, state), _ = jax.lax.scan(
            one, (cache, state), None, length=n_rounds
        )
        return cache, state

    def _staged_decode_block(self, n_rounds: int, force_threaded: bool = False):
        """``n_rounds`` true per-stage decode rounds: hidden states flow
        through the stage pipeline (every stage running its model-layer
        slice against its own KV cache slice on its submesh), then the
        shared ``_postdecode_update`` transition applies -- so greedy
        streams are bit-identical to the fused single-PU block.

        With ``n_groups == 1`` each round is one full-batch frame (the
        serial A/B reference).  With M > 1 the decode state is split
        into M lane-group slices and the rounds run *overlapped*
        (``StagedDecodeRunner.decode_block``): stage s computes group g
        while stage s-1 computes g+1, and round r+1 of a group enters
        the pipeline the moment round r of that group drains.  Every
        state operation is per-lane (sampling is per-lane argmax under
        greedy), so the merged stream is unchanged; the PRNG key is the
        only cross-lane state and is chained per group, which greedy
        never consumes -- temperature sampling stays deterministic but
        draws a different (per-group) stream than the fused loop."""
        runner = self._staged
        if runner.bound_params is not self.params:
            runner.rebind(self.params)       # e.g. after an NIU refresh
        if not self._staged_live:
            runner.load_cache(self._cache)
            self._staged_live = True
        M = runner.n_groups
        if M == 1:
            for _ in range(n_rounds):
                logits = runner.decode_round(
                    self._state["tokens"], self._state["pos"]
                )
                self._state = self._staged_update(self._state, logits)
            return
        if self._staged_groups is None:
            if self._staged_split is None:
                sc = self.serve_cfg
                temp = sc.temperature > 0
                gsz = sc.max_batch // M

                # jitted (like the merge below): eager slices would
                # re-specialize against the donated block outputs'
                # layouts at every barrier, costing fresh compiles
                def _split(state):
                    if temp:
                        keys = jax.random.split(state["key"], M + 1)
                        new_key, gkeys = keys[0], list(keys[1:])
                    else:
                        # greedy never consumes the key, but the staged
                        # cells donate their group's state -- each group
                        # needs its own buffer, not M references to the
                        # master key
                        new_key = state["key"]
                        gkeys = [state["key"] + 0 for _ in range(M)]
                    groups = []
                    for i in range(M):
                        gs = {
                            k: v[i * gsz:(i + 1) * gsz]
                            for k, v in state.items() if k != "key"
                        }
                        gs["key"] = gkeys[i]
                        groups.append(gs)
                    return new_key, groups

                self._staged_split = jax.jit(_split)
            new_key, groups = self._staged_split(self._state)
            self._staged_groups = groups
            self._staged_merged_key = new_key
        runner.decode_block(
            self._staged_groups, n_rounds, force_threaded=force_threaded
        )

    def _staged_sync_state(self):
        """Merge the per-lane-group decode states back into the master
        ``_state`` (lane groups rejoin on axis 0 in group order) -- the
        state half of the round-boundary barrier.  The merged PRNG key
        is the head of the split that seeded the groups, so a fixed
        warmup + traffic sequence stays deterministic."""
        groups = self._staged_groups
        if groups is None:
            return
        if self._staged_merge is None:

            def _merge(groups):
                return {
                    k: jnp.concatenate([gr[k] for gr in groups], axis=0)
                    for k in groups[0] if k != "key"
                }

            self._staged_merge = jax.jit(_merge)
        merged = self._staged_merge(groups)
        merged["key"] = self._staged_merged_key
        self._state = merged
        self._staged_groups = None
        self._staged_merged_key = None

    def _admit_impl(self, params, cache, state, tokens, lengths, slots, max_new):
        """Batched prefill of one length bucket + on-device admission:
        sample each prompt's first token, scatter the prefilled KV lanes
        and the per-slot decode state in one jitted update.  Dummy rows
        (bucket padding) carry ``slots == max_batch`` and are dropped by
        the out-of-bounds scatter mode."""
        batch = self._prefill_batch(
            tokens, lengths if self.bucketed_prefill else None
        )
        logits, one_cache = self.api.prefill(self.cfg, params, batch)
        key, tok = self._sample_device(state["key"], logits)

        cache = scatter_cache_lanes(cache, one_cache, slots)
        # a request whose budget is one token (or whose first token is
        # eos) completes at admission: it never occupies a decode slot
        done0 = self._apply_eos(max_new <= 1, tok)
        state = {
            "tokens": state["tokens"].at[slots, 0].set(tok, mode="drop"),
            "pos": state["pos"].at[slots].set(lengths, mode="drop"),
            "remaining": state["remaining"].at[slots].set(
                max_new - 1, mode="drop"
            ),
            "active": state["active"].at[slots].set(~done0, mode="drop"),
            "out_buf": state["out_buf"].at[slots, 0].set(tok, mode="drop"),
            "out_len": state["out_len"].at[slots].set(1, mode="drop"),
            "key": key,
        }
        return cache, state, tok, done0

    def _bucket_for(self, n: int) -> int:
        for b in self._buckets:
            if b >= n:
                return b
        return self._buckets[-1]

    def _truncated_prompt(self, req: Request) -> np.ndarray:
        """Tail of the prompt that fits the KV budget alongside the
        request's generation budget.

        A prompt of length ``keep`` prefills slots [0, keep); decode
        round r writes KV at ``keep + r`` and the engine stops after
        ``max_new - 1`` rounds (the first token is sampled at admission)
        or when ``pos`` reaches ``max_len - 1`` -- so the last KV write
        lands at ``keep + max_new - 2`` and ``keep = max_len - max_new``
        is exactly the largest safe context.  (The previous ``- 1``
        reserved a slot no path ever wrote, silently dropping one prompt
        token at the boundary -- caught by the length ``max_len - 1``
        boundary test.)"""
        sc = self.serve_cfg
        keep = max(1, sc.max_len - req.max_new_tokens)
        return req.prompt[-keep:]

    def _admit_device(self):
        """Admit every waiting request a free slot can take.  Requests in
        the same round whose prompts fall in the same length bucket share
        a single prefill call."""
        sc = self.serve_cfg
        free = [i for i, s in enumerate(self._slots) if s is None]
        admits: List[Tuple[int, Request, np.ndarray]] = []
        while free and self._queue:
            req = self._queue.popleft()
            admits.append((free.pop(0), req, None))
        if not admits:
            return
        if self._staged is not None and self._staged_live:
            # the round-boundary barrier: admission mutates slot
            # membership, so fold the per-lane-group decode states and
            # the staged runner's per-stage cache slices back into the
            # master layout first (export_cache also flushes the
            # overlapped session; everything is re-sliced lazily at the
            # next staged block, which re-pays the fill bubble there)
            self._staged_sync_state()
            self._cache = self._staged.export_cache()
            self._staged_live = False
        groups: Dict[int, List[Tuple[int, Request, np.ndarray]]] = {}
        for slot, req, _ in admits:
            prompt = self._truncated_prompt(req)
            S = (
                self._bucket_for(len(prompt))
                if self.bucketed_prefill
                else len(prompt)
            )
            groups.setdefault(S, []).append((slot, req, prompt))

        for S, group in sorted(groups.items()):
            nb = len(group)
            # pad the admit batch to a power of two so the (bucket, nb)
            # trace set stays bounded; dummy rows scatter out of bounds
            nb_pad = _pow2_ceil(nb) if self.bucketed_prefill else nb
            tokens = np.full((nb_pad, S), sc.pad_token, np.int32)
            lengths = np.ones((nb_pad,), np.int32)
            slots = np.full((nb_pad,), sc.max_batch, np.int32)
            max_new = np.ones((nb_pad,), np.int32)
            for j, (slot, req, prompt) in enumerate(group):
                tokens[j, : len(prompt)] = prompt
                lengths[j] = len(prompt)
                slots[j] = slot
                max_new[j] = req.max_new_tokens
            t0 = time.perf_counter()
            self._cache, self._state, tok, done0 = self._admit_block(
                self.params, self._cache, self._state,
                jnp.asarray(tokens), jnp.asarray(lengths),
                jnp.asarray(slots), jnp.asarray(max_new),
            )
            # lint: disable=RPL002 -- designed admission-boundary sync: the admit block must land before slots update
            done0_np = np.asarray(done0)
            self.prefill_bucket_s.setdefault(S, []).append(
                time.perf_counter() - t0
            )
            now = time.perf_counter()
            # lint: disable=RPL002 -- designed admission-boundary sync: first tokens of already-done admits drain here
            tok_np = np.asarray(tok) if done0_np[:nb].any() else None
            for j, (slot, req, prompt) in enumerate(group):
                req.first_token_at = now
                if done0_np[j]:
                    # lint: disable=RPL002 -- host-side numpy scalar; the batch already drained above
                    req.out_tokens = [int(tok_np[j])]
                    req.done_at = now
                    self.completed.append(req)
                else:
                    self._slots[slot] = req
                    self._slot_emitted[slot] = 1
                    self._slot_pos[slot] = len(prompt)

    def _step_device(self):
        """One fused block: admit (bucketed batched prefill), then run
        the largest power-of-two decode block that no active request can
        out-finish, then sync the per-slot scalars."""
        sc = self.serve_cfg
        self._admit_device()
        if not any(s is not None for s in self._slots):
            self.rounds += 1
            return
        # lint: disable=RPL002 -- _slot_emitted is a host numpy array; no device pull
        remaining = [
            max(1, req.max_new_tokens - int(self._slot_emitted[i]))
            for i, req in enumerate(self._slots)
            if req is not None
        ]
        cap = sc.max_decode_block
        if self.niu is not None:
            # AIMC refresh happens between host rounds; keep per-round
            # granularity so every round sees a fresh noise instance
            cap = 1
        # queue-aware block sizing: with admissions waiting, sync when
        # the earliest slot frees; with an empty queue a finished lane
        # just goes inactive inside the block (the batched step computes
        # every lane regardless), so run until the *last* slot could
        # finish and save the host syncs
        r = min(remaining) if self._queue else max(remaining)
        r = max(1, min(r, cap))
        R = 1 << (r.bit_length() - 1)          # largest power of two <= r
        # the decode block itself must never pull device data to the
        # host: under REPRO_SANITIZE=1 an implicit device->host transfer
        # inside it raises instead of silently serializing the rounds
        with sanitize.transfer_guard():
            if self._staged is not None:
                self._staged_decode_block(R)
            else:
                self._cache, self._state = self._decode_block(
                    self.params, self._cache, self._state, R
                )
        self.rounds += R

        groups = self._staged_groups
        if groups is not None:
            # per-group scalar sync: the decode state stays split
            # between barriers, so read the per-lane flags group-wise
            # instead of merging the whole state every block
            gsize = sc.max_batch // len(groups)
            # lint: disable=RPL002 -- the designed block-boundary sync: per-slot flags after R fused rounds
            active = np.concatenate(
                [np.asarray(gr["active"]) for gr in groups]
            )
            # lint: disable=RPL002 -- designed block-boundary sync (see above)
            out_len = np.concatenate(
                [np.asarray(gr["out_len"]) for gr in groups]
            )
        else:
            # lint: disable=RPL002 -- the designed block-boundary sync: per-slot flags after R fused rounds
            active = np.asarray(self._state["active"])
            # lint: disable=RPL002 -- designed block-boundary sync (see above)
            out_len = np.asarray(self._state["out_len"])
        now = time.perf_counter()
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            # lint: disable=RPL002 -- out_len already synced to host numpy above
            self._slot_emitted[i] = int(out_len[i])
            if not active[i]:
                # lint: disable=RPL002 -- out_len already synced to host numpy above
                n = int(out_len[i])
                if groups is not None:
                    gi, row = divmod(i, gsize)
                    buf = groups[gi]["out_buf"][row, :n]
                else:
                    buf = self._state["out_buf"][i, :n]
                # lint: disable=RPL002 -- designed drain of a finished request's tokens at the block boundary
                req.out_tokens = [int(t) for t in np.asarray(buf)]
                req.done_at = now
                self.completed.append(req)
                self._slots[i] = None

    # ======================================================================
    # legacy host-loop path (ServeConfig.host_sampling escape hatch)
    # ======================================================================

    def _step_host(self):
        """One engine round: admit+prefill -> batched decode, with
        sampling and request bookkeeping on the host (the pre-device-
        resident engine, kept as the A/B reference)."""
        sc = self.serve_cfg
        # admit
        for i in range(sc.max_batch):
            if self._slots[i] is None and self._queue:
                req = self._queue.popleft()
                self._admit_host(i, req)

        if not self.active:
            self.rounds += 1
            return

        # batched decode for all active slots (inactive slots decode a pad
        # token into their own cache lane; results discarded)
        tokens = np.zeros((sc.max_batch, 1), np.int32)
        for i, req in enumerate(self._slots):
            if req is not None:
                last = (
                    req.out_tokens[-1]
                    if req.out_tokens
                    else int(req.prompt[-1])
                )
                tokens[i, 0] = last
        # per-slot position vector: each lane writes its KV at its own
        # position, so staggered admissions never clobber a neighbour's
        # cache (the old engine passed the max over slots -- a later
        # admit wrote its KV at an earlier slot's position)
        logits, self._cache = self._decode(
            self.params, self._cache, jnp.asarray(tokens),
            jnp.asarray(self._slot_pos),
        )
        logits = np.asarray(logits, np.float32)

        now = time.perf_counter()
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            tok = self._sample(logits[i])
            req.out_tokens.append(tok)
            if req.first_token_at is None:
                req.first_token_at = now
            self._slot_pos[i] += 1
            self._slot_remaining[i] -= 1
            if self._apply_eos(
                self._slot_remaining[i] <= 0
                or self._slot_pos[i] >= sc.max_len - 1,
                tok,
            ):
                req.done_at = now
                self.completed.append(req)
                self._slots[i] = None
        self.rounds += 1

    def _admit_host(self, slot: int, req: Request):
        """Prefill a request into one cache lane (lane-isolated)."""
        prompt = self._truncated_prompt(req)
        t0 = time.perf_counter()
        batch = self._prefill_batch(jnp.asarray(prompt[None, :], jnp.int32))
        logits, cache = self.api.prefill(self.cfg, self.params, batch)
        self._cache = scatter_cache(self._cache, cache, slot, len(prompt))
        tok = self._sample(np.asarray(logits, np.float32)[0])
        self.prefill_bucket_s.setdefault(len(prompt), []).append(
            time.perf_counter() - t0
        )
        req.out_tokens.append(tok)
        req.first_token_at = time.perf_counter()
        # a single-token budget (or an eos first token) completes at
        # admission instead of occupying a slot for a wasted decode round
        if self._apply_eos(req.max_new_tokens <= 1, tok):
            req.done_at = req.first_token_at
            self.completed.append(req)
            return
        self._slots[slot] = req
        self._slot_pos[slot] = len(prompt)
        self._slot_remaining[slot] = req.max_new_tokens - 1

    def _sample(self, logits: np.ndarray) -> int:
        if self.serve_cfg.temperature <= 0:
            return int(np.argmax(logits))
        p = logits / self.serve_cfg.temperature
        p = np.exp(p - p.max())
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    # -- executed partition (stage-parallel streaming runtime) ---------------
    def execute_partition(self, n_microbatches: Optional[int] = None):
        """Run the partitioned plan through the real stage-parallel
        executor (``runtime.pipeline_exec``): K stage threads, per-stage
        prefetch workers honoring issue order, double-buffered handoffs.

        ``n_microbatches=None`` (the default) auto-tunes the microbatch
        depth and handoff queue depth against
        ``ServeConfig.target_bubble`` using the *executed* bubble
        measurement (``runtime.autotune``); an explicit integer pins M
        (the legacy fixed-depth behaviour).

        Validates the partition as a *runnable* artifact -- measured
        pipeline throughput and fill bubble land in :meth:`stats`
        alongside the analytic numbers so regressions between the cost
        model and the runtime are visible.  This is the functional-tile
        *bench* mode (microbatch dynamics at depth M); the serving
        rounds themselves run true per-stage decode through the same
        executor (``runtime.stage_decode``) whenever the engine has a
        partitioned plan on the device path.
        """
        if self.partitioned_plan is None:
            raise ValueError("engine has no partitioned plan "
                             "(ServeConfig.stream_pus not set or K=1)")
        if n_microbatches is None:
            from repro.runtime.autotune import AutotuneConfig, tune_pipeline

            result = tune_pipeline(
                self.partitioned_plan,
                AutotuneConfig(target_bubble=self.serve_cfg.target_bubble),
            )
            self.last_autotune = result
            self.last_pipeline_report = result.report
            return result.report
        from repro.runtime.pipeline_exec import execute_partitioned_plan

        report = execute_partitioned_plan(
            self.partitioned_plan, n_microbatches=n_microbatches
        )
        self.last_autotune = None     # pinned M supersedes any prior tune
        self.last_pipeline_report = report
        return report

    # -- metrics --------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        done = self.completed
        toks = sum(len(r.out_tokens) for r in done)
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        total = (
            max(r.done_at for r in done) - min(r.submitted_at for r in done)
            if done
            else 0.0
        )
        out = {
            "completed": float(len(done)),
            "tokens": float(toks),
            "rounds": float(self.rounds),
            "tokens_per_s": toks / total if total > 0 else 0.0,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "device_resident": 0.0 if self.serve_cfg.host_sampling else 1.0,
            "decode_traces": float(self.trace_counts["decode"]),
            "prefill_traces": float(self.trace_counts["prefill"]),
        }
        for b, times in sorted(self.prefill_bucket_s.items()):
            out[f"prefill_s_bucket{b}"] = float(np.mean(times))
        if self.streaming_plan is not None:
            out.update(
                {f"stream_{k}": v for k, v in self.streaming_plan.summary().items()}
            )
        if self.partitioned_plan is not None:
            p = self.partitioned_plan
            out.update(
                {
                    "partition_stages": float(len(p.stages)),
                    "partition_fps": p.fps,
                    "partition_latency_s": p.latency_s,
                    "partition_bottleneck_s": p.bottleneck_s,
                    "partition_stall_s": sum(
                        s.plan.total_stall for s in p.stages
                    ),
                }
            )
            if self.last_pipeline_report is not None:
                r = self.last_pipeline_report
                out.update(
                    {
                        "partition_executed_fps": r.measured_fps,
                        # vs the steady-state analytic fps (like
                        # FleetSim.execute_pipelines): < 1 by the fill
                        # bubble, so the stat can actually move
                        "partition_executed_vs_analytic": (
                            r.measured_fps / r.steady_fps
                            if r.steady_fps > 0
                            else 0.0
                        ),
                        "partition_bubble_measured": r.bubble_measured,
                        "partition_bubble_predicted": r.bubble_predicted,
                        "partition_executed_wall_s": r.wall_s,
                        "partition_microbatches": float(r.n_microbatches),
                    }
                )
            if self.last_autotune is not None:
                a = self.last_autotune
                out.update(
                    {
                        "partition_autotuned_m": float(a.n_microbatches),
                        "partition_autotuned_queue_depth": float(
                            a.queue_depth
                        ),
                        "partition_autotune_target_bubble": a.target_bubble,
                        "partition_autotune_within_tolerance": float(
                            a.within_tolerance
                        ),
                        "partition_autotune_trials": float(len(a.trials)),
                    }
                )
            if self.stage_meshes is not None:
                out["partition_stage_devices"] = float(
                    sum(len(m.devices.ravel()) for m in self.stage_meshes)
                    if not self.stage_meshes_shared
                    else len(self.mesh.devices.ravel())
                )
            if self._staged is not None:
                # fold any open overlapped session into the virtual
                # account so the reported bubble covers every block
                self._staged.flush()
                out["stage_decode"] = 1.0
                out["stage_decode_rounds"] = float(
                    self._staged.rounds_executed
                )
                out["stage_decode_clock_ok"] = float(self._staged.clock_ok)
                out["stage_decode_coalesced"] = float(self._staged.coalesce)
                out["stage_decode_microbatches"] = float(
                    self._staged.n_groups
                )
                out["stage_decode_queue_depth"] = float(
                    self._staged.queue_depth
                )
                out["stage_decode_bubble"] = self._staged.bubble_fraction
                for k, (a, b) in enumerate(self._staged.ranges):
                    out[f"stage{k}_decode_layers"] = float(b - a)
                if self.staged_tune is not None:
                    t = self.staged_tune
                    out["stage_decode_autotuned"] = 1.0
                    out["stage_decode_autotune_target_bubble"] = (
                        t.target_bubble
                    )
                    out["stage_decode_autotune_within_tolerance"] = float(
                        t.within_tolerance
                    )
                    out["stage_decode_autotune_trials"] = float(
                        len(t.trials)
                    )
        return out


# -------------------------------------------------------------------------
# cache scatter + streaming-plan construction
# -------------------------------------------------------------------------


def scatter_cache_lanes(batched_cache, group_cache, slots: jax.Array):
    """Write a batch of prefilled sequences into cache lanes ``slots``.

    Works over arbitrary cache pytrees: any array leaf whose second axis
    is the batch axis (layers-leading layout (L, B, ...)) gets lanes
    ``slots`` overwritten with the corresponding rows of ``group_cache``
    (zero-padded to the full lane, so stale state beyond the prefill
    never survives).  Rows whose slot index is out of bounds (the
    bucket-padding dummies) are dropped by the scatter.
    """

    def upd(full, one):
        if not hasattr(full, "ndim") or full.ndim < 2:
            return full
        one = one.astype(full.dtype)
        pad_shape = (full.shape[0], one.shape[1]) + full.shape[2:]
        slicer = tuple(
            slice(0, min(o, f)) for o, f in zip(one.shape, pad_shape)
        )
        patch = jnp.zeros(pad_shape, full.dtype).at[slicer].set(one[slicer])
        return full.at[:, slots].set(patch, mode="drop")

    return jax.tree.map(upd, batched_cache, group_cache)


def scatter_cache(batched_cache, one_cache, slot: int, length: int):
    """Write a single-sequence prefill cache into lane ``slot`` (the
    lane-isolated special case of :func:`scatter_cache_lanes`)."""
    del length  # the full lane is overwritten; garbage can't survive
    return scatter_cache_lanes(
        batched_cache, one_cache, jnp.asarray([slot], jnp.int32)
    )


def model_gemms(cfg: ModelConfig, batch_tokens: int) -> List[Tuple[str, int, int, int]]:
    """(name, N, M, P) for every weight GEMM of one decode round, in
    inference order -- the schedulable tile sequence of the paper (SS III)
    applied to an LM.  P = tokens per round (the decode batch).
    """
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.head_dim
    gemms: List[Tuple[str, int, int, int]] = []
    p = batch_tokens
    for layer in range(cfg.n_layers):
        pre = f"L{layer}"
        if cfg.family not in ("ssm",):
            gemms.append((f"{pre}/q", cfg.n_heads * hd, d, p))
            gemms.append((f"{pre}/k", cfg.n_kv_heads * hd, d, p))
            gemms.append((f"{pre}/v", cfg.n_kv_heads * hd, d, p))
            gemms.append((f"{pre}/o", d, cfg.n_heads * hd, p))
        if cfg.family in ("ssm", "hybrid"):
            din = cfg.d_inner
            ns, nh = cfg.ssm_state, cfg.ssm_heads
            gemms.append((f"{pre}/ssm_in", 2 * din + 2 * ns + nh, d, p))
            gemms.append((f"{pre}/ssm_out", d, din, p))
        if cfg.is_moe:
            # only routed-to experts need residency: top_k of n_experts
            for e in range(cfg.top_k):
                gemms.append((f"{pre}/expert{e}/up", f, d, p))
                gemms.append((f"{pre}/expert{e}/gate", f, d, p))
                gemms.append((f"{pre}/expert{e}/down", d, f, p))
        elif cfg.d_ff > 0 and cfg.family != "ssm":
            n_mats = 3 if cfg.mlp == "swiglu" else 2
            gemms.append((f"{pre}/mlp_up", f * (n_mats - 1), d, p))
            gemms.append((f"{pre}/mlp_down", d, f, p))
    gemms.append(("unembed", cfg.vocab, d, p))
    return gemms


def plan_model_streaming(
    cfg: ModelConfig,
    pu: Optional[PUConfig] = None,
    batch_tokens: int = 8,
    search: Optional[SearchConfig] = None,
) -> StreamingPlan:
    """Two-phase streaming plan for one decode round of ``cfg``.

    Layer-level granularity (not R_SA rows): at TPU scale a schedulable
    tile is one weight matrix; the scheduler math is identical.
    """
    pu = pu or host_offload_config()
    tiles = [
        WeightTile(name=name, layer_index=i, n=n, m=m, p=p)
        for i, (name, n, m, p) in enumerate(model_gemms(cfg, batch_tokens))
    ]
    return plan_streaming(tiles, pu, search=search)


def _gemm_layer(name: str, n_layers: int) -> int:
    """Model-layer index of a ``model_gemms`` entry (``L{i}/...``);
    layer-less tails (unembed) count as past the last layer."""
    if name.startswith("L"):
        head = name.split("/", 1)[0]
        try:
            return int(head[1:])
        except ValueError:
            pass
    return n_layers


def attach_decode_ranges(
    cfg: ModelConfig,
    gemms: Sequence[Tuple[str, int, int, int]],
    pplan: PartitionedPlan,
) -> PartitionedPlan:
    """Derive each stage's *model-layer* decode range from its GEMM range.

    A model layer belongs to the stage that owns its first GEMM; the
    resulting boundaries are snapped to the family's allowed slice
    points (``ModelAPI.decode_slice_points`` -- e.g. hybrid boundaries
    must be group-aligned) and kept monotone, so the ranges tile
    ``[0, n_layers)`` exactly.  Snapping is non-empty-preserving
    (:func:`repro.plan.partition.snap_boundaries_nonempty`): whenever
    the slice grid has at least K-1 interior points, every stage owns
    >= 1 layer -- the unembed-heavy tail of the GEMM sequence would
    otherwise pull the last boundary onto ``n_layers`` and leave a
    degenerate empty stage idling through every decode round.  Only
    when K exceeds what the grid can host does a stage go empty and
    pass hidden states through untouched."""
    api = model_api.get_api(cfg)
    pts = sorted(api.decode_slice_points(cfg))
    L = cfg.n_layers
    first_gemm: Dict[int, int] = {}
    for gi, (name, *_rest) in enumerate(gemms):
        first_gemm.setdefault(_gemm_layer(name, L), gi)
    bounds = [0]
    for st in pplan.stages[1:]:
        gs = st.layer_start            # gemm-sequence index
        bounds.append(
            sum(1 for l in range(L) if first_gemm.get(l, 1 << 60) < gs)
        )
    bounds.append(L)
    snapped = [0] + snap_boundaries_nonempty(bounds[1:-1], pts, L) + [L]
    stages = tuple(
        dataclasses.replace(
            s,
            decode_layer_start=snapped[k],
            decode_layer_stop=snapped[k + 1],
        )
        for k, s in enumerate(pplan.stages)
    )
    return PartitionedPlan(stages=stages)


def plan_partitioned_streaming(
    cfg: ModelConfig,
    pus: Sequence[PUConfig],
    batch_tokens: int = 8,
    search: Optional[SearchConfig] = None,
) -> PartitionedPlan:
    """Split one decode round's GEMM sequence across several PU profiles.

    Contiguous GEMM ranges are balanced on each profile's exec-time model
    and each stage gets its own two-phase schedule (capacity + load
    channel per PU) -- the served model streams across the whole fleet
    instead of replicating frames.  ``search`` selects each stage's
    schedule-search strategy.  Each stage also carries the model-layer
    decode range its layer slicers consume (:func:`attach_decode_ranges`),
    making the plan runnable by ``runtime.stage_decode``.
    """
    gemms = model_gemms(cfg, batch_tokens)
    pplan = partition_gemms(gemms, list(pus), search=search)
    return attach_decode_ranges(cfg, gemms, pplan)
