"""Batched serving engine with the paper's adaptive weight streaming.

The paper's scenario: model weights live off-chip (HBM@FPGA); a scheduler
streams weight tiles into fast memory (URAM) while inference runs, hiding
load latency behind compute (SS III).  The TPU serving analogue implemented
here has two streaming levels:

- **HBM -> VMEM** (per-layer weight residency inside a step) is Pallas's
  block pipeline -- the int8 GEMM kernel already double-buffers tiles.
- **host -> HBM** (whole-model residency across steps) is where the paper's
  scheduler runs at serving scale: when a model's weights exceed device
  HBM, layer-group tiles are prefetched from host memory under the
  two-phase schedule; `ServingEngine` plans this with the same
  `core.scheduler` used for the FPGA reproduction (PUConfig =
  `host_offload_config()`).

The engine also carries the paper's SS VI AIMC emulation: an optional
NoiseInjectionUnit refreshes weights with fresh device-noise instances
every inference round, exactly the NIU read-modify-write loop.

Request flow (continuous batching, decode-centric):

    submit(prompt tokens) -> queue
    engine step: admit up to free slots, prefill each new request,
                 one batched decode_step for all active slots,
                 retire slots that hit eos/max_tokens.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.aimc import AIMCNoiseModel, NoiseInjectionUnit
from repro.core.pu import PUConfig, host_offload_config
from repro.core.streaming import StreamingPlan, WeightTile, plan_streaming
from repro.models import api as model_api
from repro.plan import PartitionedPlan, SearchConfig, partition_gemms


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8             # decode slots
    max_len: int = 512             # KV capacity per slot
    max_new_tokens: int = 32
    eos_token: int = -1            # -1: never stop on a token
    temperature: float = 0.0       # 0 => greedy
    seed: int = 0
    # weight streaming (host->HBM level); None disables planning
    stream_pu: Optional[PUConfig] = None
    # multi-PU partitioned streaming: the model's layer sequence is split
    # across these profiles (contiguous ranges balanced on exec time, one
    # two-phase schedule per PU -- repro.plan.partition); overrides the
    # single-PU plan when set
    stream_pus: Optional[List[PUConfig]] = None
    # schedule-search strategy for the streaming/partition planners
    # (None/heuristic = the paper's one-shot heuristic; beam/anneal run
    # the richer search funded by the event-indexed engine)
    plan_search: Optional[SearchConfig] = None
    # target fill/drain bubble fraction for the auto-tuned microbatch
    # depth when execute_partition() is called without an explicit M
    target_bubble: float = 0.10
    # AIMC emulation
    aimc: Optional[AIMCNoiseModel] = None
    aimc_refresh_every: int = 1    # refresh noise every N engine rounds


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at


class ServingEngine:
    """Continuous-batching LM server over the uniform model API."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        serve_cfg: ServeConfig,
        mesh=None,
        rules=None,
    ):
        self.cfg = cfg
        self.api = model_api.get_api(cfg)
        self.serve_cfg = serve_cfg
        self.mesh = mesh
        self.rules = rules
        self._pristine = params
        self.params = params
        self._rng = np.random.default_rng(serve_cfg.seed)
        self._key = jax.random.PRNGKey(serve_cfg.seed)

        # request/slot state
        self._queue: deque[Request] = deque()
        self._uid = 0
        self._slots: List[Optional[Request]] = [None] * serve_cfg.max_batch
        self._slot_pos = np.zeros(serve_cfg.max_batch, np.int32)
        self._slot_remaining = np.zeros(serve_cfg.max_batch, np.int32)
        self.completed: List[Request] = []
        self.rounds = 0

        # batched KV cache for all slots
        self._cache = self.api.init_cache(
            cfg, serve_cfg.max_batch, serve_cfg.max_len
        )

        # jitted steps (single-device path by default; mesh-sharded when
        # mesh+rules are provided)
        self._decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(cfg, p, c, t, pos)
        )

        # --- paper machinery ------------------------------------------------
        self.streaming_plan: Optional[StreamingPlan] = None
        self.partitioned_plan: Optional[PartitionedPlan] = None
        self.stage_meshes = None
        self.stage_meshes_shared = False
        self.last_pipeline_report = None
        self.last_autotune = None
        if serve_cfg.stream_pus and len(serve_cfg.stream_pus) == 1:
            # K=1 degenerates to the single-PU path: one "partition
            # stage" would only re-wrap the plain streaming plan.
            self.streaming_plan = plan_model_streaming(
                cfg, serve_cfg.stream_pus[0],
                batch_tokens=serve_cfg.max_batch,
                search=serve_cfg.plan_search,
            )
        elif serve_cfg.stream_pus:
            self.partitioned_plan = plan_partitioned_streaming(
                cfg, serve_cfg.stream_pus,
                batch_tokens=serve_cfg.max_batch,
                search=serve_cfg.plan_search,
            )
            if mesh is not None:
                from repro.launch.mesh import stage_submeshes

                self.stage_meshes, self.stage_meshes_shared = stage_submeshes(
                    mesh, len(self.partitioned_plan.stages)
                )
        elif serve_cfg.stream_pu is not None:
            self.streaming_plan = plan_model_streaming(
                cfg, serve_cfg.stream_pu,
                batch_tokens=serve_cfg.max_batch,
                search=serve_cfg.plan_search,
            )
        self.niu: Optional[NoiseInjectionUnit] = None
        if serve_cfg.aimc is not None and serve_cfg.aimc.enabled():
            self.niu = NoiseInjectionUnit(params, serve_cfg.aimc)

    # -- client API --------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None) -> int:
        req = Request(
            uid=self._uid,
            prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new_tokens or self.serve_cfg.max_new_tokens,
            submitted_at=time.perf_counter(),
        )
        self._uid += 1
        self._queue.append(req)
        return req.uid

    @property
    def active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def pending(self) -> int:
        return len(self._queue)

    def run_until_drained(self, max_rounds: int = 10_000) -> List[Request]:
        while (self.pending or self.active) and self.rounds < max_rounds:
            self.step()
        return self.completed

    # -- engine round -------------------------------------------------------
    def step(self):
        """One engine round: AIMC refresh -> admit+prefill -> batched decode."""
        sc = self.serve_cfg
        if self.niu is not None and self.rounds % sc.aimc_refresh_every == 0:
            self._key, sub = jax.random.split(self._key)
            self.params = self.niu.refresh(sub)

        # admit
        for i in range(sc.max_batch):
            if self._slots[i] is None and self._queue:
                req = self._queue.popleft()
                self._admit(i, req)

        if not self.active:
            self.rounds += 1
            return

        # batched decode for all active slots (inactive slots decode a pad
        # token into their own cache lane; results discarded)
        tokens = np.zeros((sc.max_batch, 1), np.int32)
        for i, req in enumerate(self._slots):
            if req is not None:
                last = (
                    req.out_tokens[-1]
                    if req.out_tokens
                    else int(req.prompt[-1])
                )
                tokens[i, 0] = last
        # single shared position per call: slots are aligned because every
        # prefill wrote its prompt left-aligned; per-slot positions tracked
        # host-side and passed as the max (cache updates are per-lane).
        pos = int(self._slot_pos.max())
        logits, self._cache = self._decode(
            self.params, self._cache, jnp.asarray(tokens), jnp.int32(pos)
        )
        logits = np.asarray(logits, np.float32)

        now = time.perf_counter()
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            tok = self._sample(logits[i])
            req.out_tokens.append(tok)
            if req.first_token_at is None:
                req.first_token_at = now
            self._slot_pos[i] += 1
            self._slot_remaining[i] -= 1
            if (
                self._slot_remaining[i] <= 0
                or tok == sc.eos_token
                or self._slot_pos[i] >= sc.max_len - 1
            ):
                req.done_at = now
                self.completed.append(req)
                self._slots[i] = None
        self.rounds += 1

    def _admit(self, slot: int, req: Request):
        """Prefill a request into one cache lane."""
        sc = self.serve_cfg
        prompt = req.prompt[-(sc.max_len - req.max_new_tokens - 1) :]
        # lane-isolated prefill: run the model on this prompt alone, then
        # scatter its kv into the batched cache at the slot index.
        tokens = jnp.asarray(prompt[None, :], jnp.int32)
        batch = {"tokens": tokens}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.vision_patches, self.cfg.d_model),
                jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32,
            )
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.encoder_frames, self.cfg.d_model),
                jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32,
            )
        logits, cache = self.api.prefill(self.cfg, self.params, batch)
        self._cache = scatter_cache(self._cache, cache, slot, len(prompt))
        self._slots[slot] = req
        self._slot_pos[slot] = len(prompt)
        self._slot_remaining[slot] = req.max_new_tokens
        tok = self._sample(np.asarray(logits, np.float32)[0])
        req.out_tokens.append(tok)
        req.first_token_at = time.perf_counter()
        self._slot_remaining[slot] -= 1

    def _sample(self, logits: np.ndarray) -> int:
        if self.serve_cfg.temperature <= 0:
            return int(np.argmax(logits))
        p = logits / self.serve_cfg.temperature
        p = np.exp(p - p.max())
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    # -- executed partition (stage-parallel streaming runtime) ---------------
    def execute_partition(self, n_microbatches: Optional[int] = None):
        """Run the partitioned plan through the real stage-parallel
        executor (``runtime.pipeline_exec``): K stage threads, per-stage
        prefetch workers honoring issue order, double-buffered handoffs.

        ``n_microbatches=None`` (the default) auto-tunes the microbatch
        depth and handoff queue depth against
        ``ServeConfig.target_bubble`` using the *executed* bubble
        measurement (``runtime.autotune``); an explicit integer pins M
        (the legacy fixed-depth behaviour).

        Validates the partition as a *runnable* artifact -- measured
        pipeline throughput and fill bubble land in :meth:`stats`
        alongside the analytic numbers so regressions between the cost
        model and the runtime are visible.  ``stage_meshes`` records the
        submesh each stage would own (reported in stats); running each
        stage's decode slice *on* its submesh is the ROADMAP "true
        per-stage decode" follow-up.
        """
        if self.partitioned_plan is None:
            raise ValueError("engine has no partitioned plan "
                             "(ServeConfig.stream_pus not set or K=1)")
        if n_microbatches is None:
            from repro.runtime.autotune import AutotuneConfig, tune_pipeline

            result = tune_pipeline(
                self.partitioned_plan,
                AutotuneConfig(target_bubble=self.serve_cfg.target_bubble),
            )
            self.last_autotune = result
            self.last_pipeline_report = result.report
            return result.report
        from repro.runtime.pipeline_exec import execute_partitioned_plan

        report = execute_partitioned_plan(
            self.partitioned_plan, n_microbatches=n_microbatches
        )
        self.last_autotune = None     # pinned M supersedes any prior tune
        self.last_pipeline_report = report
        return report

    # -- metrics --------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        done = self.completed
        toks = sum(len(r.out_tokens) for r in done)
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        total = (
            max(r.done_at for r in done) - min(r.submitted_at for r in done)
            if done
            else 0.0
        )
        out = {
            "completed": float(len(done)),
            "tokens": float(toks),
            "rounds": float(self.rounds),
            "tokens_per_s": toks / total if total > 0 else 0.0,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0,
        }
        if self.streaming_plan is not None:
            out.update(
                {f"stream_{k}": v for k, v in self.streaming_plan.summary().items()}
            )
        if self.partitioned_plan is not None:
            p = self.partitioned_plan
            out.update(
                {
                    "partition_stages": float(len(p.stages)),
                    "partition_fps": p.fps,
                    "partition_latency_s": p.latency_s,
                    "partition_bottleneck_s": p.bottleneck_s,
                    "partition_stall_s": sum(
                        s.plan.total_stall for s in p.stages
                    ),
                }
            )
            if self.last_pipeline_report is not None:
                r = self.last_pipeline_report
                out.update(
                    {
                        "partition_executed_fps": r.measured_fps,
                        # vs the steady-state analytic fps (like
                        # FleetSim.execute_pipelines): < 1 by the fill
                        # bubble, so the stat can actually move
                        "partition_executed_vs_analytic": (
                            r.measured_fps / r.steady_fps
                            if r.steady_fps > 0
                            else 0.0
                        ),
                        "partition_bubble_measured": r.bubble_measured,
                        "partition_bubble_predicted": r.bubble_predicted,
                        "partition_executed_wall_s": r.wall_s,
                        "partition_microbatches": float(r.n_microbatches),
                    }
                )
            if self.last_autotune is not None:
                a = self.last_autotune
                out.update(
                    {
                        "partition_autotuned_m": float(a.n_microbatches),
                        "partition_autotuned_queue_depth": float(
                            a.queue_depth
                        ),
                        "partition_autotune_target_bubble": a.target_bubble,
                        "partition_autotune_within_tolerance": float(
                            a.within_tolerance
                        ),
                        "partition_autotune_trials": float(len(a.trials)),
                    }
                )
            if self.stage_meshes is not None:
                out["partition_stage_devices"] = float(
                    sum(len(m.devices.ravel()) for m in self.stage_meshes)
                    if not self.stage_meshes_shared
                    else len(self.mesh.devices.ravel())
                )
        return out


# -------------------------------------------------------------------------
# cache scatter + streaming-plan construction
# -------------------------------------------------------------------------


def scatter_cache(batched_cache, one_cache, slot: int, length: int):
    """Write a single-sequence prefill cache into lane ``slot``.

    Works over arbitrary cache pytrees: any array leaf whose second axis is
    the batch axis (layers-leading layout (L, B, ...)) gets lane `slot`
    overwritten with the new sequence's state.
    """

    def upd(full, one):
        if not hasattr(full, "ndim") or full.ndim < 2:
            return full
        # (L, 1, ...) -> write into (L, B, ...) at batch index `slot`.
        seq_axes = full.ndim - 2
        start = (0, slot) + (0,) * seq_axes
        one = one.astype(full.dtype)
        pad_shape = list(full.shape)
        pad_shape[1] = 1
        slicer = tuple(
            slice(0, min(o, f)) for o, f in zip(one.shape, pad_shape)
        )
        patch = jnp.zeros(pad_shape, full.dtype).at[slicer].set(one[slicer])
        return jax.lax.dynamic_update_slice(full, patch, start)

    return jax.tree.map(upd, batched_cache, one_cache)


def model_gemms(cfg: ModelConfig, batch_tokens: int) -> List[Tuple[str, int, int, int]]:
    """(name, N, M, P) for every weight GEMM of one decode round, in
    inference order -- the schedulable tile sequence of the paper (SS III)
    applied to an LM.  P = tokens per round (the decode batch).
    """
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.head_dim
    gemms: List[Tuple[str, int, int, int]] = []
    p = batch_tokens
    for layer in range(cfg.n_layers):
        pre = f"L{layer}"
        if cfg.family not in ("ssm",):
            gemms.append((f"{pre}/q", cfg.n_heads * hd, d, p))
            gemms.append((f"{pre}/k", cfg.n_kv_heads * hd, d, p))
            gemms.append((f"{pre}/v", cfg.n_kv_heads * hd, d, p))
            gemms.append((f"{pre}/o", d, cfg.n_heads * hd, p))
        if cfg.family in ("ssm", "hybrid"):
            din = cfg.d_inner
            ns, nh = cfg.ssm_state, cfg.ssm_heads
            gemms.append((f"{pre}/ssm_in", 2 * din + 2 * ns + nh, d, p))
            gemms.append((f"{pre}/ssm_out", d, din, p))
        if cfg.is_moe:
            # only routed-to experts need residency: top_k of n_experts
            for e in range(cfg.top_k):
                gemms.append((f"{pre}/expert{e}/up", f, d, p))
                gemms.append((f"{pre}/expert{e}/gate", f, d, p))
                gemms.append((f"{pre}/expert{e}/down", d, f, p))
        elif cfg.d_ff > 0 and cfg.family != "ssm":
            n_mats = 3 if cfg.mlp == "swiglu" else 2
            gemms.append((f"{pre}/mlp_up", f * (n_mats - 1), d, p))
            gemms.append((f"{pre}/mlp_down", d, f, p))
    gemms.append(("unembed", cfg.vocab, d, p))
    return gemms


def plan_model_streaming(
    cfg: ModelConfig,
    pu: Optional[PUConfig] = None,
    batch_tokens: int = 8,
    search: Optional[SearchConfig] = None,
) -> StreamingPlan:
    """Two-phase streaming plan for one decode round of ``cfg``.

    Layer-level granularity (not R_SA rows): at TPU scale a schedulable
    tile is one weight matrix; the scheduler math is identical.
    """
    pu = pu or host_offload_config()
    tiles = [
        WeightTile(name=name, layer_index=i, n=n, m=m, p=p)
        for i, (name, n, m, p) in enumerate(model_gemms(cfg, batch_tokens))
    ]
    return plan_streaming(tiles, pu, search=search)


def plan_partitioned_streaming(
    cfg: ModelConfig,
    pus: Sequence[PUConfig],
    batch_tokens: int = 8,
    search: Optional[SearchConfig] = None,
) -> PartitionedPlan:
    """Split one decode round's GEMM sequence across several PU profiles.

    Contiguous GEMM ranges are balanced on each profile's exec-time model
    and each stage gets its own two-phase schedule (capacity + load
    channel per PU) -- the served model streams across the whole fleet
    instead of replicating frames.  ``search`` selects each stage's
    schedule-search strategy.
    """
    return partition_gemms(
        model_gemms(cfg, batch_tokens), list(pus), search=search
    )
