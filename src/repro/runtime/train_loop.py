"""Fault-tolerant training loop.

Production concerns carried by this loop (DESIGN.md SS5):

- **Checkpoint/restart**: async atomic checkpoints every ``ckpt_every``
  steps (params + optimizer + loader state); on start the loop auto-resumes
  from the latest valid checkpoint.  A crash at any point loses at most the
  steps since the last checkpoint.
- **Elastic scaling**: the checkpoint stores global (unsharded) arrays, so
  a restart may present a *different* mesh; `TrainLoop` re-resolves all
  shardings against the new mesh and device_puts state accordingly.  The
  data pipeline is index-based, so the stream continues exactly.
- **Straggler mitigation**: per-step wall times feed a rolling median; a
  step slower than ``straggler_factor``x the median raises a counter and
  invokes a hook (on real fleets: report to the coordinator, trigger
  hot-spare swap; here: recorded + assertable).  This is deliberately at
  the *loop* level -- XLA steps are synchronous, so detection must be
  host-side.
- **Failure injection**: ``crash_at_step`` simulates a hard node failure
  (raises mid-run) so tests can prove restart-correctness: a run crashed at
  step k and resumed reaches the same final state as an uninterrupted run
  (bitwise, because steps are deterministic).
- **NaN/overflow guards**: non-finite loss aborts the step, restores from
  the last checkpoint and skips the offending batch (common large-scale
  practice), up to ``max_nan_restores`` times.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import DataConfig, build_dataset
from repro.models import api as model_api
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.steps import make_train_step


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    log_every: int = 10
    metrics_path: Optional[str] = None
    # fault tolerance
    straggler_factor: float = 3.0
    straggler_window: int = 20
    crash_at_step: Optional[int] = None       # failure injection (tests)
    max_nan_restores: int = 3
    seed: int = 0


@dataclasses.dataclass
class StepRecord:
    step: int
    loss: float
    grad_norm: float
    lr: float
    wall_s: float
    straggler: bool


class StragglerDetector:
    """Rolling-median step-time outlier detection (host-side)."""

    def __init__(self, factor: float, window: int):
        self.factor = factor
        self.window = window
        self._times: List[float] = []
        self.events: List[int] = []

    def observe(self, step: int, wall_s: float) -> bool:
        is_straggler = False
        if len(self._times) >= max(5, self.window // 2):
            med = float(np.median(self._times[-self.window :]))
            if wall_s > self.factor * med:
                is_straggler = True
                self.events.append(step)
        self._times.append(wall_s)
        if len(self._times) > 4 * self.window:
            self._times = self._times[-2 * self.window :]
        return is_straggler


class SimulatedCrash(RuntimeError):
    """Injected node failure (tests/drills)."""


class TrainLoop:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeConfig,
        mesh,
        rules,
        loop_cfg: TrainLoopConfig,
        opt_cfg: Optional[AdamWConfig] = None,
        data_cfg: Optional[DataConfig] = None,
        straggler_hook: Optional[Callable[[int, float], None]] = None,
    ):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.rules = rules
        self.loop_cfg = loop_cfg
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.data_cfg = data_cfg or DataConfig(
            seq_len=shape.seq_len,
            global_batch=shape.global_batch,
            vocab=cfg.vocab,
            seed=loop_cfg.seed,
        )
        self.dataset = build_dataset(self.data_cfg)
        self.ckpt = CheckpointManager(
            loop_cfg.ckpt_dir, keep=loop_cfg.keep_checkpoints
        )
        self.straggler = StragglerDetector(
            loop_cfg.straggler_factor, loop_cfg.straggler_window
        )
        self.straggler_hook = straggler_hook
        self.records: List[StepRecord] = []

        step_fn, specs, in_sh, out_sh = make_train_step(
            cfg, shape, mesh, rules, self.opt_cfg
        )
        self._shardings = in_sh
        with mesh:
            self._step = jax.jit(
                step_fn,
                in_shardings=in_sh,
                out_shardings=out_sh,
                donate_argnums=(0, 1),
            )

    # -- state ------------------------------------------------------------
    def init_state(self):
        from repro.optim import cast_params_bf16
        import functools

        api = model_api.get_api(self.cfg)
        mw = self.opt_cfg.master_weights

        def init(k):
            p = api.init_params(self.cfg, k)
            return cast_params_bf16(p) if mw else p

        with self.mesh:
            params = jax.jit(init, out_shardings=self._shardings[0])(
                jax.random.PRNGKey(self.loop_cfg.seed)
            )
            opt = jax.jit(
                functools.partial(adamw_init, master_weights=mw),
                out_shardings=self._shardings[1],
            )(params)
        return params, opt

    def _state_like(self):
        from repro.optim import cast_params_bf16

        api = model_api.get_api(self.cfg)
        mw = self.opt_cfg.master_weights
        params_s = jax.eval_shape(
            lambda: api.init_params(self.cfg, jax.random.PRNGKey(0))
        )
        if mw:
            params_s = jax.eval_shape(cast_params_bf16, params_s)
        opt_s = jax.eval_shape(
            lambda p: adamw_init(p, master_weights=mw), params_s
        )
        return {"params": params_s, "opt": opt_s}

    def try_restore(self):
        """(params, opt, next_step) from the latest checkpoint, or None."""
        step = self.ckpt.latest_step()
        if step is None:
            return None
        state, extra = self.ckpt.restore(
            self._state_like(),
            step=step,
            shardings={
                "params": self._shardings[0],
                "opt": self._shardings[1],
            },
        )
        return state["params"], state["opt"], int(extra.get("next_step", step))

    # -- main -------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        restored = self.try_restore()
        if restored is not None:
            params, opt, start_step = restored
        else:
            params, opt = self.init_state()
            start_step = 0

        lc = self.loop_cfg
        nan_restores = 0
        metrics_f = None
        if lc.metrics_path:
            Path(lc.metrics_path).parent.mkdir(parents=True, exist_ok=True)
            metrics_f = open(lc.metrics_path, "a")

        step = start_step
        try:
            while step < lc.steps:
                if lc.crash_at_step is not None and step == lc.crash_at_step:
                    raise SimulatedCrash(f"injected failure at step {step}")

                batch_np = self.dataset.batch(step)
                batch = self._device_batch(batch_np)

                t0 = time.perf_counter()
                params, opt, m = self._step(params, opt, batch)
                loss = float(m["loss"])
                wall = time.perf_counter() - t0

                if not np.isfinite(loss):
                    # poison batch / overflow: restore + skip this batch.
                    nan_restores += 1
                    if nan_restores > lc.max_nan_restores:
                        raise FloatingPointError(
                            f"non-finite loss at step {step}, restores exhausted"
                        )
                    restored = self.try_restore()
                    if restored is None:
                        params, opt = self.init_state()
                        step = 0
                    else:
                        params, opt, step = restored
                    step += 1  # skip the offending batch index
                    continue

                is_straggler = self.straggler.observe(step, wall)
                if is_straggler and self.straggler_hook:
                    self.straggler_hook(step, wall)

                rec = StepRecord(
                    step=step,
                    loss=loss,
                    grad_norm=float(m["grad_norm"]),
                    lr=float(m["lr"]),
                    wall_s=wall,
                    straggler=is_straggler,
                )
                self.records.append(rec)
                if metrics_f:
                    metrics_f.write(json.dumps(dataclasses.asdict(rec)) + "\n")
                if lc.log_every and step % lc.log_every == 0:
                    print(
                        f"step {step:6d}  loss {loss:8.4f}  "
                        f"gnorm {rec.grad_norm:7.3f}  {wall*1e3:7.1f} ms"
                        + ("  [straggler]" if is_straggler else "")
                    )

                step += 1
                if step % lc.ckpt_every == 0 or step == lc.steps:
                    self.ckpt.save(
                        step,
                        {"params": params, "opt": opt},
                        extra={"next_step": step},
                    )
        finally:
            self.ckpt.wait()
            if metrics_f:
                metrics_f.close()

        return {
            "final_step": step,
            "final_loss": self.records[-1].loss if self.records else None,
            "straggler_events": list(self.straggler.events),
            "nan_restores": nan_restores,
            "params": params,
            "opt": opt,
        }

    def _device_batch(self, batch_np):
        # Modality stubs (vlm patch embeds / encdec frames) are synthesized
        # here: the assignment treats front-ends as stubs providing
        # precomputed embeddings.
        struct = model_api.batch_struct(self.cfg, self.shape)
        for k, s in struct.items():
            if k not in batch_np:
                rng = np.random.default_rng(hash(k) % (2**32))
                batch_np[k] = rng.standard_normal(s.shape, np.float32).astype(
                    np.dtype(s.dtype) if s.dtype != jnp.bfloat16 else np.float32
                )
        b_shard = self._shardings[2]
        with self.mesh:
            return {
                k: jax.device_put(
                    jnp.asarray(batch_np[k]).astype(struct[k].dtype), b_shard[k]
                )
                for k in struct
            }
