"""Stage-parallel multi-PU streaming runtime: run a PartitionedPlan.

``repro.plan.partition`` produces a :class:`PartitionedPlan` -- K
contiguous layer ranges, each with its own two-phase weight-streaming
schedule on its own PU.  Until this module, that plan was a *report*:
``StreamingExecutor`` drives one PU serially and serving only attached
the partition's analytic numbers.  :class:`StagePipelineExecutor` makes
the plan a runnable artifact:

- **One thread per stage**, each paired with its own *prefetch worker*
  that drains the stage plan's load-channel issue order through a
  :class:`repro.core.streaming.StageStreamCore` (capacity-gated, so the
  residency bound the plan was verified against is enforced at runtime).
- **Double-buffered handoff queues** between stages carry activation
  payloads; a bounded queue (default depth 2) gives the ping-pong
  buffering of the hardware proposal and applies backpressure to
  upstream stages.
- **Microbatch injection**: the caller feeds M microbatches; the
  pipeline fills, streams, and drains, exactly the GPipe schedule that
  ``parallel/pipeline.py`` implements with shard_map -- and the same
  ``bubble_fraction`` model is used to cross-check the *measured*
  fill/drain bubble against the analytic prediction.
- **Real stage compute** (``run_stage``): with a per-stage callback the
  handoff queues carry live ``(B, 1, d_model)`` hidden-state tensors --
  each stage folds its model-layer slice over the inbound activations
  (``runtime.stage_decode`` wires ``ModelAPI.decode_stage`` here) and
  ``stage_meshes`` ``jax.device_put``s the payload onto the consuming
  stage's submesh at every handoff.  The tile loop still runs, so the
  streaming/residency account and the virtual clock remain the
  cross-check against the analytic recurrence.

Timing: compute in this CPU container is functional, so throughput is
accounted in *virtual time* derived from the executed event stream --
each stage advances its clock by its plan-derived stage time as it
actually executes each frame, and handoffs carry the producer's virtual
finish time.  By construction these event times reproduce the
``PartitionedPlan.pipeline_events`` recurrence; what keeps the account
honest is the runtime structure around it: the *bounded* handoff queues
mean a secretly serialized schedule (a stage waiting for its upstream
to finish all frames) deadlocks for M > queue depth + 1 instead of
reporting good numbers, a stalled prefetch worker trips the acquire
timeout, and ordering/residency are asserted per fetch.  Real wall
time and ``max_concurrent_stages`` -- the observed high-water mark of
stages simultaneously mid-frame, 1 if stages never actually overlap --
are reported alongside as concurrency diagnostics.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.analysis import sanitize
from repro.core.streaming import StageStreamCore
from repro.plan.partition import PartitionedPlan, StagePlan


# fetch(stage, tile_index, tile_name) -> weights
FetchFn = Callable[[int, int, str], Any]
# run_tile(stage, tile_index, weights, carry) -> carry
RunTileFn = Callable[[int, int, Any, Any], Any]
# run_stage(stage, carry) -> carry: one real compute step over the whole
# stage (e.g. a layer-sliced decode_stage on device); applied after the
# tile acquire/release loop so the streaming/residency account still runs
RunStageFn = Callable[[int, Any], Any]


def _place_on_mesh(payload, mesh):
    """``jax.device_put`` every jax array leaf of ``payload`` onto
    ``mesh`` (replicated within the stage submesh) -- the inter-stage
    handoff that moves activations onto the consuming stage's devices.
    Non-array payloads (the functional-tile bench path) pass through."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    sharding = NamedSharding(mesh, PartitionSpec())

    def put(leaf):
        if isinstance(leaf, jax.Array):
            return jax.device_put(leaf, sharding)
        return leaf

    return jax.tree.map(put, payload)


@dataclasses.dataclass
class StageTrace:
    """Executed-event account of one stage."""

    stage: int
    pu: str
    frames: int = 0
    fetches: int = 0
    peak_resident_bytes: int = 0
    busy_s: float = 0.0            # virtual occupancy (stage_s per frame)
    stall_s: float = 0.0           # weight-streaming stalls (from the plan)
    handoff_s: float = 0.0         # inbound activation transfer charged
    starve_s: float = 0.0          # waited on upstream after first frame
    first_start_t: float = 0.0
    last_end_t: float = 0.0
    fetch_orders: List[List[str]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class PipelineReport:
    """Result of one microbatched run through the stage pipeline."""

    n_stages: int
    n_microbatches: int
    outputs: List[Any]
    frame_done_t: List[float]      # virtual completion time per frame
    makespan_s: float              # virtual
    measured_fps: float            # M / makespan (virtual)
    predicted_makespan_s: float    # PartitionedPlan.pipeline_makespan(M)
    predicted_fps: float
    steady_fps: float              # analytic 1/bottleneck (no fill)
    bubble_measured: float
    bubble_predicted: float        # GPipe (K-1)/(M+K-1)
    wall_s: float                  # real wall time of the threaded run
    max_concurrent_stages: int     # observed stages simultaneously mid-frame
    stages: List[StageTrace]
    # True when a run_stage callback executed real per-frame compute (the
    # handoff queues carried live activations, not functional stand-ins)
    real_stage_compute: bool = False

    def summary(self) -> Dict[str, float]:
        return {
            "stages": float(self.n_stages),
            "microbatches": float(self.n_microbatches),
            "makespan_s": self.makespan_s,
            "measured_fps": self.measured_fps,
            "predicted_fps": self.predicted_fps,
            "steady_fps": self.steady_fps,
            "bubble_measured": self.bubble_measured,
            "bubble_predicted": self.bubble_predicted,
            "wall_s": self.wall_s,
            "max_concurrent_stages": float(self.max_concurrent_stages),
            "fetches": float(sum(s.fetches for s in self.stages)),
            "stall_s": float(sum(s.stall_s for s in self.stages)),
        }


def _stage_tile_names(k: int, stage: StagePlan) -> List[str]:
    if stage.tile_names:
        return list(stage.tile_names)
    return [f"s{k}/t{i}" for i in range(stage.plan.n)]


# marker a failed stage pushes downstream so a consumer blocked on the
# drain queue wakes immediately instead of waiting out the stall timeout
_FAILED = object()


class PipelineSession:
    """A persistent in-flight frame loop over the stage threads.

    :meth:`StagePipelineExecutor.run` opens the pipeline, pushes a fixed
    batch of M microbatches, and drains it to completion.  Overlapped
    staged decode needs a different contract: frames are injected *as
    their dependencies drain* (round r+1 of a lane group may only enter
    stage 0 once round r of the same group left the last stage -- its
    logits feed the token the next round consumes), and the pipeline
    must stay open across rounds so the fill bubble is paid once per
    decode block, not once per round.  A session keeps the K stage
    threads (and their prefetch workers) alive between ``put``/``get``
    calls; ``close()`` joins them and yields the :class:`PipelineReport`.

    Frames carry ``(scale, round_id)`` alongside the payload: ``scale``
    prorates the virtual stage/handoff/stall account for lane-group
    microbatches carrying ``1/M`` of the slot batch, and ``round_id``
    lets each stage run its weight-streaming tile loop once per round
    (lane groups of the same round reuse the resident weights).

    The drain queue is unbounded: the owner consumes frames between
    puts, and a bounded drain could deadlock the owner's blocking
    ``put`` against a full pipeline.
    """

    def __init__(
        self, ex: "StagePipelineExecutor", queue_depth: Optional[int] = None
    ):
        self.ex = ex
        K = len(ex.plan.stages)
        depth = ex.queue_depth if queue_depth is None else queue_depth
        self.qs: List["queue.Queue"] = [
            queue.Queue(maxsize=depth) for _ in range(K)
        ]
        self.qs.append(queue.Queue())          # unbounded drain
        self.traces = [
            StageTrace(stage=k, pu=s.pu.name)
            for k, s in enumerate(ex.plan.stages)
        ]
        self.errors: List[BaseException] = []
        self._frames_in = 0
        self._done_t: Dict[int, float] = {}
        self._wall = 0.0
        self._closed = False
        with ex._active_lock:
            ex._active = 0
            ex._max_active = 0
            ex._live_cores.clear()
        self.threads = [
            threading.Thread(
                target=ex._stage_loop,
                args=(k, self.qs[k], self.qs[k + 1], self.traces[k],
                      self.errors),
                name=f"stage-{k}", daemon=True,
            )
            for k in range(K)
        ]
        self._t0 = time.perf_counter()
        for t in self.threads:
            t.start()

    @property
    def frames_in(self) -> int:
        return self._frames_in

    def put(
        self,
        payload: Any,
        *,
        ready_t: float = 0.0,
        scale: float = 1.0,
        round_id: Optional[int] = None,
    ) -> int:
        """Inject one frame into stage 0; returns its frame index.

        ``ready_t`` is the virtual time the payload becomes available
        (the drain time of the frame it depends on); ``round_id``
        defaults to the frame index (every frame streams its own tiles).
        Blocks on the bounded stage-0 queue for backpressure."""
        if self._closed:
            raise ValueError("session is closed")
        if self.errors:
            raise self.errors[0]
        f = self._frames_in
        rid = f if round_id is None else round_id
        self.qs[0].put((f, payload, float(ready_t), float(scale), rid))
        # lint: disable=RPL004 -- owner-thread only by contract (put/get/close share one caller thread)
        self._frames_in += 1
        return f

    def get(self, timeout: float = 300.0):
        """Block until the next frame drains; returns
        ``(frame, payload, end_t)`` with ``end_t`` the virtual drain
        time.  Frames drain in injection order (FIFO handoffs)."""
        try:
            item = self.qs[-1].get(timeout=timeout)
        except queue.Empty:
            self._stall_unwind()   # raises
        if item is _FAILED or item is None:
            err = self.errors[0] if self.errors else RuntimeError(
                "pipeline closed while frames were in flight"
            )
            raise err
        frame, payload, end_t, _scale, _rid = item
        # lint: disable=RPL004 -- owner-thread only by contract (put/get/close share one caller thread)
        self._done_t[frame] = end_t
        return frame, payload, end_t

    def _stall_unwind(self):
        """Mirror of ``run``'s deadlock-as-detection recovery: flag the
        error so stages drain, abort in-flight cores, flush the drain
        queue, join, and raise with a diagnosis."""
        err = RuntimeError(
            "pipeline stalled: no frame completed in time "
            f"(drained {len(self._done_t)}/{self._frames_in}; a stage "
            "thread is wedged -- serialized schedule or stuck prefetch)"
        )
        self.errors.append(err)
        with self.ex._active_lock:
            cores = list(self.ex._live_cores.values())
        for c in cores:
            c.abort(err)
        self.qs[0].put(None)
        deadline = time.monotonic() + 60.0
        while time.monotonic() < deadline:
            try:
                if self.qs[-1].get(timeout=5.0) is None:
                    break
            except queue.Empty:
                pass
        for t in self.threads:
            t.join(timeout=5.0)
        # lint: disable=RPL004 -- owner thread, and all stage threads just joined
        self._closed = True
        raise err from None

    def close(self, outputs: Optional[List[Any]] = None) -> PipelineReport:
        """Shut the pipeline down and build the report.  Raises the
        first stage error if any frame failed."""
        if not self._closed:
            # lint: disable=RPL004 -- owner-thread only by contract; stages only read via queue sentinels
            self._closed = True
            self.qs[0].put(None)
            deadline = time.monotonic() + 120.0
            while time.monotonic() < deadline:
                try:
                    item = self.qs[-1].get(timeout=5.0)
                except queue.Empty:
                    continue
                if item is None:
                    break
                if item is _FAILED:
                    continue
                frame, _payload, end_t, _scale, _rid = item
                # lint: disable=RPL004 -- owner thread draining after the close sentinel
                self._done_t[frame] = end_t   # owner never collected it
            for t in self.threads:
                t.join(timeout=60.0)
            # lint: disable=RPL004 -- owner thread, stage threads joined above
            self._wall = time.perf_counter() - self._t0
        if self.errors:
            raise self.errors[0]
        n = self._frames_in
        done_t = [self._done_t.get(f, 0.0) for f in range(n)]
        outs = outputs if outputs is not None else [None] * n
        return self.ex._report(outs, done_t, self.traces, wall_s=self._wall)

    def abort(self) -> None:
        """Best-effort close that never raises (error-path cleanup)."""
        try:
            self.close()
        except BaseException:
            pass


class StagePipelineExecutor:
    """Run all K stages of a :class:`PartitionedPlan` concurrently.

    ``fetch(stage, tile_index, tile_name)`` supplies a tile's weights
    (called from that stage's prefetch worker, in plan issue order);
    ``run_tile(stage, tile_index, weights, carry)`` folds one tile into
    the stage's running activation state.  The carry entering a stage is
    the payload handed off by the previous stage (the microbatch payload
    for stage 0) and the carry after the stage's last tile is handed
    downstream.
    """

    def __init__(
        self,
        plan: PartitionedPlan,
        *,
        fetch: Optional[FetchFn] = None,
        run_tile: Optional[RunTileFn] = None,
        run_stage: Optional[RunStageFn] = None,
        stage_meshes: Optional[Sequence[Any]] = None,
        queue_depth: int = 2,
        record_fetch_orders: bool = False,
    ):
        if not plan.stages:
            raise ValueError("empty PartitionedPlan")
        if not plan.feasible:
            raise ValueError("infeasible PartitionedPlan (a stage plan "
                             "exceeds its PU's fast memory)")
        self.plan = plan
        self.fetch = fetch or (lambda k, i, name: name)
        self.run_tile = run_tile or (lambda k, i, w, carry: carry)
        # run_stage carries the *real* per-frame compute: the handoff
        # queues then move live activation tensors between stages while
        # the tile loop keeps the streaming account (the virtual clock
        # stays the cross-check against the analytic recurrence)
        self.run_stage = run_stage
        # one mesh per stage: payloads are device_put onto the consuming
        # stage's submesh at handoff (None skips placement -- CPU bench)
        self.stage_meshes = list(stage_meshes) if stage_meshes else None
        if self.stage_meshes is not None and len(self.stage_meshes) != len(
            plan.stages
        ):
            raise ValueError(
                f"stage_meshes has {len(self.stage_meshes)} entries for "
                f"{len(plan.stages)} stages"
            )
        self.queue_depth = queue_depth
        self.record_fetch_orders = record_fetch_orders
        # under REPRO_SANITIZE=1 the lock feeds the lock-order recorder
        # (class-level name, like StageStreamCore._cond)
        self._active_lock = sanitize.instrument_lock(
            "StagePipelineExecutor._active_lock"
        )
        self._active = 0
        self._max_active = 0
        self._live_cores: Dict[int, StageStreamCore] = {}

    def _enter_frame(self) -> None:
        with self._active_lock:
            sanitize.require_held(self._active_lock)
            self._active += 1
            self._max_active = max(self._max_active, self._active)

    def _exit_frame(self) -> None:
        with self._active_lock:
            self._active -= 1

    # -- per-stage workers --------------------------------------------------

    def _prefetch_loop(self, jobs: "queue.Queue") -> None:
        """One stage's prefetch worker: drain cores in frame order."""
        while True:
            core = jobs.get()
            if core is None:
                return
            try:
                core.prefetch_all()
            except BaseException as e:   # surfaced via core.acquire
                core.abort(e)

    def _stage_loop(
        self,
        k: int,
        in_q: "queue.Queue",
        out_q: "queue.Queue",
        trace: StageTrace,
        errors: List[BaseException],
    ) -> None:
        stage = self.plan.stages[k]
        costs = [t.mem_bytes for t in stage.plan.tiles]
        issue = stage.plan.issue_order()
        names = _stage_tile_names(k, stage)
        per_frame_stall = stage.plan.total_stall

        jobs: "queue.Queue" = queue.Queue()
        worker = threading.Thread(
            target=self._prefetch_loop, args=(jobs,),
            name=f"prefetch-s{k}", daemon=True,
        )
        worker.start()
        t_cursor = 0.0
        last_round = None
        while True:
            item = in_q.get()
            if item is None:
                break
            if item is _FAILED:
                out_q.put(_FAILED)   # propagate so a blocked get() wakes
                continue
            if errors:
                continue    # some stage failed: drain upstream, don't work
            frame, payload, ready_t, scale, round_id = item
            if k == 0 and self.stage_meshes is not None:
                payload = _place_on_mesh(payload, self.stage_meshes[0])
            self._enter_frame()
            # inbound handoff: the activation transfer overlaps the
            # previous frame's compute (DMA), so it delays *arrival*,
            # not the stage clock.
            arrival = ready_t + (stage.handoff_in_s * scale if k else 0.0)
            start = max(t_cursor, arrival)
            if trace.frames == 0:
                trace.first_start_t = start
            else:
                trace.starve_s += max(0.0, arrival - t_cursor)

            # the weight-streaming tile loop runs once per *round*: lane
            # groups of the same round reuse the weights the first group
            # streamed in, so only that group pays the fetch sequence
            # (frames injected via run() carry round_id == frame, which
            # keeps the legacy one-tile-loop-per-frame behaviour)
            stream_tiles = round_id != last_round
            core = None
            carry = payload
            try:
                if stream_tiles:
                    core = StageStreamCore(
                        costs=costs,
                        capacity=stage.pu.fast_mem_bytes,
                        issue_order=issue,
                        fetch=lambda j: self.fetch(k, j, names[j]),
                        names=names,
                    )
                    with self._active_lock:
                        self._live_cores[k] = core  # stall recovery aborts
                    jobs.put(core)
                    for i in range(len(costs)):
                        w = core.acquire(i)
                        carry = self.run_tile(k, i, w, carry)
                        core.release(i)
                if self.run_stage is not None:
                    # the real per-frame compute: fold the stage's layer
                    # slice over the inbound activations
                    carry = self.run_stage(k, carry)
                if self.stage_meshes is not None and k + 1 < len(
                    self.plan.stages
                ):
                    # hand the activations to the next stage's submesh
                    carry = _place_on_mesh(carry, self.stage_meshes[k + 1])
            except BaseException as e:
                if core is not None:
                    core.abort(e)   # unblock this stage's prefetch worker
                errors.append(e)
                self._exit_frame()
                out_q.put(_FAILED)
                continue
            last_round = round_id

            end = start + stage.stage_s * scale
            t_cursor = end
            trace.frames += 1
            if core is not None:
                trace.fetches += len(core.fetches)
                trace.peak_resident_bytes = max(
                    trace.peak_resident_bytes, core.peak_resident_bytes
                )
                if self.record_fetch_orders:
                    trace.fetch_orders.append(list(core.fetches))
            trace.busy_s += stage.stage_s * scale
            trace.stall_s += per_frame_stall * scale
            trace.handoff_s += stage.handoff_in_s * scale if k else 0.0
            trace.last_end_t = end
            self._exit_frame()
            out_q.put((frame, carry, end, scale, round_id))
        jobs.put(None)
        worker.join(timeout=60.0)
        out_q.put(None)

    # -- the run ------------------------------------------------------------

    def open_session(
        self, queue_depth: Optional[int] = None
    ) -> PipelineSession:
        """Open a persistent :class:`PipelineSession` over this plan --
        the overlapped staged-decode entry point (frames injected as
        their cross-round dependencies drain)."""
        return PipelineSession(self, queue_depth=queue_depth)

    def run(self, microbatches: Sequence[Any]) -> PipelineReport:
        M = len(microbatches)
        if M == 0:
            traces = [
                StageTrace(stage=k, pu=s.pu.name)
                for k, s in enumerate(self.plan.stages)
            ]
            return self._report([], [], traces, wall_s=0.0)

        session = PipelineSession(self)

        def inject():
            # all microbatches are available at t=0; the bounded stage-0
            # queue paces actual injection to the pipeline's intake rate
            try:
                for payload in microbatches:
                    session.put(payload)
            except BaseException:
                pass          # a stage failed: the drain loop raises it

        injector = threading.Thread(target=inject, name="inject", daemon=True)
        injector.start()

        outputs: List[Any] = [None] * M
        try:
            for _ in range(M):
                # generous bound: a healthy pipeline delivers frames
                # continuously; hitting it means a stage wedged (the
                # deadlock-as-detection failure mode) -- fail fast with
                # a diagnosis instead of hanging the CI job
                frame, payload, _end_t = session.get(timeout=300.0)
                outputs[frame] = payload
        except BaseException:
            injector.join(timeout=60.0)
            session.abort()
            raise
        injector.join(timeout=60.0)
        return session.close(outputs=outputs)

    def _report(
        self,
        outputs: List[Any],
        done_t: List[float],
        traces: List[StageTrace],
        *,
        wall_s: float,
    ) -> PipelineReport:
        K = len(self.plan.stages)
        M = len(outputs)
        makespan = max(done_t) if done_t else 0.0
        # handoff is overlapped DMA (it delays arrival, never the stage
        # clock), so it is NOT stage occupancy -- counting it as busy
        # would deflate (even negate) the bubble on handoff-heavy plans
        busy = sum(t.busy_s for t in traces)
        bubble = 1.0 - busy / (K * makespan) if makespan > 0 else 0.0
        return PipelineReport(
            n_stages=K,
            n_microbatches=M,
            outputs=outputs,
            frame_done_t=done_t,
            makespan_s=makespan,
            measured_fps=M / makespan if makespan > 0 else 0.0,
            predicted_makespan_s=(
                self.plan.pipeline_makespan(M) if M else 0.0
            ),
            predicted_fps=self.plan.pipeline_fps(M) if M else 0.0,
            steady_fps=self.plan.fps,
            bubble_measured=bubble,
            bubble_predicted=self.plan.bubble_prediction(M) if M else 0.0,
            wall_s=wall_s,
            max_concurrent_stages=self._max_active if M else 0,
            stages=traces,
            real_stage_compute=self.run_stage is not None,
        )


def execute_partitioned_plan(
    plan: PartitionedPlan,
    n_microbatches: int = 4,
    *,
    fetch: Optional[FetchFn] = None,
    run_tile: Optional[RunTileFn] = None,
    payloads: Optional[Sequence[Any]] = None,
    queue_depth: int = 2,
    record_fetch_orders: bool = False,
) -> PipelineReport:
    """Convenience wrapper: execute ``plan`` over M microbatches.

    With the default (functional no-op) ``fetch``/``run_tile`` this
    validates the *runtime* -- issue order, residency bounds, handoff
    flow, pipeline dynamics -- which is what FleetSim's executed mode
    and the ``stream`` benchmark suite need; callers with real weights
    and compute supply both callbacks.
    """
    ex = StagePipelineExecutor(
        plan,
        fetch=fetch,
        run_tile=run_tile,
        queue_depth=queue_depth,
        record_fetch_orders=record_fetch_orders,
    )
    if payloads is None:
        payloads = list(range(n_microbatches))
    return ex.run(list(payloads))
