"""Cycle-approximate PU pipeline simulator (paper SS IV--V).

Models one PU's full per-layer pipeline -- activation fetch from HBM,
systolic-array compute, post-processing, write-back -- to reproduce the
paper's measurements: Fig. 5(a) per-layer ResNet-50 latencies, Table I
FPS / FPS-per-TOPS, and the scheduler's stall behaviour (Fig. 5(b,c)).

Latency model per layer (GEMM of weight N x M against acts M x P):
  compute  = ceil(N/R_SA) * P * ceil(M/C_SA) / f_fast      (SS II-B rounds)
  act_in   = M * P bytes / act_bw     (int8, streamed once per N-round reuse
             from the ping-pong buffer; reused ceil(N/R_SA) times on-chip)
  act_out  = N * P / act_bw
  latency ~= max(compute, act_in, act_out) + pipeline fill
The paper reports near-optimal per-layer efficiency when the WRB read rate
exceeds the SA write rate (R_g >= R_SA / ceil(M/C_SA)); we surface that
check per layer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, List, Literal, Optional, Sequence, Tuple

from repro.core.pu import PUConfig, TileCost
from repro.core import scheduler as sched

if TYPE_CHECKING:  # repro.plan imports core.pu: keep the cycle lazy
    from repro.plan import ExecutionPlan, PartitionedPlan


@dataclasses.dataclass(frozen=True)
class GemmLayer:
    """One GEMM-ified layer: weights (n x m) applied to (m x p) acts."""

    name: str
    n: int          # output channels / rows of the weight matrix
    m: int          # reduction dim (k*k*C_in for conv)
    p: int          # activation columns (OH*OW for conv, tokens for FC)
    residual: bool = False   # fused residual addition (ResNet shortcut)

    @property
    def macs(self) -> int:
        return self.n * self.m * self.p

    @property
    def weight_bytes(self) -> int:
        return self.n * self.m   # int8


@dataclasses.dataclass
class LayerSim:
    layer: GemmLayer
    compute_s: float
    act_in_s: float
    act_out_s: float
    latency_s: float
    wrb_rate_ok: bool


@dataclasses.dataclass
class ModelSim:
    layers: List[LayerSim]
    pu: PUConfig
    schedule: sched.TwoPhaseResult
    frame_s_resident: float       # all weights on-chip (Fig. 5a conditions)
    frame_s_scheduled: float      # with two-phase weight streaming stalls
    plan: Optional["ExecutionPlan"] = None   # underlying repro.plan IR

    @property
    def fps_resident(self) -> float:
        return 1.0 / self.frame_s_resident

    @property
    def fps_scheduled(self) -> float:
        return 1.0 / self.frame_s_scheduled

    @property
    def total_macs(self) -> int:
        return sum(l.layer.macs for l in self.layers)

    @property
    def efficiency(self) -> float:
        """Measured/available TOPS in the SA (the paper reports up to 98%)."""
        ideal = 2.0 * self.total_macs / self.pu.peak_ops_per_s
        return ideal / self.frame_s_scheduled


def simulate_layer(pu: PUConfig, layer: GemmLayer, r_g: int = 8) -> LayerSim:
    rounds = math.ceil(layer.n / pu.r_sa)
    waves_per_round = layer.p
    cycles_per_wave = math.ceil(layer.m / pu.c_sa)
    compute_s = rounds * waves_per_round * cycles_per_wave / pu.fast_clock_hz
    act_in_s = layer.m * layer.p / pu.act_bw_bytes_per_s
    residual_in_s = (layer.n * layer.p / pu.act_bw_bytes_per_s) if layer.residual else 0.0
    act_out_s = layer.n * layer.p / pu.act_bw_bytes_per_s
    # Activations stream in once and are reused on-chip for all N-rounds;
    # I/O overlaps compute via the ping-pong buffers, so steady-state layer
    # latency is the max of the streams plus the SA fill (R_SA + C_SA deep).
    fill_s = (pu.r_sa + pu.c_sa + cycles_per_wave) / pu.fast_clock_hz
    latency_s = max(compute_s, act_in_s + residual_in_s, act_out_s) + fill_s
    # WRB read rate must beat the SA write rate for no back-pressure (SS V):
    wrb_ok = r_g >= pu.r_sa / cycles_per_wave
    return LayerSim(
        layer=layer,
        compute_s=compute_s,
        act_in_s=act_in_s + residual_in_s,
        act_out_s=act_out_s,
        latency_s=latency_s,
        wrb_rate_ok=wrb_ok,
    )


def model_tiles(pu: PUConfig, layers: Sequence[GemmLayer]) -> List[TileCost]:
    """Tile every layer the way the scheduler sees it (R_SA x M_v tiles)."""
    tiles: List[TileCost] = []
    for layer in layers:
        tiles.extend(pu.gemm_tiles(layer.n, layer.m, layer.p))
    return tiles


def simulate_model(
    pu: PUConfig,
    layers: Sequence[GemmLayer],
    r_g: int = 8,
    schedule_mode: Literal["two_phase", "baseline", "resident"] = "two_phase",
) -> ModelSim:
    from repro.plan import plan_cached

    per_layer = [simulate_layer(pu, l, r_g) for l in layers]
    frame_resident = sum(l.latency_s for l in per_layer)

    tiles = model_tiles(pu, layers)
    # single planning path: the content-hashed cache means sweeping the
    # same model across schedule modes (or re-running a benchmark) plans
    # once per (tiles, capacity) pair
    exec_plan = plan_cached(tiles, pu.fast_mem_bytes)
    result = exec_plan.to_two_phase()
    if schedule_mode == "resident":
        stall = 0.0
    elif schedule_mode == "baseline":
        stall = exec_plan.baseline_stall
    else:
        stall = exec_plan.total_stall
    frame_scheduled = frame_resident + stall
    return ModelSim(
        layers=per_layer,
        pu=pu,
        schedule=result,
        frame_s_resident=frame_resident,
        frame_s_scheduled=frame_scheduled,
        plan=exec_plan,
    )


# ----------------------------------------------------------------------
# ResNet GEMM-layer tables (ImageNet 224x224), following the paper's
# evaluation choices: avg-pool executed as a Conv layer ([2]'s approach),
# max-pool fused into post-processing, first conv run as a GEMM with
# host-side IM2COL (patches padded 147 -> 160 bytes for HBM alignment).
# ----------------------------------------------------------------------


def _conv_out(h: int, k: int, s: int, p: int) -> int:
    return (h + 2 * p - k) // s + 1


def resnet_gemm_layers(variant: Literal[18, 50]) -> List[GemmLayer]:
    layers: List[GemmLayer] = []
    h = 224
    # conv1: 7x7/2, 64ch; paper pads host IM2COL patches 147->160 elements.
    h = _conv_out(h, 7, 2, 3)
    layers.append(GemmLayer("conv1", n=64, m=160, p=h * h))
    # max-pool 3x3/2 fused in post-processing (SS V) -- changes spatial only
    h = _conv_out(h, 3, 2, 1)

    if variant == 18:
        stage_blocks = [2, 2, 2, 2]
        stage_ch = [64, 128, 256, 512]
        cin = 64
        for s_i, (blocks, ch) in enumerate(zip(stage_blocks, stage_ch)):
            for b in range(blocks):
                stride = 2 if (s_i > 0 and b == 0) else 1
                h_out = _conv_out(h, 3, stride, 1)
                layers.append(
                    GemmLayer(f"s{s_i}b{b}conv1", n=ch, m=9 * cin, p=h_out * h_out)
                )
                layers.append(
                    GemmLayer(
                        f"s{s_i}b{b}conv2", n=ch, m=9 * ch, p=h_out * h_out,
                        residual=True,
                    )
                )
                if stride != 1 or cin != ch:
                    layers.append(
                        GemmLayer(
                            f"s{s_i}b{b}down", n=ch, m=cin, p=h_out * h_out
                        )
                    )
                cin = ch
                h = h_out
        feat = 512
    else:
        stage_blocks = [3, 4, 6, 3]
        stage_ch = [64, 128, 256, 512]
        cin = 64
        for s_i, (blocks, ch) in enumerate(zip(stage_blocks, stage_ch)):
            for b in range(blocks):
                stride = 2 if (s_i > 0 and b == 0) else 1
                h_out = _conv_out(h, 3, stride, 1)
                layers.append(
                    GemmLayer(f"s{s_i}b{b}conv1", n=ch, m=cin, p=h * h)
                )
                layers.append(
                    GemmLayer(f"s{s_i}b{b}conv2", n=ch, m=9 * ch, p=h_out * h_out)
                )
                layers.append(
                    GemmLayer(
                        f"s{s_i}b{b}conv3", n=4 * ch, m=ch, p=h_out * h_out,
                        residual=True,
                    )
                )
                if stride != 1 or cin != 4 * ch:
                    layers.append(
                        GemmLayer(
                            f"s{s_i}b{b}down", n=4 * ch, m=cin, p=h_out * h_out
                        )
                    )
                cin = 4 * ch
                h = h_out
        feat = 2048
    # avg-pool as conv (7x7 window over 7x7 map -> 1x1), then FC 1000.
    layers.append(GemmLayer("avgpool", n=feat, m=feat * 49 // feat, p=1))
    layers.append(GemmLayer("fc", n=1000, m=feat, p=1))
    return layers


def simulate_partitioned(
    pus: Sequence[PUConfig],
    layers: Sequence[GemmLayer],
    r_g: int = 8,
) -> "PartitionedPlan":
    """Split one model across K PUs as a pipeline (repro.plan.partition).

    Contiguous layer ranges are balanced on the simulator's per-layer
    latency under each stage's own cost model, then each stage runs its
    own two-phase weight-transfer schedule against its own URAM capacity
    and load channel.  Steady-state FPS is set by the bottleneck stage --
    genuine single-stream scaling, in contrast to ``FleetSim``'s
    frame-per-PU additivity.
    """
    from repro.plan import partition as _partition

    return _partition.partition_layers(
        list(layers),
        list(pus),
        latency_s=lambda pu, l: simulate_layer(pu, l, r_g).latency_s,
        tiles_of=lambda pu, l: pu.gemm_tiles(l.n, l.m, l.p),
        name_of=lambda l: l.name,
        act_bytes_of=lambda l: l.m * l.p,
    )


@dataclasses.dataclass
class FleetSim:
    """Multi-PU throughput: replicated frames and/or partitioned pipelines.

    ``sims`` is the paper's SS V evaluation mode: each PU processes one
    frame independently over its own HBM channels, so FPS is additive.
    ``pipelines`` is the replacement API for single-stream scaling: one
    model partitioned across several PU profiles (see
    :func:`simulate_partitioned`); each pipeline contributes its
    bottleneck-stage frame rate.
    """

    sims: List[Tuple[str, ModelSim, int]] = dataclasses.field(
        default_factory=list
    )  # (pu name, sim, count)
    pipelines: List[Tuple[str, "PartitionedPlan", int]] = dataclasses.field(
        default_factory=list
    )  # (name, partitioned plan, count)

    @property
    def fps(self) -> float:
        return sum(c * s.fps_scheduled for _, s, c in self.sims) + sum(
            c * p.fps for _, p, c in self.pipelines
        )

    @property
    def tops(self) -> float:
        t = sum(c * s.pu.peak_ops_per_s for _, s, c in self.sims) / 1e12
        return t + sum(c * p.tops for _, p, c in self.pipelines)

    @property
    def fps_per_tops(self) -> float:
        return self.fps / self.tops

    def execute_pipelines(self, n_microbatches: int = 4) -> dict:
        """Executed mode: validate the analytic pipeline numbers against
        the real stage-parallel runtime.

        Each partitioned pipeline is run through
        ``runtime.pipeline_exec.StagePipelineExecutor`` (stage threads,
        prefetch workers, bounded handoff queues) and the measured
        throughput/bubble is reported next to the analytic prediction.
        ``measured_vs_analytic`` below 1.0 is the pipeline-fill cost the
        additive model ignores; a large gap flags a runtime/cost-model
        divergence.
        """
        from repro.runtime.pipeline_exec import execute_partitioned_plan

        out = {}
        for name, pplan, count in self.pipelines:
            rep = execute_partitioned_plan(
                pplan, n_microbatches=n_microbatches
            )
            out[name] = {
                "count": count,
                "analytic_fps": pplan.fps,
                "predicted_fps": rep.predicted_fps,
                "measured_fps": rep.measured_fps,
                "measured_vs_analytic": rep.measured_fps / pplan.fps,
                "bubble_measured": rep.bubble_measured,
                "bubble_predicted": rep.bubble_predicted,
                "wall_s": rep.wall_s,
            }
        return out
