"""Core library: the paper's contribution as composable JAX modules.

- quant:      INT8 + power-of-two scaling (the PU's arithmetic)
- pu:         processing-unit cost model (FPGA PU_1x/PU_2x and TPU profiles)
- scheduler:  two-phase weight-transfer scheduling heuristic (SS III)
- streaming:  scheduler -> executable prefetch plans for real models
- simulator:  cycle-approximate PU pipeline (reproduces Fig. 5 / Table I)
- wrb:        wave-reorder-buffer model (SS II-A claim quantification)
- aimc:       AIMC noise-injection unit (SS VI)
"""
from repro.core.pu import PUConfig, TileCost, PU_1X, PU_2X, tpu_v5e_config, host_offload_config
from repro.core.quant import QTensor, quantize, dequantize, fake_quant
from repro.core.scheduler import (
    Schedule,
    TwoPhaseResult,
    adaptive_schedule,
    baseline_schedule,
    reference_adaptive_schedule,
    reference_two_phase,
    simulate,
    two_phase,
)
from repro.core.streaming import (
    StreamingExecutor,
    StreamingPlan,
    WeightTile,
    gemm_sequence_tiles,
    plan_streaming,
)

__all__ = [
    "PUConfig", "TileCost", "PU_1X", "PU_2X", "tpu_v5e_config",
    "host_offload_config", "QTensor", "quantize", "dequantize", "fake_quant",
    "Schedule", "TwoPhaseResult", "adaptive_schedule", "baseline_schedule",
    "reference_adaptive_schedule", "reference_two_phase",
    "simulate", "two_phase", "StreamingExecutor", "StreamingPlan",
    "WeightTile", "gemm_sequence_tiles", "plan_streaming",
]
