"""Processing-unit cost model.

The paper's PU (SS II, SS IV) is parameterized by the systolic array shape
(R_SA x C_SA), its clock, the URAM capacity available for weights, and the
HBM link feeding it.  The weight-transfer scheduler (SS III) only needs three
quantities per tile: load time, execution time, and fast-memory usage --
all derived here.

The same cost model is reused, with different constants, for the TPU-v5e
adaptation (VMEM or HBM as the "URAM", the MXU as the "systolic array"), so
the scheduler is memory-hierarchy-agnostic.  See DESIGN.md SS2.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class PUConfig:
    """Cost-model parameters of one processing unit.

    Defaults model the paper's PU_2x on the Alveo U50.
    """

    name: str = "pu2x"
    r_sa: int = 64                  # systolic array rows (PEs)
    c_sa: int = 8                   # systolic array columns (dot-product width)
    fast_clock_hz: float = 600e6    # SA + on-chip memory clock (SS IV)
    # Weight fast-memory capacity in bytes.  One URAM column = 64 blocks
    # x 288 Kb = 2.25 MiB usable for weights (8-bit payload of the 72-bit
    # word; the spare byte holds biases, SS II-A).
    fast_mem_bytes: int = 64 * 4096 * 8   # 64 URAMs x 4096 entries x 8 B
    # Sustained HBM->URAM weight bandwidth: 128 bit @ 600 MHz (SS IV).
    weight_bw_bytes_per_s: float = 16 * 600e6
    # Activation stream bandwidth: 256 bit AXI @ 300 MHz (SS IV).
    act_bw_bytes_per_s: float = 32 * 300e6

    @property
    def macs_per_cycle(self) -> int:
        return self.r_sa * self.c_sa

    @property
    def peak_ops_per_s(self) -> float:
        # 2 ops (mul+add) per MAC per fast-clock cycle.
        return 2.0 * self.macs_per_cycle * self.fast_clock_hz

    # ---- tile-level quantities used by the scheduler -------------------

    def tile_bytes(self, m: int, rows: int | None = None) -> int:
        """Fast-memory bytes used by an (rows x m) int8 weight tile.

        Weight storage is allocated in URAM *entries* of C_SA elements
        across R_SA parallel blocks (SS II-B): a tile occupies
        ceil(m / c_sa) entries per R_SA row-block.  The paper's tiles are
        exactly R_SA rows; LM-scale tiles (whole weight matrices under the
        TPU profiles) span ceil(rows / r_sa) row-blocks.
        """
        rows = self.r_sa if rows is None else rows
        entries = math.ceil(m / self.c_sa)
        row_blocks = max(1, math.ceil(rows / self.r_sa))
        return entries * self.c_sa * self.r_sa * row_blocks  # int8: 1 B/elem

    def load_time(self, m: int, rows: int | None = None) -> float:
        """HBM -> fast-memory transfer time of one weight tile (seconds)."""
        return self.tile_bytes(m, rows) / self.weight_bw_bytes_per_s

    def exec_time(self, m: int, p: int, rows: int | None = None) -> float:
        """Steady-state execution time of one tile against P activation

        columns.  Each MVM wave takes ceil(M/C_SA) fast cycles and the SA
        processes one wave per round (SS II-B): P waves per R_SA row-block
        round.
        """
        rows = self.r_sa if rows is None else rows
        rounds = max(1, math.ceil(rows / self.r_sa))
        waves = p
        cycles_per_wave = math.ceil(m / self.c_sa)
        return rounds * waves * cycles_per_wave / self.fast_clock_hz

    def gemm_tiles(self, n: int, m: int, p: int) -> List["TileCost"]:
        """Partition an (N x M) weight matrix GEMM (against M x P acts)

        into the paper's R_SA x M tiles and cost each one.
        """
        n_tiles = math.ceil(n / self.r_sa)
        out = []
        for t in range(n_tiles):
            rows = min(self.r_sa, n - t * self.r_sa)
            out.append(
                TileCost(
                    load_s=self.load_time(m, rows),
                    exec_s=self.exec_time(m, p, rows),
                    mem_bytes=self.tile_bytes(m, rows),
                )
            )
        return out


@dataclasses.dataclass(frozen=True)
class TileCost:
    """Scheduler-facing view of one weight tile (paper SS III)."""

    load_s: float
    exec_s: float
    mem_bytes: int


# Paper configurations (SS IV): both use one URAM column (64 blocks), R_g=8.
PU_2X = PUConfig(name="pu2x", r_sa=64, c_sa=8)
PU_1X = PUConfig(
    name="pu1x",
    r_sa=64,
    c_sa=4,
    # PU_1x splits each URAM into two sub-regions matching the 32-bit weight
    # read path; capacity seen by the scheduler is unchanged, load path is
    # the same stream-width-adapted 128b @ 600MHz.
)


def tpu_v5e_config(
    fast_mem_bytes: int = 96 * 1024 * 1024,   # VMEM budget reserved for weights
    hbm_bw: float = 819e9,
    peak_flops: float = 197e12 * 2 / 2,       # bf16 MACs/s equivalent
) -> PUConfig:
    """The TPU adaptation: VMEM plays URAM, HBM feeds it, MXU is the SA.

    The scheduler consumes only (load_s, exec_s, mem_bytes), so expressing a
    v5e core in the same dataclass lets the identical two-phase heuristic
    plan HBM->VMEM weight streaming.  We encode the MXU as a 128x128 "SA" at
    a virtual clock chosen so peak_ops matches the chip.
    """
    r, c = 128, 128
    clock = peak_flops / (2.0 * r * c)
    return PUConfig(
        name="tpu_v5e",
        r_sa=r,
        c_sa=c,
        fast_clock_hz=clock,
        fast_mem_bytes=fast_mem_bytes,
        weight_bw_bytes_per_s=hbm_bw,
        act_bw_bytes_per_s=hbm_bw,
    )


def host_offload_config(
    hbm_bytes: int = 16 * 1024**3,
    pcie_bw: float = 32e9,            # host->device interconnect
    peak_flops: float = 197e12,
) -> PUConfig:
    """Second-level streaming: device HBM plays URAM, host memory plays HBM.

    This is the generalization the paper points at in SS V ("naturally
    supports larger models by dynamically allocating weights"): models whose
    weights exceed device HBM stream layer tiles host->device, scheduled by
    the same heuristic.
    """
    r, c = 128, 128
    clock = peak_flops / (2.0 * r * c)
    return PUConfig(
        name="tpu_v5e_host_offload",
        r_sa=r,
        c_sa=c,
        fast_clock_hz=clock,
        fast_mem_bytes=hbm_bytes,
        weight_bw_bytes_per_s=pcie_bw,
        act_bw_bytes_per_s=pcie_bw,
    )
