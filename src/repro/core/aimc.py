"""AIMC device-noise emulation (paper SS VI).

The paper extends the accelerator with a Noise Injection Unit (NIU): each
inference round, the NIU reads the *noiseless* weights of AIMC-emulated
tiles from a pristine HBM region, injects fresh device-noise instances, and
overwrites the weight regions the PU consumes -- so every round sees new
noise, capturing device-level variation (PCM-style models per [17], [18]).

TPU adaptation: the NIU is a pure JAX transform applied to the quantized
weight pytree before each inference round, integrated as a hook of the
serving engine (`runtime/serving.py`).  The "pristine region" is simply the
original params pytree; per-round refresh is a jitted function of
(params, rng).  The noise model follows the IBM aihwkit convention used by
the paper's references: programming noise + read noise on the conductance
scale, and temporal conductance drift.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, quantize


@dataclasses.dataclass(frozen=True)
class AIMCNoiseModel:
    """PCM-like noise parameters (relative to the max programmed weight).

    prog_noise_scale: std of programming error, proportional to |w| with a
        floor -- sigma = scale * (0.25*|w| + 0.05*w_max)  (shape follows
        aihwkit's PCM-like model in spirit).
    read_noise_scale: std of per-read (per-inference) noise.
    drift_nu: conductance drift exponent; weights decay as (t/t0)^-nu.
    t_read: seconds since programming at which inference happens.
    """

    prog_noise_scale: float = 0.1
    read_noise_scale: float = 0.02
    drift_nu: float = 0.06
    t_read: float = 3600.0
    t0: float = 20.0

    def enabled(self) -> bool:
        return (
            self.prog_noise_scale > 0
            or self.read_noise_scale > 0
            or self.drift_nu > 0
        )


def inject_noise_float(
    w: jax.Array, key: jax.Array, model: AIMCNoiseModel
) -> jax.Array:
    """One fresh noise instance on a float weight tensor."""
    w_max = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12)
    k_prog, k_read = jax.random.split(key)
    sigma_prog = model.prog_noise_scale * (0.25 * jnp.abs(w) + 0.05 * w_max)
    w_noisy = w + sigma_prog * jax.random.normal(k_prog, w.shape, w.dtype)
    if model.drift_nu > 0:
        drift = (model.t_read / model.t0) ** (-model.drift_nu)
        w_noisy = w_noisy * drift
    if model.read_noise_scale > 0:
        sigma_read = model.read_noise_scale * w_max
        w_noisy = w_noisy + sigma_read * jax.random.normal(k_read, w.shape, w.dtype)
    return w_noisy


def _is_weight_leaf(path: tuple) -> bool:
    # AIMC emulation targets GEMM weight matrices; biases/norms stay digital
    # (the paper's NIU rewrites URAM *weight* regions, biases are static).
    # Embedding tables count: tied embeddings serve as the unembed GEMM.
    leaf_name = str(path[-1]).lower() if path else ""
    return any(s in leaf_name for s in ("w", "kernel", "embed"))


class NoiseInjectionUnit:
    """The NIU: refresh a params pytree with fresh AIMC noise each round.

    ``pristine`` is never mutated (the separate HBM region of SS VI); each
    :meth:`refresh` returns a new noisy pytree for the PU to consume.
    Quantized leaves (QTensor) are dequantized, perturbed, and requantized
    onto the same power-of-two grid -- matching the read-modify-write loop
    of the hardware NIU.
    """

    def __init__(
        self,
        pristine: Any,
        model: AIMCNoiseModel,
        target_filter=None,
    ):
        self.pristine = pristine
        self.model = model
        self.target_filter = target_filter or (lambda path, leaf: _is_weight_leaf(path))
        self._refresh = jax.jit(self._refresh_impl)

    def _refresh_impl(self, key: jax.Array) -> Any:
        leaves_with_paths = jax.tree_util.tree_leaves_with_path(
            self.pristine, is_leaf=lambda x: isinstance(x, QTensor)
        )
        keys = jax.random.split(key, max(1, len(leaves_with_paths)))
        flat = []
        for (path, leaf), k in zip(leaves_with_paths, keys):
            if not self.target_filter(path, leaf):
                flat.append(leaf)
            elif isinstance(leaf, QTensor):
                noisy = inject_noise_float(leaf.dequantize(), k, self.model)
                flat.append(quantize(noisy, exp=leaf.exp))
            elif hasattr(leaf, "ndim") and leaf.ndim >= 2:
                flat.append(inject_noise_float(leaf, k, self.model))
            else:
                flat.append(leaf)
        treedef = jax.tree_util.tree_structure(
            self.pristine, is_leaf=lambda x: isinstance(x, QTensor)
        )
        return jax.tree_util.tree_unflatten(treedef, flat)

    def refresh(self, key: jax.Array) -> Any:
        """New noisy weights for one inference round."""
        return self._refresh(key)


def snr_db(clean: jax.Array, noisy: jax.Array) -> jax.Array:
    """Signal-to-noise ratio of a noisy weight tensor, in dB."""
    sig = jnp.sum(clean.astype(jnp.float32) ** 2)
    err = jnp.sum((noisy.astype(jnp.float32) - clean.astype(jnp.float32)) ** 2)
    return 10.0 * jnp.log10(sig / jnp.maximum(err, 1e-30))
