"""INT8 quantization with power-of-two scaling factors.

The paper (SS V) evaluates ResNet models "using 8-bit quantization with
power-of-two scaling factors for activations, weights, and biases".  A
power-of-two scale turns dequantization into a bit shift, which is what the
PU's scale/shift module does after the systolic array (Fig. 2(b)).

We reproduce that scheme exactly:

    q = clip(round(x / 2**e), -128, 127)        with integer exponent e
    x_hat = q * 2**e

A GEMM  Y = W X + b  in this scheme runs as

    acc_i32 = W_q X_q + b_q                     (int8 x int8 -> int32)
    Y_q     = shift_round(acc_i32, s)           (s = e_w + e_x - e_y)

which is exactly the datapath of the systolic array + scale/shift module.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

INT8_MIN = -128
INT8_MAX = 127


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """An int8 tensor with a power-of-two scale: value = q * 2**exp.

    ``exp`` is a per-tensor (scalar) integer exponent, as in the paper where
    the scale/shift module applies a single shift per layer output.
    """

    q: jax.Array          # int8 payload
    exp: jax.Array        # int32 scalar exponent e: value = q * 2**e

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * jnp.exp2(self.exp.astype(jnp.float32))

    @property
    def shape(self):
        return self.q.shape

    @property
    def dtype(self):
        return self.q.dtype

    def tree_flatten(self):
        return (self.q, self.exp), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, exp = children
        return cls(q=q, exp=exp)


def pow2_exponent(x: jax.Array) -> jax.Array:
    """Smallest integer e such that max|x| / 2**e fits int8 range."""
    amax = jnp.max(jnp.abs(x))
    amax = jnp.maximum(amax, 1e-30)
    # We need amax / 2**e <= 127  =>  e >= log2(amax/127)
    e = jnp.ceil(jnp.log2(amax / float(INT8_MAX)))
    return e.astype(jnp.int32)


def quantize(x: jax.Array, exp: Optional[jax.Array] = None) -> QTensor:
    """Quantize a float tensor to int8 with a power-of-two scale."""
    if exp is None:
        exp = pow2_exponent(x)
    scale = jnp.exp2(exp.astype(jnp.float32))
    q = jnp.clip(jnp.round(x / scale), INT8_MIN, INT8_MAX).astype(jnp.int8)
    return QTensor(q=q, exp=exp)


def dequantize(t: QTensor) -> jax.Array:
    return t.dequantize()


def shift_round(acc: jax.Array, shift: jax.Array) -> jax.Array:
    """Arithmetic right shift with round-half-away-from-zero, as a

    power-of-two rescale of an int32 accumulator.  ``shift`` >= 0 shifts
    right (divides by 2**shift); negative shifts multiply.
    """
    shift = jnp.asarray(shift, jnp.int32)

    def right(acc):
        # round(x / 2**s) for x int32: add half-ulp of the target grid.
        half = jnp.where(shift > 0, (1 << jnp.maximum(shift - 1, 0)), 0)
        pos = (acc + half) >> jnp.maximum(shift, 0)
        neg = -((-acc + half) >> jnp.maximum(shift, 0))
        return jnp.where(acc >= 0, pos, neg)

    def left(acc):
        return acc << jnp.maximum(-shift, 0)

    return jnp.where(shift >= 0, right(acc), left(acc)).astype(jnp.int32)


def requantize_i32(acc: jax.Array, acc_exp: jax.Array, out_exp: jax.Array) -> jax.Array:
    """Rescale an int32 accumulator with exponent ``acc_exp`` onto the output

    grid ``out_exp`` and saturate to int8.  This is the scale/shift module.
    """
    shift = (out_exp - acc_exp).astype(jnp.int32)
    y = shift_round(acc, shift)
    return jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)


def quantized_linear_exponents(w_exp: jax.Array, x_exp: jax.Array) -> jax.Array:
    """Exponent of the int32 accumulator of W_q @ X_q."""
    return (w_exp + x_exp).astype(jnp.int32)


def fake_quant(x: jax.Array) -> jax.Array:
    """Quantize-dequantize roundtrip (for accuracy studies / AIMC baselines)."""
    return quantize(x).dequantize()
