"""Two-phase weight-transfer scheduling (paper SS III, Fig. 4).

A model runs tile-by-tile: tile *i* has weights load time ``l_i`` (off-chip
to fast memory), execution time ``e_i``, and a fast-memory footprint.  The
load channel is serial, executions are strictly in inference order, a tile's
memory is allocated from the moment its load starts and released when its
execution completes, and the sum of live allocations can never exceed the
fast-memory capacity.

Phase 1 (*baseline*): the load of tile *i* is issued during the execution
window of tile *i-1* ("loading the next tile's weights is attempted while
the preceding tile operates").  A tile with ``l_i <= e_{i-1}`` and enough
free memory exhibits zero stall; otherwise the pipeline waits ``l_i -
e_{i-1}`` -- or up to ``l_i`` when memory is the limiting factor.

Phase 2 (*adaptive*): remaining stalls are examined in descending stall
order; each stalled tile's load is tentatively relocated into an earlier
execution window with adequate memory headroom.  Any relocation that
reduces total stall is retained, otherwise reversed, and earlier windows
are examined in turn.

The scheduler is memory-hierarchy agnostic: it only sees ``TileCost``
(load seconds / exec seconds / bytes) plus a capacity, so the same code
plans URAM@FPGA (the paper), VMEM@TPU, and host-offload@TPU schedules
(see ``core/pu.py``).

``two_phase`` / ``adaptive_schedule`` are thin wrappers over the unified
planning subsystem (``repro.plan``, see DESIGN.md SS1): the incremental
planner there is bit-identical but an order of magnitude faster on the
adaptive phase.  ``simulate`` and the ``reference_*`` entry points keep
the original full-replay implementation as the semantics oracle.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
from typing import List, Optional, Sequence

from repro.core.pu import TileCost


@dataclasses.dataclass
class TileSchedule:
    """Resolved timing of one tile."""

    index: int
    window: int          # load issued during this tile's execution window (-1 = preload)
    load_start: float
    load_end: float
    exec_start: float
    exec_end: float
    stall: float         # wait between previous exec end and this exec start
    mem_bytes: int


@dataclasses.dataclass
class Schedule:
    """A fully resolved schedule plus summary statistics."""

    tiles: List[TileSchedule]
    feasible: bool
    capacity: int

    @property
    def total_stall(self) -> float:
        return sum(t.stall for t in self.tiles)

    @property
    def makespan(self) -> float:
        return self.tiles[-1].exec_end if self.tiles else 0.0

    @property
    def busy_time(self) -> float:
        return sum(t.exec_end - t.exec_start for t in self.tiles)

    @property
    def utilization(self) -> float:
        """Fraction of the makespan the compute array is busy.

        This is the paper's "performance efficiency" (98% reported in SS V).
        """
        ms = self.makespan
        return self.busy_time / ms if ms > 0 else 1.0

    def peak_memory(self) -> int:
        """Peak bytes of simultaneously-resident tiles (for assertions)."""
        events = []
        for t in self.tiles:
            events.append((t.load_start, 1, t.mem_bytes))
            events.append((t.exec_end, 0, -t.mem_bytes))
        # Releases at the same timestamp apply before allocations.
        events.sort(key=lambda e: (e[0], e[1]))
        cur = peak = 0
        for _, _, d in events:
            cur += d
            peak = max(peak, cur)
        return peak

    def memory_trace(self) -> List[tuple]:
        """(time, resident_bytes) samples at every allocation/release edge."""
        stamps = sorted(
            {t.load_start for t in self.tiles} | {t.exec_end for t in self.tiles}
        )
        out = []
        for s in stamps:
            cur = sum(
                t.mem_bytes for t in self.tiles if t.load_start <= s < t.exec_end
            )
            out.append((s, cur))
        return out


_EPS = 1e-12


def simulate(
    tiles: Sequence[TileCost],
    capacity: int,
    windows: Optional[Sequence[int]] = None,
    preload_first: bool = True,
) -> Schedule:
    """Event-driven simulation of a window assignment.

    ``windows[j] = k`` issues tile *j*'s load during tile *k*'s execution
    window (k < j).  ``windows[j] = -1`` (with ``preload_first``) issues the
    load at t=0 before the pipeline starts -- the paper pre-loads the first
    tile "to avoid an initial delay".

    Loads are serialized on one channel in queue order sorted by
    (window, tile).  A load waits for (a) its window to open, (b) the
    channel, and (c) sufficient free memory; memory is released when a
    tile's execution completes.  If a memory wait can only be satisfied by
    the execution of a tile whose own load is queued *behind* the blocked
    load, the assignment deadlocks and is reported infeasible.
    """
    n = len(tiles)
    if n == 0:
        return Schedule(tiles=[], feasible=True, capacity=capacity)
    if windows is None:
        windows = [j - 1 for j in range(n)]
    windows = list(windows)
    if preload_first:
        windows[0] = -1
    for j, w in enumerate(windows):
        if not (-1 <= w < j):
            raise ValueError(f"window[{j}]={w} must be in [-1, {j-1}]")
    if any(t.mem_bytes > capacity for t in tiles):
        return Schedule(tiles=[], feasible=False, capacity=capacity)

    queue = sorted(range(n), key=lambda j: (windows[j], j))

    nan = math.nan
    load_start = [nan] * n
    load_end = [nan] * n
    exec_start = [nan] * n
    exec_end = [nan] * n

    # Allocation edges of issued loads / scheduled execs: (+bytes at
    # load_start, -bytes at exec_end).  Kept as parallel numpy arrays so
    # memory queries are vectorized (the adaptive phase re-simulates many
    # candidate schedules; this is the hot path).
    edge_t = np.empty(2 * n + 8, np.float64)
    edge_d = np.empty(2 * n + 8, np.float64)
    n_edges = 0
    release_edges: List[tuple] = []  # (time, bytes) from scheduled execs

    def add_edge(t: float, d: float):
        nonlocal n_edges
        edge_t[n_edges] = t
        edge_d[n_edges] = d
        n_edges += 1

    def usage_at(t: float) -> float:
        if n_edges == 0:
            return 0.0
        mask = edge_t[:n_edges] <= t
        return float(edge_d[:n_edges][mask].sum())

    def earliest_fit(t0: float, need: int) -> Optional[float]:
        """Earliest t >= t0 where `need` bytes fit, given known releases."""
        if usage_at(t0) + need <= capacity:
            return t0
        for ts, _ in sorted(release_edges):
            if ts <= t0:
                continue
            if usage_at(ts) + need <= capacity:
                return ts
        return None

    channel_free = -math.inf
    prev_exec_end = 0.0
    i_exec = 0
    qpos = 0

    while i_exec < n:
        # Greedily schedule every execution whose weights are loaded: this
        # only adds release information and never delays a load.
        if not math.isnan(load_end[i_exec]):
            exec_start[i_exec] = max(prev_exec_end, load_end[i_exec])
            exec_end[i_exec] = exec_start[i_exec] + tiles[i_exec].exec_s
            prev_exec_end = exec_end[i_exec]
            add_edge(exec_end[i_exec], -tiles[i_exec].mem_bytes)
            release_edges.append((exec_end[i_exec], tiles[i_exec].mem_bytes))
            i_exec += 1
            continue
        if qpos >= n:
            return Schedule(tiles=[], feasible=False, capacity=capacity)
        j = queue[qpos]
        w = windows[j]
        # Pre-loaded tiles (window -1) complete their transfer at t=0: the
        # paper pre-loads the first tile "to avoid an initial delay" (SS V).
        open_t = -tiles[j].load_s if w == -1 else exec_start[w]
        if math.isnan(open_t):
            # Window tile has not executed: its load is behind us in the
            # queue => deadlock.
            return Schedule(tiles=[], feasible=False, capacity=capacity)
        t0 = max(open_t, channel_free)
        t_issue = earliest_fit(t0, tiles[j].mem_bytes)
        if t_issue is None:
            return Schedule(tiles=[], feasible=False, capacity=capacity)
        load_start[j] = t_issue
        load_end[j] = t_issue + tiles[j].load_s
        channel_free = load_end[j]
        add_edge(t_issue, tiles[j].mem_bytes)
        qpos += 1

    out = []
    for i in range(n):
        prev_end = exec_end[i - 1] if i > 0 else 0.0
        out.append(
            TileSchedule(
                index=i,
                window=windows[i],
                load_start=load_start[i],
                load_end=load_end[i],
                exec_start=exec_start[i],
                exec_end=exec_end[i],
                stall=max(0.0, exec_start[i] - prev_end),
                mem_bytes=tiles[i].mem_bytes,
            )
        )
    return Schedule(tiles=out, feasible=True, capacity=capacity)


def baseline_schedule(
    tiles: Sequence[TileCost], capacity: int, preload_first: bool = True
) -> Schedule:
    """Phase 1: prefetch next tile during the current tile's execution."""
    return simulate(tiles, capacity, None, preload_first=preload_first)


def adaptive_schedule(
    tiles: Sequence[TileCost],
    capacity: int,
    preload_first: bool = True,
    baseline: Optional[Schedule] = None,
    exhaustive: bool = False,
    max_window_scan: Optional[int] = None,
    search=None,
) -> Schedule:
    """Phase 2: relocate stalled loads into earlier execution windows.

    Follows the paper: stalled tiles are visited in descending stall order;
    for each, earlier windows are examined nearest-first, considering tiles
    "with processing time e_k and adequate memory space to conceal l_j" --
    i.e. candidate windows must be able to fully hide the load
    (``e_k >= l_j``).  Any relocation that reduces *overall* stall is
    retained, otherwise reversed; the search for a tile stops early once its
    stall is fully hidden.

    ``exhaustive=True`` drops the concealment filter and also tries windows
    that can only partially hide a load (beyond-paper variant; slower,
    occasionally better -- compared in the benchmark harness).
    ``max_window_scan`` bounds candidate windows examined per stalled tile.

    Thin wrapper over the unified planning subsystem (``repro.plan``),
    which evaluates candidates by incremental suffix re-simulation; the
    result is bit-identical to :func:`reference_adaptive_schedule` (the
    original full-replay implementation, kept for verification and the
    scheduler microbenchmark).  A caller-supplied ``baseline`` with a
    non-default window assignment falls back to the reference path.
    """
    if baseline is not None:
        if not baseline.feasible:
            return baseline
        default = [-1] + list(range(len(tiles) - 1))
        if [t.window for t in baseline.tiles] != default:
            return reference_adaptive_schedule(
                tiles, capacity, preload_first, baseline=baseline,
                exhaustive=exhaustive, max_window_scan=max_window_scan,
            )
    from repro import plan as _plan

    result = _plan.plan(
        tiles, capacity, preload_first=preload_first,
        exhaustive=exhaustive, max_window_scan=max_window_scan,
        search=search,
    )
    return result.to_schedule("adaptive")


def reference_adaptive_schedule(
    tiles: Sequence[TileCost],
    capacity: int,
    preload_first: bool = True,
    baseline: Optional[Schedule] = None,
    exhaustive: bool = False,
    max_window_scan: Optional[int] = None,
) -> Schedule:
    """Original O(n^2)-per-candidate adaptive phase (full re-simulation).

    Semantics reference for ``repro.plan``: kept verbatim so the property
    tests and the scheduler microbenchmark can assert the incremental
    planner reproduces it bit-for-bit (same windows, stalls, makespan).
    """
    if baseline is None:
        baseline = baseline_schedule(tiles, capacity, preload_first)
    if not baseline.feasible:
        return baseline

    windows = [t.window for t in baseline.tiles]
    best = baseline

    stalled = sorted(
        (t for t in baseline.tiles if t.stall > _EPS),
        key=lambda t: -t.stall,
    )
    for st in stalled:
        j = st.index
        if windows[j] <= 0:
            continue
        l_j = tiles[j].load_s
        scanned = 0
        for k in range(windows[j] - 1, -1, -1):
            if not exhaustive and tiles[k].exec_s < l_j - _EPS:
                continue  # paper: window k cannot conceal l_j
            if max_window_scan is not None and scanned >= max_window_scan:
                break
            scanned += 1
            trial_windows = list(windows)
            trial_windows[j] = k
            trial = simulate(tiles, capacity, trial_windows, preload_first)
            if trial.feasible and trial.total_stall < best.total_stall - _EPS:
                best = trial
                windows = trial_windows
                if trial.tiles[j].stall <= _EPS:
                    break
    return best


@dataclasses.dataclass
class TwoPhaseResult:
    baseline: Schedule
    adaptive: Schedule

    @property
    def stall_reduction(self) -> float:
        b = self.baseline.total_stall
        if b <= 0:
            return 0.0
        return (b - self.adaptive.total_stall) / b

    def time_ratios(self) -> List[float]:
        """Fig. 5(b): e_i / l_{i+1} -- >1 means full load/exec overlap."""
        ts = self.baseline.tiles
        out = []
        for i in range(len(ts) - 1):
            e_i = ts[i].exec_end - ts[i].exec_start
            l_next = ts[i + 1].load_end - ts[i + 1].load_start
            out.append(e_i / l_next if l_next > 0 else math.inf)
        return out

    def memory_ratios(self) -> List[float]:
        """Fig. 5(c): (mem_i + mem_{i+1}) / capacity -- <=1 means the current

        and next tile fit simultaneously.
        """
        ts = self.baseline.tiles
        cap = self.baseline.capacity
        return [
            (ts[i].mem_bytes + ts[i + 1].mem_bytes) / cap
            for i in range(len(ts) - 1)
        ]


def two_phase(
    tiles: Sequence[TileCost],
    capacity: int,
    preload_first: bool = True,
    exhaustive: bool = False,
    max_window_scan: Optional[int] = None,
    search=None,
) -> TwoPhaseResult:
    """Run both phases and return both schedules (paper Fig. 4).

    Thin wrapper over ``repro.plan`` (single planning path for the
    repo); ``search`` (a ``repro.plan.SearchConfig``) upgrades the
    adaptive phase to beam/annealing search over multi-tile
    reassignments.  See :func:`reference_two_phase` for the original
    implementation.
    """
    from repro import plan as _plan

    result = _plan.plan(
        tiles, capacity, preload_first=preload_first,
        exhaustive=exhaustive, max_window_scan=max_window_scan,
        search=search,
    )
    return result.to_two_phase()


def reference_two_phase(
    tiles: Sequence[TileCost],
    capacity: int,
    preload_first: bool = True,
    exhaustive: bool = False,
    max_window_scan: Optional[int] = None,
) -> TwoPhaseResult:
    """Both phases via the original full-replay planner (verification)."""
    base = baseline_schedule(tiles, capacity, preload_first)
    adpt = reference_adaptive_schedule(
        tiles, capacity, preload_first, baseline=base,
        exhaustive=exhaustive, max_window_scan=max_window_scan,
    )
    return TwoPhaseResult(baseline=base, adaptive=adpt)
