"""Wave Reorder Buffer (WRB) model (paper SS II-A).

The SA emits one R_SA-byte systolic wave every ceil(M/C_SA) cycles, split
into R_SA/R_g row-block chunks that arrive at the aggregator at staggered
times.  Chunks are written to the WRB *tagged* with (wave, row-block), so a
new wave can begin draining into the buffer before earlier waves fully
retire -- out-of-order writes with strict in-order reads.  The paper credits
this for "minimizing the idle state of the pipeline" (up to 98% measured
efficiency).

There is no TPU analogue to build: XLA's dataflow scheduling plays the WRB
role.  We keep this cycle-level model to *quantify* the paper's claim (the
benchmark compares in-order vs. out-of-order write admission) and document
the non-transfer in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class WRBConfig:
    r_sa: int = 64          # wave size in bytes (one byte per SA row)
    r_g: int = 8            # row-block granularity (aggregator lanes)
    capacity_waves: int = 4  # WRB depth in waves
    read_bytes_per_cycle: int = 8   # R_g bytes per cycle on the read side

    @property
    def blocks_per_wave(self) -> int:
        return self.r_sa // self.r_g


@dataclasses.dataclass
class WRBStats:
    cycles: int
    producer_stall_cycles: int   # aggregate chunk-wait cycles (can exceed
                                 # `cycles`: chunks wait concurrently)
    waves: int
    wave_interval: int = 1

    @property
    def efficiency(self) -> float:
        """Fraction of the ideal production rate sustained: the SA wants to
        emit one wave every `wave_interval` cycles; `cycles` is what the
        pipeline actually took."""
        ideal = self.waves * self.wave_interval
        return ideal / self.cycles if self.cycles else 1.0


def _simulate(cfg: WRBConfig, n_waves: int, wave_interval: int, out_of_order: bool) -> WRBStats:
    """Simulate producer (SA) vs consumer (post-processing read side).

    Producer: every ``wave_interval`` cycles a full wave's chunks become
    ready (row-blocks staggered by one cycle each, modeling the aggregator
    shift-up chain).  A chunk is admitted iff the WRB has space; with
    ``out_of_order=False`` it additionally requires all previous waves to be
    fully admitted *and drained* past it (head-of-line blocking).  When a
    chunk cannot be admitted the producer stalls (the SA pipeline halts).

    Consumer: drains strictly in wave order at ``read_bytes_per_cycle``.
    """
    bpw = cfg.blocks_per_wave
    buf_occupancy = 0               # in chunks
    capacity = cfg.capacity_waves * bpw
    drain_cycles_per_wave = max(1, cfg.r_sa // cfg.read_bytes_per_cycle)

    t = 0
    stall = 0
    drained_waves = 0
    admitted: List[int] = [0] * n_waves     # chunks admitted per wave
    consumer_free_at = 0

    for w in range(n_waves):
        ready_t = max(t, w * wave_interval)
        for b in range(bpw):
            chunk_t = ready_t + b
            # wait for space
            while True:
                # drain completed waves up to chunk_t
                while (
                    drained_waves < w
                    and admitted[drained_waves] == bpw
                    and consumer_free_at <= chunk_t
                ):
                    consumer_free_at = max(consumer_free_at, chunk_t) + drain_cycles_per_wave
                    buf_occupancy -= bpw
                    drained_waves += 1
                in_order_ok = out_of_order or drained_waves >= w
                if buf_occupancy < capacity and in_order_ok:
                    break
                stall += 1
                chunk_t += 1
            admitted[w] += 1
            buf_occupancy += 1
            t = chunk_t
    # drain the tail
    while drained_waves < n_waves:
        consumer_free_at = max(consumer_free_at, t) + drain_cycles_per_wave
        buf_occupancy -= bpw
        drained_waves += 1
        t = consumer_free_at
    return WRBStats(cycles=t, producer_stall_cycles=stall, waves=n_waves,
                    wave_interval=wave_interval)


def simulate_wrb(
    cfg: WRBConfig, n_waves: int, wave_interval: int, out_of_order: bool = True
) -> WRBStats:
    if n_waves <= 0:
        return WRBStats(cycles=0, producer_stall_cycles=0, waves=0,
                        wave_interval=wave_interval)
    return _simulate(cfg, n_waves, wave_interval, out_of_order)


def ooo_benefit(cfg: WRBConfig, n_waves: int, wave_interval: int) -> Tuple[WRBStats, WRBStats]:
    """(in-order, out-of-order) stats for the same workload."""
    return (
        simulate_wrb(cfg, n_waves, wave_interval, out_of_order=False),
        simulate_wrb(cfg, n_waves, wave_interval, out_of_order=True),
    )
