"""Weight-streaming executor: the paper's scheduler driving real inference.

Bridges the two-phase schedule (core/scheduler.py) and a JAX model: the
model's weight matrices are partitioned into named tiles, costed under a
memory-hierarchy profile (core/pu.py PUConfig -- URAM@FPGA, VMEM@TPU or
host-offload@TPU), scheduled, and the plan is exposed to the serving engine
which issues prefetches in plan order.

On real TPU hardware the prefetch issue would be `jax.device_put` onto the
target memory space ahead of the consuming layer; in this CPU container the
executor runs the *plan* faithfully (same ordering, same residency account)
and the compute functionally, so every schedule property is testable.
"""
from __future__ import annotations

import dataclasses
from typing import (
    TYPE_CHECKING, Any, Callable, Dict, List, Optional, Sequence, Tuple,
)

import numpy as np

from repro.core.pu import PUConfig, TileCost
from repro.core import scheduler as sched

if TYPE_CHECKING:  # repro.plan imports core.pu: keep the cycle lazy
    from repro.plan import ExecutionPlan


@dataclasses.dataclass(frozen=True)
class WeightTile:
    """A named weight tile: one schedulable unit of the model."""

    name: str          # e.g. "layer3/mlp/up/rows0"
    layer_index: int   # inference order of the consuming layer
    n: int             # tile rows (<= R_SA after padding at the PU level)
    m: int             # reduction dim
    p: int             # activation columns it will be applied to

    def cost(self, pu: PUConfig) -> TileCost:
        return TileCost(
            load_s=pu.load_time(self.m, self.n),
            exec_s=pu.exec_time(self.m, self.p, self.n),
            mem_bytes=pu.tile_bytes(self.m, self.n),
        )


@dataclasses.dataclass
class StreamingPlan:
    tiles: List[WeightTile]
    plan: "ExecutionPlan"
    pu: PUConfig

    @property
    def result(self) -> sched.TwoPhaseResult:
        """Legacy two-schedule view of the underlying ExecutionPlan."""
        return self.plan.to_two_phase()

    @property
    def schedule(self) -> sched.Schedule:
        return self.plan.to_schedule("adaptive")

    def issue_order(self) -> List[int]:
        """Tile indices in channel (load-issue) order.

        The load channel is serial and drains its queue sorted by
        ``(window, tile)``; this is the order the executor must fetch in.
        """
        windows = self.plan.windows
        return sorted(range(len(self.tiles)), key=lambda i: (windows[i], i))

    def prefetch_order(self) -> List[Tuple[str, int]]:
        """(tile name, window) in load-issue order."""
        windows = self.plan.windows
        return [(self.tiles[i].name, windows[i]) for i in self.issue_order()]

    def summary(self) -> Dict[str, float]:
        out = {
            "tiles": len(self.tiles),
            "capacity_bytes": float(self.pu.fast_mem_bytes),
            "weight_bytes": float(self.plan.weight_bytes),
            "baseline_stall_s": self.plan.baseline_stall,
            "adaptive_stall_s": self.plan.total_stall,
            "stall_reduction": self.plan.stall_reduction,
            "baseline_util": self.plan.baseline.utilization,
            "adaptive_util": self.plan.utilization,
            "makespan_s": self.plan.makespan,
        }
        return out


def plan_streaming(
    tiles: Sequence[WeightTile], pu: PUConfig
) -> StreamingPlan:
    """Plan a tile sequence on ``pu`` via the shared (cached) planner."""
    from repro.plan import plan_cached

    costs = [t.cost(pu) for t in tiles]
    result = plan_cached(costs, pu.fast_mem_bytes)
    return StreamingPlan(tiles=list(tiles), plan=result, pu=pu)


def gemm_sequence_tiles(
    gemms: Sequence[Tuple[str, int, int, int]], pu: PUConfig
) -> List[WeightTile]:
    """Tile a sequence of (name, N, M, P) GEMMs into R_SA-row tiles,

    exactly the paper's `R_SA x M_v` partitioning (SS III).
    """
    tiles: List[WeightTile] = []
    for li, (name, n, m, p) in enumerate(gemms):
        n_tiles = -(-n // pu.r_sa)
        for t in range(n_tiles):
            rows = min(pu.r_sa, n - t * pu.r_sa)
            tiles.append(
                WeightTile(
                    name=f"{name}/rows{t * pu.r_sa}",
                    layer_index=li,
                    n=rows,
                    m=m,
                    p=p,
                )
            )
    return tiles


class StreamingExecutor:
    """Execute a tiled computation under a streaming plan.

    ``tile_fns[i]`` computes tile *i*'s output given its weights; weights
    are fetched via ``fetch(tile_name)`` no earlier than the plan's issue
    order allows, and evicted once executed (bounded residency).  The
    executor asserts the plan's memory bound at runtime -- it is the
    software twin of the hardware's URAM allocator.
    """

    def __init__(
        self,
        plan: StreamingPlan,
        fetch: Callable[[str], Any],
    ):
        self.plan = plan
        self.fetch = fetch
        self._resident: Dict[int, Any] = {}
        self._resident_bytes = 0
        self.peak_resident_bytes = 0
        self.fetches: List[str] = []

    def run(
        self, tile_fns: Sequence[Callable[[Any], Any]]
    ) -> List[Any]:
        schedule = self.plan.schedule
        assert schedule.feasible, "infeasible streaming plan"
        tiles = self.plan.tiles
        # The load channel is serial: fetches MUST follow the plan's issue
        # order (queue sorted by (window, tile)).  Issuing by raw
        # load_start with an exemption for tile i's own load could pull a
        # late load ahead of queued earlier ones, breaking the residency
        # account the schedule was verified against.
        issue_order = self.plan.issue_order()
        costs = [schedule.tiles[i].mem_bytes for i in range(len(tiles))]
        outputs: List[Optional[Any]] = [None] * len(tiles)
        qpos = 0
        for i in range(len(tiles)):
            # Issue, in plan order, every prefetch the plan starts no later
            # than tile i's execution.  Tile i's own load is always among
            # them: its load_start precedes its exec_start, and everything
            # queued before it starts no later still.
            while qpos < len(issue_order):
                j = issue_order[qpos]
                if schedule.tiles[j].load_start > schedule.tiles[i].exec_start:
                    break
                if j not in self._resident:
                    self._resident[j] = self.fetch(tiles[j].name)
                    self._resident_bytes += costs[j]
                    self.fetches.append(tiles[j].name)
                    self.peak_resident_bytes = max(
                        self.peak_resident_bytes, self._resident_bytes
                    )
                    assert self._resident_bytes <= self.plan.pu.fast_mem_bytes, (
                        f"residency {self._resident_bytes} exceeds capacity"
                    )
                qpos += 1
            assert i in self._resident, f"tile {i} executed before its load"
            outputs[i] = tile_fns[i](self._resident[i])
            self._resident_bytes -= costs[i]
            del self._resident[i]
        return outputs
