"""Weight-streaming executor: the paper's scheduler driving real inference.

Bridges the two-phase schedule (core/scheduler.py) and a JAX model: the
model's weight matrices are partitioned into named tiles, costed under a
memory-hierarchy profile (core/pu.py PUConfig -- URAM@FPGA, VMEM@TPU or
host-offload@TPU), scheduled, and the plan is exposed to the serving engine
which issues prefetches in plan order.

On real TPU hardware the prefetch issue would be `jax.device_put` onto the
target memory space ahead of the consuming layer; in this CPU container the
executor runs the *plan* faithfully (same ordering, same residency account)
and the compute functionally, so every schedule property is testable.
"""
from __future__ import annotations

import dataclasses
import threading
import time as _time
from typing import (
    TYPE_CHECKING, Any, Callable, Dict, List, Optional, Sequence, Tuple,
)

import numpy as np

from repro.analysis import sanitize
from repro.core.pu import PUConfig, TileCost
from repro.core import scheduler as sched

if TYPE_CHECKING:  # repro.plan imports core.pu: keep the cycle lazy
    from repro.plan import ExecutionPlan


@dataclasses.dataclass(frozen=True)
class WeightTile:
    """A named weight tile: one schedulable unit of the model."""

    name: str          # e.g. "layer3/mlp/up/rows0"
    layer_index: int   # inference order of the consuming layer
    n: int             # tile rows (<= R_SA after padding at the PU level)
    m: int             # reduction dim
    p: int             # activation columns it will be applied to

    def cost(self, pu: PUConfig) -> TileCost:
        return TileCost(
            load_s=pu.load_time(self.m, self.n),
            exec_s=pu.exec_time(self.m, self.p, self.n),
            mem_bytes=pu.tile_bytes(self.m, self.n),
        )


@dataclasses.dataclass
class StreamingPlan:
    tiles: List[WeightTile]
    plan: "ExecutionPlan"
    pu: PUConfig

    @property
    def result(self) -> sched.TwoPhaseResult:
        """Legacy two-schedule view of the underlying ExecutionPlan."""
        return self.plan.to_two_phase()

    @property
    def schedule(self) -> sched.Schedule:
        return self.plan.to_schedule("adaptive")

    def issue_order(self) -> List[int]:
        """Tile indices in channel (load-issue) order.

        The load channel is serial and drains its queue sorted by
        ``(window, tile)``; this is the order the executor must fetch in.
        """
        return self.plan.issue_order()

    def prefetch_order(self) -> List[Tuple[str, int]]:
        """(tile name, window) in load-issue order."""
        windows = self.plan.windows
        return [(self.tiles[i].name, windows[i]) for i in self.issue_order()]

    def summary(self) -> Dict[str, float]:
        out = {
            "tiles": len(self.tiles),
            "capacity_bytes": float(self.pu.fast_mem_bytes),
            "weight_bytes": float(self.plan.weight_bytes),
            "baseline_stall_s": self.plan.baseline_stall,
            "adaptive_stall_s": self.plan.total_stall,
            "stall_reduction": self.plan.stall_reduction,
            "baseline_util": self.plan.baseline.utilization,
            "adaptive_util": self.plan.utilization,
            "makespan_s": self.plan.makespan,
        }
        return out


def plan_streaming(
    tiles: Sequence[WeightTile], pu: PUConfig, search=None
) -> StreamingPlan:
    """Plan a tile sequence on ``pu`` via the shared (cached) planner.

    ``search`` (a ``repro.plan.SearchConfig``) selects the schedule
    search strategy; it is folded into the plan-cache key.
    """
    from repro.plan import plan_cached

    costs = [t.cost(pu) for t in tiles]
    result = plan_cached(costs, pu.fast_mem_bytes, search=search)
    return StreamingPlan(tiles=list(tiles), plan=result, pu=pu)


def gemm_sequence_tiles(
    gemms: Sequence[Tuple[str, int, int, int]], pu: PUConfig
) -> List[WeightTile]:
    """Tile a sequence of (name, N, M, P) GEMMs into R_SA-row tiles,

    exactly the paper's `R_SA x M_v` partitioning (SS III).
    """
    tiles: List[WeightTile] = []
    for li, (name, n, m, p) in enumerate(gemms):
        n_tiles = -(-n // pu.r_sa)
        for t in range(n_tiles):
            rows = min(pu.r_sa, n - t * pu.r_sa)
            tiles.append(
                WeightTile(
                    name=f"{name}/rows{t * pu.r_sa}",
                    layer_index=li,
                    n=rows,
                    m=m,
                    p=p,
                )
            )
    return tiles


class StageStreamCore:
    """Residency-accounted prefetch/execute core of one streaming stage.

    Owns one PU's fast-memory account.  The *prefetch side* walks the
    plan's issue order -- the serial load channel drained sorted by
    ``(window, tile)`` -- and never reorders it; the *execute side*
    retires tiles strictly in inference (index) order and frees their
    bytes at retire, exactly when the hardware's URAM slot frees.

    The two sides may run on one thread, alternated by a plan-time gate
    (:class:`StreamingExecutor`), or on separate threads with the
    prefetch worker blocking on capacity (``runtime.pipeline_exec``) --
    feasibility of the underlying plan guarantees the blocking mode is
    deadlock-free: whenever the execute side waits on tile *i*, every
    queue entry up to *i* fits alongside the not-yet-retired residents,
    because the plan's verified peak residency at *i*'s exec covers
    precisely that set.
    """

    def __init__(
        self,
        *,
        costs: Sequence[int],            # mem_bytes per tile (index order)
        capacity: int,
        issue_order: Sequence[int],
        fetch: Callable[[int], Any],     # tile index -> weights
        names: Optional[Sequence[str]] = None,
    ):
        self.costs = list(costs)
        self.capacity = capacity
        self.issue_order = list(issue_order)
        self._fetch = fetch
        self.names = list(names) if names is not None else [
            str(i) for i in range(len(self.costs))
        ]
        # under REPRO_SANITIZE=1 the condition feeds the lock-order
        # recorder (one class-level name: ordering is a property of the
        # code, not the instance); otherwise a plain Condition
        self._cond = sanitize.instrument_condition("StageStreamCore._cond")
        self._resident: Dict[int, Any] = {}
        self._resident_bytes = 0
        self._qpos = 0
        self._failed: Optional[BaseException] = None
        self.peak_resident_bytes = 0
        self.fetches: List[str] = []     # names, in actual fetch order

    # -- prefetch side ------------------------------------------------------

    def next_issue(self) -> Optional[int]:
        """Peek the next tile in issue order without fetching it."""
        with self._cond:
            if self._qpos >= len(self.issue_order):
                return None
            return self.issue_order[self._qpos]

    def issue_next(self, *, block: bool) -> Optional[int]:
        """Fetch the next tile in issue order; returns its index.

        ``block=True`` (async worker) waits until the tile fits in fast
        memory -- the load channel stalling on URAM space; ``block=False``
        (plan-time-gated sync driver) asserts it fits, because the caller
        only issues loads the verified schedule has already started.
        """
        with self._cond:
            if self._qpos >= len(self.issue_order):
                return None
            j = self.issue_order[self._qpos]
            need = self.costs[j]
            if block:
                while self._resident_bytes + need > self.capacity:
                    if self._failed is not None:
                        return None
                    self._cond.wait(timeout=60.0)
            else:
                assert self._resident_bytes + need <= self.capacity, (
                    f"residency {self._resident_bytes + need} exceeds "
                    f"capacity {self.capacity}"
                )
            self._qpos += 1
        # fetch outside the lock: callbacks may be slow (host DMA, disk)
        try:
            w = self._fetch(j)
        except BaseException as e:
            with self._cond:
                self._failed = e
                self._cond.notify_all()
            raise
        with self._cond:
            self._resident[j] = w
            self._resident_bytes += need
            self.fetches.append(self.names[j])
            self.peak_resident_bytes = max(
                self.peak_resident_bytes, self._resident_bytes
            )
            self._cond.notify_all()
        return j

    def prefetch_all(self) -> None:
        """Blocking-worker loop: drain the whole issue queue."""
        while self.issue_next(block=True) is not None:
            pass

    # -- execute side -------------------------------------------------------

    def is_resident(self, i: int) -> bool:
        with self._cond:
            return i in self._resident

    def acquire(self, i: int, timeout: float = 120.0) -> Any:
        """Block until tile *i* is resident; return its weights."""
        deadline = _time.monotonic() + timeout
        with self._cond:
            while i not in self._resident:
                if self._failed is not None:
                    raise RuntimeError(
                        f"prefetch worker failed: {self._failed!r}"
                    ) from self._failed
                remaining = deadline - _time.monotonic()
                if remaining <= 0 or not self._cond.wait(timeout=remaining):
                    raise RuntimeError(
                        f"tile {i} not resident after {timeout}s "
                        "(prefetch stalled?)"
                    )
            return self._resident[i]

    def release(self, i: int) -> None:
        with self._cond:
            del self._resident[i]
            self._resident_bytes -= self.costs[i]
            self._cond.notify_all()

    def abort(self, exc: BaseException) -> None:
        """Unblock both sides after a failure on either."""
        with self._cond:
            self._failed = exc
            self._cond.notify_all()


class StreamingExecutor:
    """Execute a tiled computation under a streaming plan (one PU).

    ``tile_fns[i]`` computes tile *i*'s output given its weights; weights
    are fetched via ``fetch(tile_name)`` no earlier than the plan's issue
    order allows, and evicted once executed (bounded residency).  The
    executor asserts the plan's memory bound at runtime -- it is the
    software twin of the hardware's URAM allocator.  Prefetch and compute
    are interleaved on the calling thread, gated by the plan's timeline;
    the stage-parallel runtime (``runtime.pipeline_exec``) drives the same
    :class:`StageStreamCore` with a concurrent prefetch worker instead.
    """

    def __init__(
        self,
        plan: StreamingPlan,
        fetch: Callable[[str], Any],
    ):
        self.plan = plan
        self.fetch = fetch
        self.peak_resident_bytes = 0
        self.fetches: List[str] = []

    def run(
        self, tile_fns: Sequence[Callable[[Any], Any]]
    ) -> List[Any]:
        schedule = self.plan.schedule
        assert schedule.feasible, "infeasible streaming plan"
        tiles = self.plan.tiles
        # The load channel is serial: fetches MUST follow the plan's issue
        # order (queue sorted by (window, tile)).  Issuing by raw
        # load_start with an exemption for tile i's own load could pull a
        # late load ahead of queued earlier ones, breaking the residency
        # account the schedule was verified against.
        core = StageStreamCore(
            costs=[schedule.tiles[i].mem_bytes for i in range(len(tiles))],
            capacity=self.plan.pu.fast_mem_bytes,
            issue_order=self.plan.issue_order(),
            fetch=lambda j: self.fetch(tiles[j].name),
            names=[t.name for t in tiles],
        )
        outputs: List[Optional[Any]] = [None] * len(tiles)
        try:
            for i in range(len(tiles)):
                # Issue, in plan order, every prefetch the plan starts no
                # later than tile i's execution.  Tile i's own load is
                # always among them: its load_start precedes its
                # exec_start, and everything queued before it starts no
                # later still.
                while True:
                    j = core.next_issue()
                    if j is None or (
                        schedule.tiles[j].load_start
                        > schedule.tiles[i].exec_start
                    ):
                        break
                    core.issue_next(block=False)
                assert core.is_resident(i), (
                    f"tile {i} executed before its load"
                )
                outputs[i] = tile_fns[i](core.acquire(i))
                core.release(i)
        finally:
            # publish even on failure: the partial fetch order is the
            # first thing debugging a mid-run fault needs
            self.peak_resident_bytes = core.peak_resident_bytes
            self.fetches = core.fetches
        return outputs
