"""Fault-tolerant checkpointing: atomic manifest + shards, async writes,
elastic restore onto a different mesh."""
from repro.checkpoint.manager import (
    CheckpointManager,
    latest_step,
    restore,
    save,
)

__all__ = ["CheckpointManager", "latest_step", "restore", "save"]
