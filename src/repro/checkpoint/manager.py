"""Checkpoint manager: atomic, async, elastic.

Layout of one checkpoint::

    <dir>/step_000100/
        arrays.npz        flat {path -> ndarray} of params/opt/extra state
        manifest.json     step, tree structure, loader state, mesh shape,
                          wall time, framework versions

**Atomicity**: everything is written into ``step_X.tmp-<pid>`` and renamed
into place; the manifest is written last, so a checkpoint without a
manifest is by definition incomplete and ignored by discovery/cleanup.
A crash mid-write can never corrupt the latest valid checkpoint.

**Async**: `CheckpointManager.save_async` snapshots device arrays to host
(blocking only for the device->host copy) and writes in a daemon thread, so
the train loop overlaps checkpoint IO with the next steps -- the standard
trick to keep checkpoint stalls off the critical path at scale.

**Elasticity**: arrays are saved *unsharded* (global view).  `restore`
re-applies whatever shardings the *current* mesh prescribes, so a job saved
on mesh (16,16) restores cleanly on (8,16) or a single host -- the
re-shard is just a device_put with the new NamedSharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax


_SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = _SEP.join(_path_elem(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_elem(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten(tree_like: Any, flat: Dict[str, np.ndarray]) -> Any:
    leaves_with_paths = jax.tree_util.tree_leaves_with_path(tree_like)
    treedef = jax.tree_util.tree_structure(tree_like)
    out = []
    for path, like in leaves_with_paths:
        key = _SEP.join(_path_elem(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing array '{key}'")
        arr = flat[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"checkpoint shape mismatch at '{key}': "
                f"saved {arr.shape} vs expected {like.shape}"
            )
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def _step_dir(base: Path, step: int) -> Path:
    return base / f"step_{step:08d}"


def save(
    base_dir: str | Path,
    step: int,
    state: Any,
    extra: Optional[dict] = None,
) -> Path:
    """Synchronous atomic save of a pytree + metadata."""
    base = Path(base_dir)
    base.mkdir(parents=True, exist_ok=True)
    final = _step_dir(base, step)
    tmp = base / f"{final.name}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(state)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "time": time.time(),
        "n_arrays": len(flat),
        "bytes": int(sum(a.nbytes for a in flat.values())),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(base_dir: str | Path) -> Optional[int]:
    base = Path(base_dir)
    if not base.exists():
        return None
    steps = []
    for d in base.iterdir():
        if d.name.startswith("step_") and (d / "manifest.json").exists():
            try:
                steps.append(int(d.name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(
    base_dir: str | Path,
    state_like: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, dict]:
    """Restore (state, manifest['extra']).

    ``state_like`` provides the tree structure + expected shapes (an
    eval_shape pytree works).  ``shardings``, when given (a matching pytree
    of NamedSharding), re-shards every leaf onto the *current* mesh --
    elastic restore.
    """
    base = Path(base_dir)
    if step is None:
        step = latest_step(base)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {base}")
    d = _step_dir(base, step)
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / "arrays.npz") as z:
        flat = {k: z[k] for k in z.files}
    state = _unflatten(state_like, flat)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings
        )
    return state, manifest.get("extra", {})


class CheckpointManager:
    """Async checkpointing with retention and crash-safe discovery."""

    def __init__(
        self,
        base_dir: str | Path,
        keep: int = 3,
        async_write: bool = True,
    ):
        self.base = Path(base_dir)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        # Snapshot to host NOW (cheap on CPU; device->host copy on TPU) so
        # the caller may mutate/donate its arrays immediately after.
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        if not self.async_write:
            self._write(step, host_state, extra)
            return
        self.wait()  # one in-flight write at a time
        # lint: disable=RPL004 -- owner thread; wait() above joined any in-flight writer
        self._thread = threading.Thread(
            target=self._write, args=(step, host_state, extra), daemon=True
        )
        self._thread.start()

    def _write(self, step, host_state, extra):
        try:
            save(self.base, step, host_state, extra)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            # lint: disable=RPL004 -- writer thread; owner only reads after join() in wait()
            self._error = e

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            # lint: disable=RPL004 -- owner thread, writer joined on the line above
            self._thread = None
        if self._error is not None:
            # lint: disable=RPL004 -- owner thread, after join(): the writer is gone
            err, self._error = self._error, None
            raise err

    # -- restore ----------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        return latest_step(self.base)

    def restore(self, state_like, step=None, shardings=None):
        return restore(self.base, state_like, step, shardings)

    # -- retention ---------------------------------------------------------
    def _gc(self):
        steps = sorted(
            int(d.name.split("_")[1])
            for d in self.base.iterdir()
            if d.name.startswith("step_")
            and "tmp" not in d.name
            and (d / "manifest.json").exists()
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(_step_dir(self.base, s), ignore_errors=True)
        # stale tmp dirs from crashed writers
        for d in self.base.iterdir():
            if ".tmp-" in d.name:
                try:
                    if time.time() - d.stat().st_mtime > 3600:
                        shutil.rmtree(d, ignore_errors=True)
                except OSError:
                    pass
