"""CI perf-regression gate over the committed benchmark baselines.

    PYTHONPATH=src python benchmarks/check_regression.py [--ref HEAD]

Compares the freshly written ``BENCH_plan.json`` (and, when present,
``BENCH_stream.json``) at the repo root against the version committed at
``--ref`` (read via ``git show``, so the working-tree file can be the
candidate even though the bench overwrote it in place).

Gates:
- ``BENCH_plan.json``: adaptive-phase stall reduction per workload and
  the K=2 pipeline gain must not regress below the committed baseline
  (small absolute/relative slack for float noise); the incremental-
  planner speedup, when both files carry it, must not collapse (wall
  time is noisy on shared runners, so the slack is generous);
  adaptive-phase wall time must stay under per-workload ceilings
  (the vectorized-engine budget -- generous vs the measured numbers,
  but far below the pre-vectorization planner); the search records
  must keep their stall-reduction floor over the heuristic and stay
  inside the search wall-time ceiling; the load-bound workload must
  keep its early exit.
- ``BENCH_stream.json``: the PR's acceptance floor, independent of any
  baseline -- measured K=2 gain >= 1.2x the best single-PU executor,
  measured bubble within 2x of the analytic prediction, and the
  microbatch auto-tuner landing in its bubble band at no throughput
  cost vs the fixed M=8 baseline.
- ``BENCH_serve.json``: the device-resident serving floor -- steady-state
  decode tokens/s >= 1.5x the legacy host-loop engine per config, zero
  jit retraces after warmup under mixed-length traffic, and greedy token
  streams bit-identical to the host loop on the dense (bit-gated)
  configs.  The ``pipeline_decode`` record gates overlapped staged
  decode: the K=2 --multi-pu engine's greedy streams bit-identical to
  the single-PU device loop, >= 2 stages, the executed virtual clock
  matching the plan recurrence, zero retraces after warmup, and
  steady-state decode throughput >= 1.0x the fused single-PU loop.
  The ``decode_kernels`` record gates the fused Pallas decode kernels:
  --decode-kernels greedy streams argmax-identical to the composed-XLA
  decode, zero retraces after warmup, every per-op kernel within
  numeric tolerance of its XLA composition, and (compiled runs only --
  the record carries ``interpreted``; CPU CI runs the kernels through
  the Pallas interpreter, where "speedup" measures interpreter
  overhead, not the fused datapath) decode throughput >= 1.0x the XLA
  path.

Exit code 1 on any regression, with one line per violation.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# Wall-time budgets for the adaptive phase (seconds).  Measured values
# on the dev container are ~0.2 s (resnet50), ~0.02 s (resnet18) and
# ~0.02 s (olmo: load-bound early exit); ceilings leave ~5x headroom
# for slower CI runners while still enforcing the vectorized engine's
# >=3x improvement over the pre-vectorization planner (3.2 s on
# resnet50).
ADAPTIVE_WALL_CEILING_S = {
    "resnet18": 0.6,
    "resnet50": 1.1,
    "olmo_1b_decode": 0.25,
}
# The search path must beat the heuristic's stall reduction by this
# factor on the dedicated search workloads, inside the wall ceiling.
SEARCH_GAIN_FLOOR = 1.5
SEARCH_WALL_CEILING_S = 8.0
SEARCH_WORKLOADS = ("search_resnet50", "search_resnet50_tight")

# Device-resident serving engine: steady-state decode throughput floor
# over the legacy host-loop engine (measured medians 1.8x-2.6x on the
# dev container; the floor is the PR's acceptance criterion).
SERVE_DECODE_SPEEDUP_FLOOR = 1.5

# Overlapped staged decode (--multi-pu K=2): the auto-tuned engine's
# steady-state decode rate must match the fused single-PU device loop
# (measured median ~1.5x with the coalesced single-device block; the
# floor is the PR's acceptance criterion, up from the 0.34x serial
# staged loop it replaces).
PIPELINE_DECODE_VS_SINGLE_PU_FLOOR = 1.0

# Fused Pallas decode kernels (--decode-kernels): steady-state decode
# throughput floor vs the composed-XLA decode, applied only when the
# record was produced by a *compiled* run (interpreted=false) -- the
# ISSUE's "interpret-comparable terms": on CPU both paths lower to the
# same XLA ops modulo interpreter overhead, so only correctness
# (argmax-identity, per-op tolerance, retraces) gates there.
DECODE_KERNELS_SPEEDUP_FLOOR = 1.0


def committed(name: str, ref: str) -> dict | None:
    try:
        out = subprocess.run(
            ["git", "show", f"{ref}:{name}"],
            capture_output=True, text=True, check=True, cwd=ROOT,
        ).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


def check_plan(base: dict, cand: dict, errors: list[str]) -> None:
    for wl in ("resnet18", "resnet50", "olmo_1b_decode"):
        b, c = base.get(wl), cand.get(wl)
        if not (b and c):
            continue
        # deterministic planner outputs: tight absolute slack only
        if c["stall_reduction"] < b["stall_reduction"] - 1e-6:
            errors.append(
                f"plan/{wl}: stall_reduction {c['stall_reduction']:.4f} "
                f"< baseline {b['stall_reduction']:.4f}"
            )
        if "speedup" in b and "speedup" in c:
            # wall-clock ratio: allow 50% noise, catch collapses
            if c["speedup"] < 0.5 * b["speedup"]:
                errors.append(
                    f"plan/{wl}: incremental speedup {c['speedup']:.1f}x "
                    f"collapsed (baseline {b['speedup']:.1f}x)"
                )
    b = base.get("partition_resnet50_k2")
    c = cand.get("partition_resnet50_k2")
    if b and c and c["pipeline_gain"] < b["pipeline_gain"] - 0.02:
        errors.append(
            f"plan/partition: K=2 pipeline_gain {c['pipeline_gain']:.3f} "
            f"< baseline {b['pipeline_gain']:.3f}"
        )
    # planner wall-time budgets (vectorized engine)
    for wl, ceiling in ADAPTIVE_WALL_CEILING_S.items():
        c = cand.get(wl)
        if c and c.get("adaptive_wall_s", 0.0) > ceiling:
            errors.append(
                f"plan/{wl}: adaptive_wall_s {c['adaptive_wall_s']:.3f}s "
                f"exceeds the {ceiling:.2f}s budget"
            )
    # the load-bound workload must keep its cheap exit
    c = cand.get("olmo_1b_decode")
    if c and "skipped_load_bound" in c and not c["skipped_load_bound"]:
        errors.append(
            "plan/olmo_1b_decode: load-bound early exit no longer fires"
        )
    # search path: stall-reduction floor over the heuristic + wall budget
    for wl in SEARCH_WORKLOADS:
        c = cand.get(wl)
        if not c:
            errors.append(f"plan/{wl}: search record missing")
            continue
        if c["search_gain"] < SEARCH_GAIN_FLOOR:
            errors.append(
                f"plan/{wl}: search stall-reduction gain "
                f"{c['search_gain']:.2f}x < {SEARCH_GAIN_FLOOR}x floor"
            )
        for strat in ("beam", "anneal"):
            w = c.get(strat, {}).get("wall_s", 0.0)
            if w > SEARCH_WALL_CEILING_S:
                errors.append(
                    f"plan/{wl}/{strat}: wall {w:.1f}s exceeds the "
                    f"{SEARCH_WALL_CEILING_S:.0f}s search budget"
                )
        b = base.get(wl)
        if b and c["stall_reduction"] < b["stall_reduction"] - 1e-6:
            errors.append(
                f"plan/{wl}: search stall_reduction "
                f"{c['stall_reduction']:.4f} < baseline "
                f"{b['stall_reduction']:.4f}"
            )


def check_stream(cand: dict, errors: list[str]) -> None:
    gain = cand.get("k2_gain_measured", 0.0)
    if gain < 1.2:
        errors.append(
            f"stream: measured K=2 gain {gain:.3f}x < 1.2x acceptance floor"
        )
    ratio = cand.get("k2_bubble_vs_predicted")
    if ratio is not None and ratio > 2.0:
        errors.append(
            f"stream: measured bubble {ratio:.2f}x the analytic "
            "prediction (> 2x acceptance bound)"
        )
    at = cand.get("autotune_k2")
    if at is None:
        errors.append("stream: autotune_k2 record missing")
    else:
        if not at.get("within_tolerance", False):
            errors.append(
                f"stream/autotune: measured bubble "
                f"{at.get('bubble_measured', -1):.3f} outside 10% of the "
                f"{at.get('target_bubble', 0.1):.2f} target"
            )
        if at.get("fps_vs_fixed_m8", 0.0) < 0.999:
            errors.append(
                f"stream/autotune: tuned throughput "
                f"{at.get('fps_vs_fixed_m8', 0.0):.3f}x the fixed M=8 "
                "baseline (< 1x)"
            )


def check_serve(cand: dict, errors: list[str]) -> None:
    configs = cand.get("configs", {})
    if not configs:
        errors.append("serve: no per-config records in BENCH_serve.json")
        return
    for arch, rec in configs.items():
        spd = rec.get("decode_speedup", 0.0)
        if spd < SERVE_DECODE_SPEEDUP_FLOOR:
            errors.append(
                f"serve/{arch}: decode speedup {spd:.2f}x < "
                f"{SERVE_DECODE_SPEEDUP_FLOOR}x floor vs the host-loop "
                "engine"
            )
        if rec.get("speedup", 0.0) < SERVE_DECODE_SPEEDUP_FLOOR:
            errors.append(
                f"serve/{arch}: end-to-end speedup "
                f"{rec.get('speedup', 0.0):.2f}x < "
                f"{SERVE_DECODE_SPEEDUP_FLOOR}x floor"
            )
        retr = rec.get("retraces_after_warmup", -1)
        if retr != 0:
            errors.append(
                f"serve/{arch}: {retr} jit retraces after warmup under "
                "mixed-length traffic (ceiling is 0)"
            )
        if rec.get("bit_gated") and not rec.get("greedy_bit_identical"):
            errors.append(
                f"serve/{arch}: greedy device stream diverged from the "
                "host-loop engine"
            )
    if "ttft_poisson" not in cand:
        errors.append("serve: ttft_poisson record missing")
    pd = cand.get("pipeline_decode")
    if pd is None:
        errors.append(
            "serve: pipeline_decode record missing (true per-stage "
            "decode -- run `benchmarks.run --only serve`)"
        )
    else:
        if not pd.get("greedy_bit_identical"):
            errors.append(
                "serve/pipeline_decode: staged --multi-pu greedy stream "
                "diverged from the single-PU device loop"
            )
        if pd.get("stages", 0) < 2:
            errors.append(
                f"serve/pipeline_decode: {pd.get('stages')} stage(s) -- "
                "the partition did not pipeline"
            )
        if not pd.get("clock_ok", False):
            errors.append(
                "serve/pipeline_decode: executed virtual clock diverged "
                "from the plan's pipeline recurrence"
            )
        if pd.get("retraces_after_warmup", -1) != 0:
            errors.append(
                f"serve/pipeline_decode: {pd.get('retraces_after_warmup')} "
                "retraces after warmup (ceiling is 0)"
            )
        ratio = pd.get("vs_single_pu", 0.0)
        if ratio < PIPELINE_DECODE_VS_SINGLE_PU_FLOOR:
            errors.append(
                f"serve/pipeline_decode: staged K=2 steady-state decode "
                f"{ratio:.2f}x the fused single-PU loop < "
                f"{PIPELINE_DECODE_VS_SINGLE_PU_FLOOR:.1f}x floor"
            )
    dk = cand.get("decode_kernels")
    if dk is None:
        errors.append(
            "serve: decode_kernels record missing (fused Pallas decode "
            "kernels -- run `benchmarks.run --only serve`)"
        )
        return
    interpreted = dk.get("interpreted", True)
    if not dk.get("per_op"):
        errors.append("serve/decode_kernels: per-op records missing")
    for op, rec in dk.get("per_op", {}).items():
        if not rec.get("ok", False):
            errors.append(
                f"serve/decode_kernels/{op}: fused kernel outside numeric "
                "tolerance of the XLA composition"
            )
    if not dk.get("configs"):
        errors.append("serve/decode_kernels: end-to-end records missing")
    for arch, rec in dk.get("configs", {}).items():
        if not rec.get("argmax_identical", False):
            errors.append(
                f"serve/decode_kernels/{arch}: --decode-kernels greedy "
                "stream diverged from the composed-XLA decode"
            )
        retr = rec.get("retraces_after_warmup", -1)
        if retr != 0:
            errors.append(
                f"serve/decode_kernels/{arch}: {retr} retraces after "
                "warmup (ceiling is 0)"
            )
        spd = rec.get("decode_speedup", 0.0)
        if not interpreted and spd < DECODE_KERNELS_SPEEDUP_FLOOR:
            errors.append(
                f"serve/decode_kernels/{arch}: compiled decode speedup "
                f"{spd:.2f}x < {DECODE_KERNELS_SPEEDUP_FLOOR:.1f}x floor "
                "vs the XLA decode"
            )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the committed baselines")
    ap.add_argument("--require-stream", action="store_true",
                    help="fail when BENCH_stream.json is absent (CI runs "
                         "the stream bench immediately before this gate)")
    ap.add_argument("--require-serve", action="store_true",
                    help="fail when BENCH_serve.json is absent (CI runs "
                         "the serve bench immediately before this gate)")
    args = ap.parse_args()

    errors: list[str] = []
    plan_path = ROOT / "BENCH_plan.json"
    if plan_path.exists():
        base = committed("BENCH_plan.json", args.ref)
        if base is None:
            print("no committed BENCH_plan.json baseline; skipping plan gate")
        else:
            check_plan(base, json.loads(plan_path.read_text()), errors)
    else:
        errors.append("BENCH_plan.json missing (run `benchmarks.run --only plan` first)")

    stream_path = ROOT / "BENCH_stream.json"
    if stream_path.exists():
        check_stream(json.loads(stream_path.read_text()), errors)
    elif args.require_stream:
        errors.append(
            "BENCH_stream.json missing (run `benchmarks.run --only stream`)"
        )

    serve_path = ROOT / "BENCH_serve.json"
    if serve_path.exists():
        check_serve(json.loads(serve_path.read_text()), errors)
    elif args.require_serve:
        errors.append(
            "BENCH_serve.json missing (run `benchmarks.run --only serve`)"
        )

    for e in errors:
        print(f"REGRESSION: {e}")
    if not errors:
        print("benchmark gates OK")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
