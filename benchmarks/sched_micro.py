"""Scheduler microbenchmark: incremental planner vs full-replay reference.

Times the adaptive phase (paper SS III phase 2) on a ResNet-50-scale tile
list under memory pressure -- the planner's hot path -- comparing the
unified ``repro.plan`` incremental planner against the original
full-re-simulation implementation kept as
``core.scheduler.reference_adaptive_schedule``.  Asserts bit-identical
output (same windows, stalls, makespan) and, in full mode, the >=5x
speedup target on a >=200-tile workload.

    PYTHONPATH=src python benchmarks/sched_micro.py [--smoke]

``--smoke`` runs a reduced workload without the speedup assertion (CI).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def run_micro(capacity_frac: float = 0.25, variant: int = 50, smoke: bool = False):
    from repro.core.pu import PU_2X
    from repro.core import scheduler as sched
    from repro.core import simulator as sim
    from repro.plan import plan

    layers = sim.resnet_gemm_layers(variant)
    tiles = sim.model_tiles(PU_2X, layers)
    capacity = int(PU_2X.fast_mem_bytes * capacity_frac)
    max_scan = 8 if smoke else None

    base = sched.baseline_schedule(tiles, capacity)
    assert base.feasible

    t0 = time.perf_counter()
    ref = sched.reference_adaptive_schedule(
        tiles, capacity, baseline=base, max_window_scan=max_scan
    )
    t_ref = time.perf_counter() - t0

    t0 = time.perf_counter()
    new = plan(tiles, capacity, max_window_scan=max_scan)
    t_new = time.perf_counter() - t0

    # bit-identical adaptive schedules
    assert list(new.windows) == [t.window for t in ref.tiles], "window mismatch"
    assert new.total_stall == ref.total_stall, "stall mismatch"
    assert new.makespan == ref.makespan, "makespan mismatch"

    speedup = t_ref / t_new if t_new > 0 else float("inf")
    return {
        "workload": f"resnet{variant}_pu2x@{capacity_frac:.2f}cap",
        "tiles": len(tiles),
        "capacity_bytes": capacity,
        "max_window_scan": max_scan,
        "reference_adaptive_s": t_ref,
        "incremental_plan_s": t_new,
        "speedup": speedup,
        "baseline_stall_s": base.total_stall,
        "adaptive_stall_s": new.total_stall,
        "stall_reduction": new.stall_reduction,
        "relocations": len(new.relocations()),
        "bit_identical": True,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workload, no speedup assertion (CI)")
    ap.add_argument("--capacity-frac", type=float, default=0.25)
    args = ap.parse_args()

    rec = run_micro(
        capacity_frac=args.capacity_frac,
        variant=18 if args.smoke else 50,
        smoke=args.smoke,
    )
    print(json.dumps(rec, indent=1))

    out = ROOT / "experiments" / "bench"
    out.mkdir(parents=True, exist_ok=True)
    (out / "sched_micro.json").write_text(json.dumps(rec, indent=1))

    if not args.smoke:
        assert rec["tiles"] >= 200, f"workload too small: {rec['tiles']} tiles"
        assert rec["speedup"] >= 5.0, (
            f"incremental planner only {rec['speedup']:.1f}x faster "
            "(target >=5x)"
        )
        print(f"OK: {rec['speedup']:.1f}x on {rec['tiles']} tiles")
    else:
        assert rec["speedup"] > 0.5, "incremental planner unexpectedly slow"
        print(f"smoke OK: {rec['speedup']:.1f}x on {rec['tiles']} tiles")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
