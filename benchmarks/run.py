"""Benchmark harness -- one benchmark per paper table/figure, plus kernel
micro-benchmarks and the TPU-scale derived benchmarks.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]

Prints ``name,us_per_call,derived`` CSV (one line per benchmark) and writes
per-benchmark artifacts (full tables) under ``experiments/bench/``.

Paper mapping:
  table1_resnet18 / table1_resnet50   -> Table I (FPS, FPS/TOPS)
  fig5a_layer_latency                 -> Fig. 5(a) per-layer latencies
  fig5bc_scheduler_ratios             -> Fig. 5(b,c) time/memory ratios
  efficiency_98pct                    -> SS V "up to 98% performance efficiency"
  wrb_out_of_order                    -> SS II-A WRB claim
  aimc_noise_snr                      -> SS VI AIMC emulation
Beyond-paper (TPU adaptation):
  kernel_int8_gemm / kernel_im2col    -> Pallas kernels vs oracles (wall time)
  scheduler_capacity_sweep            -> two-phase gain vs memory pressure
  streaming_plan_lm                   -> scheduler applied to assigned LMs
  plan / stream                       -> repro.plan perf trajectory
                                         (BENCH_plan.json) and the executed
                                         stage pipeline vs its analytic
                                         model (BENCH_stream.json)
  serve                               -> device-resident decode loop vs the
                                         legacy host-loop engine: decode
                                         tok/s, retraces under mixed-length
                                         traffic, greedy bit-identity,
                                         prefill latency per bucket, Poisson
                                         TTFT percentiles (BENCH_serve.json)
  train_smoke / serve_smoke           -> end-to-end throughput (reduced configs)
  roofline_summary                    -> reads experiments/dryrun artifacts
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]
BENCH_DIR = ROOT / "experiments" / "bench"


def timed(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # us


def emit(name: str, us: float, derived: str, artifact: dict | None = None):
    print(f"{name},{us:.1f},{derived}")
    if artifact is not None:
        BENCH_DIR.mkdir(parents=True, exist_ok=True)
        (BENCH_DIR / f"{name}.json").write_text(json.dumps(artifact, indent=1))


# ------------------------------------------------------------- Table I ----


def bench_table1(variant: int):
    from repro.core.pu import PU_1X, PU_2X
    from repro.core import simulator as sim

    paper = {18: (1237.7, 268.6), 50: (584.9, 126.9)}[variant]
    layers = sim.resnet_gemm_layers(variant)

    def run():
        s1 = sim.simulate_model(PU_1X, layers)
        s2 = sim.simulate_model(PU_2X, layers)
        return sim.FleetSim(sims=[("pu1x", s1, 5), ("pu2x", s2, 5)])

    fleet, us = timed(run, repeats=1)
    fps, fpt = fleet.fps, fleet.fps_per_tops
    emit(
        f"table1_resnet{variant}",
        us,
        f"fps={fps:.1f}(paper {paper[0]});fps_per_tops={fpt:.1f}(paper {paper[1]});"
        f"rel_err={abs(fps - paper[0]) / paper[0]:.3f}",
        {
            "fps": fps, "fps_per_tops": fpt, "tops": fleet.tops,
            "paper_fps": paper[0], "paper_fps_per_tops": paper[1],
            "per_pu": {
                name: {
                    "fps": s.fps_scheduled,
                    "latency_ms": s.frame_s_scheduled * 1e3,
                    "efficiency": s.efficiency,
                }
                for name, s, _ in fleet.sims
            },
        },
    )


def bench_fig5a():
    from repro.core.pu import PU_1X, PU_2X
    from repro.core import simulator as sim

    layers = sim.resnet_gemm_layers(50)
    rows = []

    def run():
        rows.clear()
        for pu in (PU_1X, PU_2X):
            for ls in [sim.simulate_layer(pu, l) for l in layers]:
                rows.append(
                    {
                        "pu": pu.name,
                        "layer": ls.layer.name,
                        "latency_us": ls.latency_s * 1e6,
                        "compute_us": ls.compute_s * 1e6,
                        "act_in_us": ls.act_in_s * 1e6,
                        "wrb_ok": ls.wrb_rate_ok,
                    }
                )
        return rows

    _, us = timed(run, repeats=1)
    tot1 = sum(r["latency_us"] for r in rows if r["pu"] == "pu1x") / 1e3
    tot2 = sum(r["latency_us"] for r in rows if r["pu"] == "pu2x") / 1e3
    emit(
        "fig5a_layer_latency",
        us,
        f"resnet50_pu1x_ms={tot1:.1f}(paper 25.3);pu2x_ms={tot2:.1f}(paper 12.9);layers={len(layers)}",
        {"rows": rows},
    )


def bench_fig5bc():
    from repro.core.pu import PU_2X
    from repro.core import simulator as sim
    from repro.core import scheduler as sched

    layers = sim.resnet_gemm_layers(18)
    tiles = sim.model_tiles(PU_2X, layers)

    def run():
        return sched.two_phase(tiles, capacity=PU_2X.fast_mem_bytes)

    res, us = timed(run, repeats=1)
    tr = res.time_ratios()
    mr = res.memory_ratios()
    n_stall_base = sum(1 for t in res.baseline.tiles if t.stall > 1e-12)
    n_stall_adpt = sum(1 for t in res.adaptive.tiles if t.stall > 1e-12)
    emit(
        "fig5bc_scheduler_ratios",
        us,
        f"tiles={len(tiles)};stalled_base={n_stall_base};stalled_adaptive={n_stall_adpt};"
        f"stall_reduction={res.stall_reduction:.3f};mem_ratio_max={max(mr):.3f}",
        {
            "time_ratios": tr,
            "memory_ratios": mr,
            "baseline_stall_s": res.baseline.total_stall,
            "adaptive_stall_s": res.adaptive.total_stall,
            "relocations": [
                {"tile": t.index, "from": bt.window, "to": t.window}
                for bt, t in zip(res.baseline.tiles, res.adaptive.tiles)
                if bt.window != t.window
            ],
        },
    )


def bench_efficiency():
    from repro.core.pu import PU_1X, PU_2X
    from repro.core import simulator as sim

    out = {}
    def run():
        for variant in (18, 50):
            layers = sim.resnet_gemm_layers(variant)
            for pu in (PU_1X, PU_2X):
                out[f"r{variant}_{pu.name}"] = sim.simulate_model(pu, layers).efficiency
        return out

    _, us = timed(run, repeats=1)
    emit(
        "efficiency_98pct",
        us,
        ";".join(f"{k}={v:.3f}" for k, v in out.items()) + ";paper=0.98",
        out,
    )


def bench_wrb():
    from repro.core import wrb

    cfg = wrb.WRBConfig()

    def run():
        return {
            str(iv): wrb.ooo_benefit(cfg, n_waves=256, wave_interval=iv)
            for iv in (2, 4, 8)
        }

    res, us = timed(run, repeats=1)
    derived = ";".join(
        f"iv{iv}:in={io.efficiency:.3f},ooo={oo.efficiency:.3f}"
        for iv, (io, oo) in res.items()
    )
    emit("wrb_out_of_order", us, derived,
         {iv: {"in_order": io.efficiency, "ooo": oo.efficiency}
          for iv, (io, oo) in res.items()})


def bench_aimc():
    import jax
    import jax.numpy as jnp
    from repro.core.aimc import AIMCNoiseModel, NoiseInjectionUnit, snr_db
    from repro.core.quant import quantize

    w = jax.random.normal(jax.random.PRNGKey(0), (256, 256))
    niu = NoiseInjectionUnit({"w": quantize(w)}, AIMCNoiseModel())
    counter = [0]

    def run():
        counter[0] += 1
        noisy = niu.refresh(jax.random.PRNGKey(counter[0]))
        return float(snr_db(w, noisy["w"].dequantize()))

    snr, us = timed(run, repeats=3)
    emit("aimc_noise_snr", us, f"snr_db={snr:.1f};model=pcm_default",
         {"snr_db": snr})


# ----------------------------------------------------------- kernels -----


def bench_kernel_gemm(fast: bool):
    import jax.numpy as jnp
    from repro.kernels import int8_gemm, ref

    rng = np.random.default_rng(0)
    n, m, p = (128, 256, 128) if fast else (256, 512, 256)
    w = jnp.asarray(rng.integers(-127, 128, (n, m), dtype=np.int8))
    x = jnp.asarray(rng.integers(-127, 128, (m, p), dtype=np.int8))

    y, us_pallas = timed(
        lambda: int8_gemm(w, x, shift=7).block_until_ready(), repeats=2
    )
    yr, us_ref = timed(
        lambda: ref.int8_gemm_ref(w, x, shift=7).block_until_ready(), repeats=2
    )
    ok = bool((np.asarray(y) == np.asarray(yr)).all())
    emit(
        "kernel_int8_gemm",
        us_pallas,
        f"shape={n}x{m}x{p};interpret_vs_ref_ok={ok};ref_us={us_ref:.1f};"
        f"note=interpret-mode(CPU oracle check; perf target is TPU)",
    )


def bench_kernel_im2col(fast: bool):
    import jax.numpy as jnp
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    h = 32 if fast else 64
    img = jnp.asarray(rng.integers(-127, 128, (h, h, 16), dtype=np.int8))

    y, us = timed(lambda: ops.im2col(img, 3, 1, 1).block_until_ready(), repeats=2)
    yr, us_ref = timed(
        lambda: ref.im2col_ref(img, 3, 1, 1).block_until_ready(), repeats=2
    )
    ok = bool((np.asarray(y) == np.asarray(yr)).all())
    emit(
        "kernel_im2col",
        us,
        f"img={h}x{h}x16;k3s1p1;ok={ok};ref_us={us_ref:.1f}",
    )


# ----------------------------------------------- scheduler at scale -------


def bench_scheduler_sweep():
    from repro.core.pu import PU_2X
    from repro.core import simulator as sim
    from repro.core import scheduler as sched

    layers = sim.resnet_gemm_layers(50)
    tiles = sim.model_tiles(PU_2X, layers)
    full_cap = PU_2X.fast_mem_bytes
    rows = []

    def run():
        rows.clear()
        for frac in (0.1, 0.2, 0.4, 0.6, 0.8, 1.0):
            cap = int(full_cap * frac)
            # bounded window scan: stress capacities leave many stalls
            # memory-blocked; scanning every window is O(n^2) simulates
            res = sched.two_phase(tiles, capacity=cap, max_window_scan=32)
            rows.append(
                {
                    "capacity_frac": frac,
                    "feasible": res.baseline.feasible,
                    "baseline_stall_ms": res.baseline.total_stall * 1e3,
                    "adaptive_stall_ms": res.adaptive.total_stall * 1e3,
                    "reduction": res.stall_reduction,
                    "baseline_util": res.baseline.utilization,
                    "adaptive_util": res.adaptive.utilization,
                }
            )
        return rows

    _, us = timed(run, repeats=1)
    feasible = [r for r in rows if r["feasible"]]
    mean_red = np.mean([r["reduction"] for r in feasible]) if feasible else 0
    emit(
        "scheduler_capacity_sweep",
        us,
        f"points={len(rows)};mean_stall_reduction={mean_red:.3f};"
        f"min_cap_frac_feasible={min((r['capacity_frac'] for r in feasible), default=None)}",
        {"rows": rows},
    )


def bench_streaming_lm():
    """Host->HBM weight streaming viability per arch: utilization vs tokens
    per round (the l/e ratio analysis of SS III applied to LM serving).
    Decode rounds (small P) are load-bound -- streaming only pays off past
    the arithmetic-intensity breakeven, which we report per arch."""
    from repro.configs import ARCH_IDS, get_config
    from repro.core.pu import host_offload_config
    from repro.runtime.serving import plan_model_streaming

    pu = host_offload_config()
    sweep = (64, 1024, 16384, 131072)
    rows = []

    def run():
        rows.clear()
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            utils = {}
            for p in sweep:
                plan = plan_model_streaming(cfg, pu, batch_tokens=p)
                utils[p] = float(plan.summary()["adaptive_util"])
            breakeven = next(
                (p for p in sweep if utils[p] > 0.5), None
            )
            rows.append({"arch": arch, "util_by_tokens": utils,
                         "breakeven_tokens_50pct": breakeven})
        return rows

    _, us = timed(run, repeats=1)
    at_max = np.mean([r["util_by_tokens"][sweep[-1]] for r in rows])
    n_be = sum(1 for r in rows if r["breakeven_tokens_50pct"] is not None)
    emit(
        "streaming_plan_lm",
        us,
        f"archs={len(rows)};mean_util@{sweep[-1]}tok={at_max:.3f};"
        f"archs_reaching_50pct={n_be};note=decode(P=64)_is_load-bound_by_design",
        {"rows": rows},
    )


# --------------------------------------------------- plan subsystem -------


def bench_plan_suite(fast: bool):
    """repro.plan perf trajectory: adaptive-phase wall time, stall
    reduction, incremental-vs-reference speedup, schedule-search gains
    (beam/anneal vs the paper heuristic), load-bound early exit, multi-PU
    partitioning and plan-cache behaviour.  Emits BENCH_plan.json at the
    repo root so future PRs can diff."""
    import time as _time

    from repro.configs import get_config
    from repro.core.pu import PU_1X, PU_2X, host_offload_config
    from repro.core import scheduler as sched
    from repro.core import simulator as sim
    from repro.plan import PlanCache, SearchConfig, plan
    from repro.runtime.serving import model_gemms

    records = {}

    # --fast trims the anneal ladder: same record shape, quick signal
    # (the CI gate only runs against full-mode output)
    anneal_steps = 300 if fast else 1500

    def search_record(tiles, cap, heuristic):
        """Beam + anneal vs the heuristic on one workload."""
        out = {
            "heuristic_stall_reduction": heuristic.stall_reduction,
            "heuristic_wall_s": heuristic.plan_wall_s,
        }
        for strat, cfg in (
            ("beam", SearchConfig(strategy="beam")),
            ("anneal", SearchConfig(
                strategy="anneal", seed=0, anneal_steps=anneal_steps)),
        ):
            t0 = _time.perf_counter()
            sp = plan(tiles, cap, search=cfg)
            out[strat] = {
                "stall_reduction": sp.stall_reduction,
                "wall_s": _time.perf_counter() - t0,
                "relocations": len(sp.relocations()),
                "gain_vs_heuristic": (
                    sp.stall_reduction / heuristic.stall_reduction
                    if heuristic.stall_reduction > 0
                    else float("inf")
                ),
                "search": sp.search,
            }
        out["stall_reduction"] = out["anneal"]["stall_reduction"]
        out["search_gain"] = out["anneal"]["gain_vs_heuristic"]
        return out

    def run():
        records.clear()
        # ResNet workloads under memory pressure (adaptive phase active)
        r50_tiles = None
        for variant in (18, 50):
            layers = sim.resnet_gemm_layers(variant)
            tiles = sim.model_tiles(PU_2X, layers)
            cap = int(PU_2X.fast_mem_bytes * 0.25)
            t0 = _time.perf_counter()
            new = plan(tiles, cap)
            t_new = _time.perf_counter() - t0
            rec = {
                "tiles": len(tiles),
                "capacity_frac": 0.25,
                "adaptive_wall_s": t_new,
                "baseline_stall_s": new.baseline_stall,
                "adaptive_stall_s": new.total_stall,
                "stall_reduction": new.stall_reduction,
                "relocations": len(new.relocations()),
            }
            if not fast:
                # bit-identity vs the reference planner: full-scan on the
                # smaller net, bounded-scan on ResNet-50 (the full-scan
                # reference costs ~20 s there; the bound exercises the
                # same code paths)
                scan = None if variant == 18 else 6
                t0 = _time.perf_counter()
                ref = sched.reference_two_phase(
                    tiles, cap, max_window_scan=scan
                )
                rec["reference_wall_s"] = _time.perf_counter() - t0
                if scan is None:
                    rec["speedup"] = rec["reference_wall_s"] / t_new
                    got = new
                else:
                    rec["reference_window_scan"] = scan
                    t0 = _time.perf_counter()
                    got = plan(tiles, cap, max_window_scan=scan)
                    rec["speedup"] = rec["reference_wall_s"] / (
                        _time.perf_counter() - t0
                    )
                rec["bit_identical"] = (
                    [t.window for t in ref.adaptive.tiles] == list(got.windows)
                    and ref.adaptive.total_stall == got.total_stall
                )
            records[f"resnet{variant}"] = rec
            if variant == 50:
                r50_tiles = tiles
                records["search_resnet50"] = search_record(tiles, cap, new)

        # second search workload: ResNet-50 under tighter memory, where
        # annealing finds relocations the one-shot heuristic cannot
        cap_tight = int(PU_2X.fast_mem_bytes * 0.2)
        heur_tight = plan(r50_tiles, cap_tight)
        rec = search_record(r50_tiles, cap_tight, heur_tight)
        rec["capacity_frac"] = 0.2
        records["search_resnet50_tight"] = rec

        # one LM config: host->HBM streaming plan of a decode round --
        # load-bound by design, so the adaptive phase must detect it and
        # exit without burning wall time on a scan that can't help
        cfg = get_config("olmo-1b")
        gemms = model_gemms(cfg, batch_tokens=16)
        pu = host_offload_config()
        tiles = []
        for _, n, m, p in gemms:
            tiles.extend(pu.gemm_tiles(n, m, p))
        t0 = _time.perf_counter()
        lm_plan = plan(tiles, pu.fast_mem_bytes)
        records["olmo_1b_decode"] = {
            "tiles": len(tiles),
            "adaptive_wall_s": _time.perf_counter() - t0,
            "baseline_stall_s": lm_plan.baseline_stall,
            "adaptive_stall_s": lm_plan.total_stall,
            "stall_reduction": lm_plan.stall_reduction,
            "skipped_load_bound": lm_plan.skipped_load_bound,
        }

        # multi-PU partitioning: K=2 pipeline vs the best single PU
        layers = sim.resnet_gemm_layers(50)
        part = sim.simulate_partitioned([PU_1X, PU_2X], layers)
        single = max(
            sim.simulate_model(PU_1X, layers).fps_scheduled,
            sim.simulate_model(PU_2X, layers).fps_scheduled,
        )
        records["partition_resnet50_k2"] = {
            "fps": part.fps,
            "best_single_pu_fps": single,
            "pipeline_gain": part.fps / single,
            "stages": part.summary()["stages"],
        }

        # cache effectiveness: replanning an identical workload is free
        # (fresh cache so the cold path is exercised exactly once)
        tiles = sim.model_tiles(PU_2X, sim.resnet_gemm_layers(18))
        cache = PlanCache()
        t0 = _time.perf_counter()
        cache.get_or_plan(tiles, PU_2X.fast_mem_bytes)
        t_cold = _time.perf_counter() - t0
        t0 = _time.perf_counter()
        cache.get_or_plan(tiles, PU_2X.fast_mem_bytes)
        records["plan_cache"] = {
            "cold_plan_s": t_cold,
            "warm_plan_s": _time.perf_counter() - t0,
            "hits_gained": cache.stats()["hits"],
        }
        return records

    # no timed() here: its warmup pass would double the suite's wall time
    # and pre-warm the cache record
    t0 = _time.perf_counter()
    run()
    us = (_time.perf_counter() - t0) * 1e6
    r18 = records["resnet18"]
    r50 = records["resnet50"]
    part = records["partition_resnet50_k2"]
    s50 = records["search_resnet50"]
    derived = (
        f"r50_adaptive_s={r50['adaptive_wall_s']:.3f};"
        f"r18_stall_red={r18['stall_reduction']:.3f};"
        + (f"r18_speedup={r18['speedup']:.1f}x;" if "speedup" in r18 else "")
        + f"search_gain={s50['search_gain']:.2f}x;"
        f"olmo_skipped={records['olmo_1b_decode']['skipped_load_bound']};"
        f"k2_gain={part['pipeline_gain']:.2f}x;"
        f"cache_hits={records['plan_cache']['hits_gained']}"
    )
    emit("plan", us, derived, records)
    (ROOT / "BENCH_plan.json").write_text(json.dumps(records, indent=1))


def bench_stream_suite(fast: bool):
    """Stage-parallel streaming runtime vs the analytic pipeline model:
    execute ResNet-50 partitioned plans for K in {1, 2} through
    runtime.pipeline_exec and record measured throughput, the
    measured-vs-predicted bubble fraction, and the K=2 gain over the
    best single-PU executor; then auto-tune microbatch depth against a
    10% target bubble from the executed measurement and compare with the
    fixed M=8 baseline.  Emits BENCH_stream.json at the repo root; CI
    gates on gain >= 1.2x, bubble within 2x of prediction, and the
    auto-tuner hitting its band at no throughput cost."""
    import time as _time

    from repro.core.pu import PU_1X, PU_2X
    from repro.core import simulator as sim
    from repro.runtime.autotune import AutotuneConfig, tune_pipeline
    from repro.runtime.pipeline_exec import execute_partitioned_plan

    layers = sim.resnet_gemm_layers(50)
    # the >=1.2x CI gate is calibrated at M=8 (fewer microbatches grow
    # the fill bubble: gain at M=4 is ~1.19x); the whole suite runs in
    # well under a second, so smoke mode keeps the same M
    M = 8
    records = {"microbatches": M}

    def record(tag, pus):
        pplan = sim.simulate_partitioned(pus, layers)
        rep = execute_partitioned_plan(pplan, n_microbatches=M)
        records[tag] = {
            "pus": [pu.name for pu in pus],
            "stages": [
                {
                    "pu": t.pu,
                    "busy_s": t.busy_s,
                    "stall_s": t.stall_s,
                    "starve_s": t.starve_s,
                    "fetches": t.fetches,
                    "peak_resident_bytes": t.peak_resident_bytes,
                }
                for t in rep.stages
            ],
            "measured_fps": rep.measured_fps,
            "predicted_fps": rep.predicted_fps,
            "steady_fps": rep.steady_fps,
            "analytic_fps": pplan.fps,
            "bubble_measured": rep.bubble_measured,
            "bubble_predicted": rep.bubble_predicted,
            "makespan_s": rep.makespan_s,
            "wall_s": rep.wall_s,
            "max_concurrent_stages": rep.max_concurrent_stages,
        }
        return records[tag]

    def run():
        r1a = record("k1_pu1x", [PU_1X])
        r1b = record("k1_pu2x", [PU_2X])
        r2 = record("k2", [PU_1X, PU_2X])
        best = max(r1a["measured_fps"], r1b["measured_fps"])
        records["best_single_pu_fps"] = best
        records["k2_gain_measured"] = r2["measured_fps"] / best
        records["k2_bubble_vs_predicted"] = (
            r2["bubble_measured"] / max(r2["bubble_predicted"], 1e-12)
        )
        # auto-tuned microbatch depth on the K=2 partition: the tuner
        # must land the executed bubble within 10% of the 0.10 target
        # and lose no throughput against the fixed M=8 baseline
        pplan = sim.simulate_partitioned([PU_1X, PU_2X], layers)
        tuned = tune_pipeline(pplan, AutotuneConfig(target_bubble=0.10))
        records["autotune_k2"] = {
            "target_bubble": tuned.target_bubble,
            "tuned_m": tuned.n_microbatches,
            "analytic_m": tuned.analytic_m,
            "tuned_queue_depth": tuned.queue_depth,
            "bubble_measured": tuned.bubble_measured,
            "within_tolerance": tuned.within_tolerance,
            "measured_fps": tuned.measured_fps,
            "fixed_m8_fps": r2["measured_fps"],
            "fps_vs_fixed_m8": tuned.measured_fps / r2["measured_fps"],
            "trials": tuned.trials,
            "depth_trials": tuned.depth_trials,
        }
        return records

    # no timed(): its warmup pass would run the three pipelines twice
    t0 = _time.perf_counter()
    run()
    us = (_time.perf_counter() - t0) * 1e6
    r2 = records["k2"]
    at = records["autotune_k2"]
    derived = (
        f"M={M};k2_measured_fps={r2['measured_fps']:.1f};"
        f"k2_gain={records['k2_gain_measured']:.2f}x;"
        f"bubble={r2['bubble_measured']:.3f}"
        f"(pred {r2['bubble_predicted']:.3f});"
        f"autoM={at['tuned_m']}@bubble={at['bubble_measured']:.3f};"
        f"wall_s={r2['wall_s']:.2f}"
    )
    emit("stream", us, derived, records)
    (ROOT / "BENCH_stream.json").write_text(json.dumps(records, indent=1))


def bench_serve_suite(fast: bool):
    """Device-resident decode loop vs the legacy host-loop engine
    (DESIGN.md SS7): identical mixed-length traffic through both engines
    per model config, recording decode throughput, the jit trace deltas
    after warmup, greedy stream bit-identity, per-bucket prefill latency,
    and TTFT/TPOT percentiles under a Poisson arrival trace (fused and
    staged); plus the ``pipeline_decode`` record -- a K=2 --multi-pu
    engine serving the same traffic through the overlapped staged
    decode loop (end-to-end medians over paired trials + steady-state
    decode-phase rates), gated on greedy bit-identity with the
    single-PU device loop, on the executor's virtual clock reproducing
    the plan recurrence, and on the >=1.0x steady-state decode
    throughput floor vs the fused loop; a lane-group sweep
    (M in {1,2,4,auto} x K in {2,3}) records bubble fraction and
    tokens/s per point; plus the ``decode_kernels`` record -- the fused
    Pallas decode kernels A/B'd against the composed-XLA decode, per-op
    (decode-attention, MLP, QKV at the smoke configs' shapes) and
    end-to-end, gated on argmax-identical greedy streams and zero
    retraces (the speedup floor applies to compiled runs only; CPU runs
    the kernels interpreted).  Emits BENCH_serve.json at the repo root; CI
    gates on the >=1.5x speedup floor, a zero-retrace ceiling after
    warmup, and bit-identity on the dense configs (MoE capacity
    coupling legitimately perturbs logits under admission regrouping,
    so mixtral's stream equality is recorded but not gated)."""
    import time as _time

    import jax
    from repro.configs import get_config, smoke_variant
    from repro.models import api as model_api
    from repro.runtime.serving import ServeConfig, ServingEngine

    archs = ("olmo-1b", "mixtral-8x7b") if fast else (
        "olmo-1b", "mixtral-8x7b", "gemma3-12b"
    )
    bit_gated = {"olmo-1b", "gemma3-12b"}
    n_req = 8 if fast else 16
    max_new = 12 if fast else 16
    records = {"n_requests": n_req, "max_new_tokens": max_new, "configs": {}}

    def mk_engine(cfg, params, host, decode_kernels=False):
        return ServingEngine(
            cfg, params,
            ServeConfig(
                max_batch=4, max_len=96, max_new_tokens=max_new,
                host_sampling=host, decode_kernels=decode_kernels,
            ),
        )

    def traffic(cfg, seed=2):
        rng = np.random.default_rng(seed)
        return [
            rng.integers(0, cfg.vocab, int(l)).astype(np.int32)
            for l in rng.integers(6, 40, n_req)
        ]

    def run_one(eng, prompts):
        eng.warmup()
        traces0 = dict(eng.trace_counts)
        n0 = len(eng.completed)       # run_until_drained returns the
        t0 = _time.perf_counter()     # engine-lifetime completed list;
        for p in prompts:             # scope this trial's tokens/streams
            eng.submit(p.copy())      # so engines can be re-trialed
        eng.run_until_drained()
        wall = _time.perf_counter() - t0
        done = eng.completed[n0:]
        toks = sum(len(r.out_tokens) for r in done)
        # key by arrival order within the trial (uids are lifetime
        # counters and would shift between trials)
        streams = {
            i: list(r.out_tokens)
            for i, r in enumerate(sorted(done, key=lambda r: r.uid))
        }
        retraces = {
            k: eng.trace_counts[k] - traces0[k] for k in traces0
        }
        return toks / wall, wall, streams, retraces

    def decode_phase_rate(
        cfg, params, host, stream_pus=None, m=0, decode_kernels=False
    ):
        """Steady-state decode rate with prefill out of the timed window:
        admit a full batch, then time the pure decode drain.  Median over
        trials (single-run walls are jittery at smoke scale).  With
        ``stream_pus`` the engine decodes through the overlapped staged
        loop (m=0 auto-tunes the lane-group depth)."""
        trials = 3 if fast else 5
        decode_new = 48 if fast else 64
        rng = np.random.default_rng(9)
        rates = []
        for _ in range(trials):
            eng = ServingEngine(
                cfg, params,
                ServeConfig(
                    max_batch=4, max_len=decode_new + 40,
                    max_new_tokens=decode_new, host_sampling=host,
                    stream_pus=stream_pus, decode_microbatches=m,
                    decode_kernels=decode_kernels,
                ),
            )
            eng.warmup()
            for _ in range(4):
                eng.submit(
                    rng.integers(0, cfg.vocab, 24).astype(np.int32)
                )
            if host:
                while eng.pending:
                    slot = next(
                        i for i, s in enumerate(eng._slots) if s is None
                    )
                    eng._admit_host(slot, eng._queue.popleft())
            else:
                eng._admit_device()
            # tokens already emitted at admission (host keeps them in
            # req.out_tokens; the device engine holds them in out_buf and
            # mirrors the count in _slot_emitted)
            pre = sum(len(r.out_tokens) for r in eng.completed)
            for i, r in enumerate(eng._slots):
                if r is not None:
                    pre += (
                        len(r.out_tokens) if host
                        else int(eng._slot_emitted[i])
                    )
            t0 = _time.perf_counter()
            done = eng.run_until_drained()
            wall = _time.perf_counter() - t0
            toks = sum(len(r.out_tokens) for r in done) - pre
            rates.append(toks / wall)
        return float(np.median(rates))

    def run():
        records["configs"].clear()
        olmo_device = None             # (params, streams, tps) for the
        for arch in archs:             # pipeline_decode comparison below
            cfg = smoke_variant(get_config(arch))
            api = model_api.get_api(cfg)
            params = api.init_params(cfg, jax.random.PRNGKey(0))
            prompts = traffic(cfg)
            host = mk_engine(cfg, params, host=True)
            host_tps, host_wall, host_streams, _ = run_one(host, prompts)
            dev = mk_engine(cfg, params, host=False)
            dev_tps, dev_wall, dev_streams, retr = run_one(dev, prompts)
            if arch == "olmo-1b":
                olmo_device = (params, dev_streams, dev_tps)
            host_dec = decode_phase_rate(cfg, params, host=True)
            dev_dec = decode_phase_rate(cfg, params, host=False)
            rec = {
                "family": cfg.family,
                "host_tokens_per_s": host_tps,
                "device_tokens_per_s": dev_tps,
                "speedup": dev_tps / host_tps,
                "host_decode_tokens_per_s": host_dec,
                "device_decode_tokens_per_s": dev_dec,
                "decode_speedup": dev_dec / host_dec,
                "host_wall_s": host_wall,
                "device_wall_s": dev_wall,
                "retraces_after_warmup": sum(retr.values()),
                "retraces_by_kind": retr,
                "greedy_bit_identical": host_streams == dev_streams,
                "bit_gated": arch in bit_gated,
                "prefill_s_by_bucket": {
                    str(b): float(np.mean(ts))
                    for b, ts in sorted(dev.prefill_bucket_s.items())
                },
                "decode_traces_total": dev.trace_counts["decode"],
                "prefill_traces_total": dev.trace_counts["prefill"],
            }
            records["configs"][arch] = rec

        # true per-stage decode (--multi-pu): the overlapped staged loop
        # serves the same traffic as the single-PU device engine.  The
        # headline K=2 auto-tuned record is the median over paired
        # in-process trials (single-run walls are jittery at smoke
        # scale) and is CI-gated on greedy bit-identity, the virtual
        # clock reproducing the plan recurrence, zero retraces after
        # warmup, and the >=1.0x throughput floor vs the fused loop.
        # The lane-group sweep (M x K) below is informational.
        import dataclasses

        from repro.core.pu import host_offload_config, tpu_v5e_config

        def stage_pus(k):
            return [
                host_offload_config() if i % 2 == 0 else tpu_v5e_config()
                for i in range(k)
            ]

        def staged_engine(cfg, params, k, m):
            return ServingEngine(
                cfg, params,
                ServeConfig(
                    max_batch=4, max_len=96, max_new_tokens=max_new,
                    stream_pus=stage_pus(k), decode_microbatches=m,
                ),
            )

        cfg = smoke_variant(get_config("olmo-1b"))
        assert olmo_device is not None, "olmo-1b left the arch list"
        params, dev_streams, _ = olmo_device
        prompts = traffic(cfg)
        trials = 3 if fast else 5
        staged = staged_engine(cfg, params, 2, 0)
        base = mk_engine(cfg, params, host=False)
        ratios, st_rates, dev_rates, walls = [], [], [], []
        bit, retr_total = True, 0
        for _ in range(trials):
            st_tps, st_wall, st_streams, st_retr = run_one(staged, prompts)
            dev_tps, _, base_streams, _ = run_one(base, prompts)
            ratios.append(st_tps / dev_tps)
            st_rates.append(st_tps)
            dev_rates.append(dev_tps)
            walls.append(st_wall)
            bit = bit and st_streams == dev_streams == base_streams
            retr_total += sum(st_retr.values())
        st = staged.stats()

        # the gated ratio is the steady-state decode phase (prefill and
        # admission barriers out of the timed window, same methodology
        # as the per-config decode_speedup gate): this is the loop the
        # overlap optimizes, and end-to-end walls at smoke scale are
        # admission-jitter-bound (the e2e ratio stays recorded below)
        st_dec = decode_phase_rate(
            cfg, params, host=False, stream_pus=stage_pus(2)
        )
        dev_dec = decode_phase_rate(cfg, params, host=False)

        # lane-group sweep: M in {1 (serial reference), 2, 4, auto} x
        # K in {2, 3} stages; K=3 needs one model layer per stage, so
        # it runs on a 4-layer variant with its own fused baseline
        sweep = []
        for k in (2, 3):
            if k == 2:
                s_cfg, s_params = cfg, params
                s_base = float(np.median(dev_rates))
                ref_streams = dev_streams
            else:
                s_cfg = dataclasses.replace(cfg, n_layers=4)
                s_api = model_api.get_api(s_cfg)
                s_params = s_api.init_params(s_cfg, jax.random.PRNGKey(0))
                ref_eng = mk_engine(s_cfg, s_params, host=False)
                s_base, _, ref_streams, _ = run_one(
                    ref_eng, traffic(s_cfg)
                )
            for m in (1, 2, 4, 0):
                eng = staged_engine(s_cfg, s_params, k, m)
                tps, _, streams, retr = run_one(eng, traffic(s_cfg))
                es = eng.stats()
                sweep.append({
                    "k": k,
                    "m_requested": m,
                    "m": int(es["stage_decode_microbatches"]),
                    "tokens_per_s": tps,
                    "e2e_vs_single_pu": tps / s_base,
                    "bubble": float(es["stage_decode_bubble"]),
                    "clock_ok": bool(es["stage_decode_clock_ok"]),
                    "greedy_bit_identical": streams == ref_streams,
                    "retraces_after_warmup": sum(retr.values()),
                })

        records["pipeline_decode"] = {
            "arch": "olmo-1b",
            "stages": int(st["partition_stages"]),
            "stage_decode_rounds": st["stage_decode_rounds"],
            "stage_layers": [
                int(st[k]) for k in sorted(st) if k.endswith("_decode_layers")
            ],
            "clock_ok": bool(st["stage_decode_clock_ok"]),
            "greedy_bit_identical": bit,
            "microbatches": int(st["stage_decode_microbatches"]),
            "queue_depth": int(st["stage_decode_queue_depth"]),
            "coalesced": bool(st["stage_decode_coalesced"]),
            "bubble": float(st["stage_decode_bubble"]),
            "trials": trials,
            "decode_tokens_per_s": st_dec,
            "single_pu_decode_tokens_per_s": dev_dec,
            "vs_single_pu": st_dec / dev_dec,
            "tokens_per_s": float(np.median(st_rates)),
            "single_pu_tokens_per_s": float(np.median(dev_rates)),
            "e2e_vs_single_pu": float(np.median(ratios)),
            "retraces_after_warmup": retr_total,
            "wall_s": float(np.median(walls)),
            "sweep": sweep,
        }

        # fused Pallas decode kernels (--decode-kernels): per-op
        # microbenchmark (fused kernel vs the same math composed from
        # jitted XLA primitives, at the smoke configs' decode shapes)
        # plus an end-to-end engine A/B on identical traffic.  On CPU
        # the kernels run through the Pallas interpreter
        # (interpreted=true) so the timing ratios are recorded for
        # attribution but the speedup floor only gates compiled (TPU)
        # runs; argmax-identity and the zero-retrace ceiling gate
        # everywhere (benchmarks/check_regression.py).
        import functools

        import jax.numpy as jnp

        from repro.kernels import (
            decode_attention_ref,
            default_interpret,
            fused_decode_attention,
            fused_mlp,
            fused_mlp_ref,
            fused_qkv,
            fused_qkv_ref,
        )
        from repro.kernels import dispatch as kdispatch

        krec = {
            "interpreted": bool(default_interpret()),
            "per_op": {},
            "configs": {},
        }
        kop_archs = ("olmo-1b",) if fast else ("olmo-1b", "gemma3-12b")
        kb, ksk = 4, 96
        rngk = np.random.default_rng(7)

        def _tol_ok(a, b, atol=5e-2):
            return bool(
                np.allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    atol=atol,
                )
            )

        for arch in kop_archs:
            kcfg = smoke_variant(get_config(arch))
            d, f = kcfg.d_model, kcfg.d_ff
            hq, hkv, hd = kcfg.n_heads, kcfg.n_kv_heads, kcfg.head_dim
            blocks = kdispatch.kernel_blocks(kcfg, sk=ksk)
            mk = lambda *s: jnp.asarray(
                rngk.normal(size=s) * 0.05, jnp.bfloat16
            )
            x = mk(kb, d)
            qb, kbuf, vbuf = mk(kb, hq, hd), mk(kb, ksk, hkv, hd), mk(kb, ksk, hkv, hd)
            wo = mk(hq * hd, d)
            wq_, wk_, wv_ = mk(d, hq * hd), mk(d, hkv * hd), mk(d, hkv * hd)
            wu, wd_ = mk(d, f), mk(f, d)
            wg = mk(d, f) if kcfg.mlp == "swiglu" else None
            pos = jnp.asarray(rngk.integers(0, ksk, kb), jnp.int32)
            vlen = jnp.asarray(rngk.integers(1, ksk + 1, kb), jnp.int32)

            qkv_kw = dict(
                n_heads=hq, n_kv_heads=hkv, head_dim=hd, rope=True,
                theta=float(kcfg.rope_theta),
            )
            xla_qkv = jax.jit(functools.partial(fused_qkv_ref, **qkv_kw))
            ops_tbl = {
                "decode_attention": (
                    lambda: fused_decode_attention(
                        qb, kbuf, vbuf, wo, q_positions=pos,
                        kv_valid_len=vlen, block_s=blocks["block_s"],
                    ),
                    jax.jit(
                        lambda: decode_attention_ref(
                            qb, kbuf, vbuf, wo, q_positions=pos,
                            kv_valid_len=vlen,
                        )
                    ),
                ),
                "mlp": (
                    lambda: fused_mlp(
                        x, wu, wg, None, wd_, None, act=kcfg.mlp,
                        block_f=blocks["block_f"],
                    ),
                    jax.jit(
                        lambda: fused_mlp_ref(
                            x, wu, wg, None, wd_, None, act=kcfg.mlp
                        )
                    ),
                ),
                "qkv": (
                    lambda: fused_qkv(
                        x, wq_, wk_, wv_, None, None, None, pos,
                        block_m=blocks["block_m"], **qkv_kw,
                    ),
                    lambda: xla_qkv(x, wq_, wk_, wv_, None, None, None, pos),
                ),
            }
            for op, (kfn, xfn) in ops_tbl.items():
                yk, us_k = timed(
                    lambda: jax.block_until_ready(kfn()), repeats=3
                )
                yx, us_x = timed(
                    lambda: jax.block_until_ready(xfn()), repeats=3
                )
                ya = jax.tree.leaves(yk)
                yb = jax.tree.leaves(yx)
                krec["per_op"][f"{arch}/{op}"] = {
                    "kernel_us": us_k,
                    "xla_us": us_x,
                    "speedup": us_x / us_k,
                    "ok": all(_tol_ok(a, b) for a, b in zip(ya, yb)),
                }

        for arch in kop_archs:
            kcfg = smoke_variant(get_config(arch))
            kapi = model_api.get_api(kcfg)
            kparams = kapi.init_params(kcfg, jax.random.PRNGKey(0))
            kprompts = traffic(kcfg)
            xeng = mk_engine(kcfg, kparams, host=False)
            x_tps, _, x_streams, _ = run_one(xeng, kprompts)
            keng = mk_engine(kcfg, kparams, host=False, decode_kernels=True)
            k_tps, _, k_streams, kretr = run_one(keng, kprompts)
            k_dec = decode_phase_rate(
                kcfg, kparams, host=False, decode_kernels=True
            )
            x_dec = decode_phase_rate(kcfg, kparams, host=False)
            krec["configs"][arch] = {
                "kernel_tokens_per_s": k_tps,
                "xla_tokens_per_s": x_tps,
                "e2e_speedup": k_tps / x_tps,
                "kernel_decode_tokens_per_s": k_dec,
                "xla_decode_tokens_per_s": x_dec,
                "decode_speedup": k_dec / x_dec,
                "argmax_identical": k_streams == x_streams,
                "retraces_after_warmup": sum(kretr.values()),
            }
        records["decode_kernels"] = krec

        # TTFT / TPOT under a Poisson arrival trace (olmo): requests
        # arrive on the open-loop clock; the engine keeps fusing decode
        # blocks between admissions.  Both the fused device loop and the
        # K=2 overlapped staged loop serve the same trace.
        n_arr = 6 if fast else 12

        def poisson_trace(eng):
            eng.warmup()
            rng = np.random.default_rng(5)
            gaps = rng.exponential(0.08, n_arr)
            arrivals = np.cumsum(gaps)
            ps = traffic(cfg, seed=6)
            t0 = _time.perf_counter()
            i = 0
            while i < n_arr or eng.pending or eng.active:
                now = _time.perf_counter() - t0
                while i < n_arr and arrivals[i] <= now:
                    eng.submit(ps[i % len(ps)].copy())
                    i += 1
                if eng.pending or eng.active:
                    eng.step()
                elif i < n_arr:
                    _time.sleep(min(0.005, arrivals[i] - now))
            ttfts = sorted(
                r.ttft_s for r in eng.completed if r.ttft_s is not None
            )
            tpots = sorted(
                r.tpot_s for r in eng.completed if r.tpot_s is not None
            )
            return ttfts, tpots

        cfg = smoke_variant(get_config("olmo-1b"))
        api = model_api.get_api(cfg)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        ttfts, tpots = poisson_trace(mk_engine(cfg, params, host=False))
        st_ttfts, st_tpots = poisson_trace(
            staged_engine(cfg, params, 2, 0)
        )
        records["ttft_poisson"] = {
            "arrival_rate_hz": 1.0 / 0.08,
            "requests": n_arr,
            "p50_s": float(np.percentile(ttfts, 50)),
            "p95_s": float(np.percentile(ttfts, 95)),
            "max_s": float(ttfts[-1]),
            "tpot_p50_s": float(np.percentile(tpots, 50)),
            "tpot_p95_s": float(np.percentile(tpots, 95)),
            "staged": {
                "ttft_p50_s": float(np.percentile(st_ttfts, 50)),
                "ttft_p95_s": float(np.percentile(st_ttfts, 95)),
                "tpot_p50_s": float(np.percentile(st_tpots, 50)),
                "tpot_p95_s": float(np.percentile(st_tpots, 95)),
            },
        }
        return records

    t0 = time.perf_counter()
    run()
    us = (time.perf_counter() - t0) * 1e6
    parts = []
    for arch, rec in records["configs"].items():
        parts.append(
            f"{arch}:x{rec['speedup']:.1f}/dec x{rec['decode_speedup']:.2f}"
            f"(retr={rec['retraces_after_warmup']}"
            f",bit={int(rec['greedy_bit_identical'])})"
        )
    tt = records["ttft_poisson"]
    pd = records["pipeline_decode"]
    derived = (
        ";".join(parts)
        + f";ttft_p50={tt['p50_s']:.3f}s;ttft_p95={tt['p95_s']:.3f}s"
        + f";tpot_p50={tt['tpot_p50_s']:.4f}s"
        + f";staged_k2:x{pd['vs_single_pu']:.2f}"
        f"(m={pd['microbatches']},bub={pd['bubble']:.2f})"
    )
    dk = records["decode_kernels"]
    dko = dk["configs"]["olmo-1b"]
    derived += (
        f";dk:x{dko['decode_speedup']:.2f}"
        f"(bit={int(dko['argmax_identical'])}"
        f",retr={dko['retraces_after_warmup']}"
        f",interp={int(dk['interpreted'])})"
    )
    emit("serve", us, derived, records)
    (ROOT / "BENCH_serve.json").write_text(json.dumps(records, indent=1))


# -------------------------------------------------------- end-to-end ------


def bench_train_smoke():
    from repro.configs import get_config, smoke_variant
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import single_device_mesh
    from repro.optim import AdamWConfig
    from repro.parallel.sharding import RULES_FSDP_TP
    from repro.runtime.train_loop import TrainLoop, TrainLoopConfig
    import tempfile

    cfg = smoke_variant(get_config("olmo-1b"))
    shape = ShapeConfig("bench", seq_len=128, global_batch=8, kind="train")
    with tempfile.TemporaryDirectory() as d:
        loop = TrainLoop(
            cfg, shape, single_device_mesh(), RULES_FSDP_TP,
            TrainLoopConfig(steps=8, ckpt_every=100, ckpt_dir=d, log_every=0),
            opt_cfg=AdamWConfig(lr=1e-3),
        )
        t0 = time.perf_counter()
        out = loop.run()
        dt = time.perf_counter() - t0
    steps_done = len(loop.records)
    wall = [r.wall_s for r in loop.records[2:]]
    us = float(np.mean(wall)) * 1e6 if wall else dt / max(steps_done, 1) * 1e6
    tokens_s = shape.seq_len * shape.global_batch / (us / 1e6)
    emit(
        "train_smoke",
        us,
        f"steps={steps_done};tokens_per_s={tokens_s:.0f};final_loss={out['final_loss']:.3f}",
    )


def bench_serve_smoke():
    import jax
    from repro.configs import get_config, smoke_variant
    from repro.models import api as model_api
    from repro.runtime.serving import ServeConfig, ServingEngine

    cfg = smoke_variant(get_config("olmo-1b"))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(
        cfg, params, ServeConfig(max_batch=4, max_len=96, max_new_tokens=16)
    )
    rng = np.random.default_rng(0)
    for _ in range(8):
        eng.submit(rng.integers(0, cfg.vocab, 16).astype(np.int32))
    t0 = time.perf_counter()
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    s = eng.stats()
    emit(
        "serve_smoke",
        dt / max(s["rounds"], 1) * 1e6,
        f"tokens={s['tokens']:.0f};tokens_per_s={s['tokens']/dt:.1f};"
        f"mean_ttft_s={s['mean_ttft_s']:.2f}",
    )


# ----------------------------------------------------------- roofline -----


def bench_roofline_summary():
    dr = ROOT / "experiments" / "dryrun"
    rows = []
    if dr.exists():
        for f in sorted(dr.glob("*.json")):
            rec = json.loads(f.read_text())
            if rec.get("status") != "ok":
                continue
            r = rec["roofline"]
            rows.append(
                {
                    "cell": f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
                    + (f"/{rec['rules']}" if rec.get("rules", "fsdp_tp") != "fsdp_tp" else ""),
                    "dominant": r["dominant"],
                    "bound_ms": r["bound_s"] * 1e3,
                    "fraction": r["roofline_fraction"],
                }
            )
    if not rows:
        emit("roofline_summary", 0.0, "no dryrun artifacts yet")
        return
    worst = min(rows, key=lambda r: r["fraction"])
    best = max(rows, key=lambda r: r["fraction"])
    emit(
        "roofline_summary",
        0.0,
        f"cells={len(rows)};best={best['cell']}@{best['fraction']:.2f};"
        f"worst={worst['cell']}@{worst['fraction']:.2f}",
        {"rows": rows},
    )


BENCHES = {
    "table1_resnet18": lambda fast: bench_table1(18),
    "table1_resnet50": lambda fast: bench_table1(50),
    "fig5a_layer_latency": lambda fast: bench_fig5a(),
    "fig5bc_scheduler_ratios": lambda fast: bench_fig5bc(),
    "efficiency_98pct": lambda fast: bench_efficiency(),
    "wrb_out_of_order": lambda fast: bench_wrb(),
    "aimc_noise_snr": lambda fast: bench_aimc(),
    "kernel_int8_gemm": bench_kernel_gemm,
    "kernel_im2col": bench_kernel_im2col,
    "scheduler_capacity_sweep": lambda fast: bench_scheduler_sweep(),
    "streaming_plan_lm": lambda fast: bench_streaming_lm(),
    "plan": bench_plan_suite,
    "stream": bench_stream_suite,
    "serve": bench_serve_suite,
    "train_smoke": lambda fast: bench_train_smoke(),
    "serve_smoke": lambda fast: bench_serve_smoke(),
    "roofline_summary": lambda fast: bench_roofline_summary(),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and args.only != name:
            continue
        try:
            fn(args.fast)
        except Exception as e:  # keep the harness running
            emit(name, -1.0, f"ERROR:{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
