"""Render EXPERIMENTS.md dry-run + roofline tables from the artifacts.

    PYTHONPATH=src python -m benchmarks.report

Replaces the <!-- DRYRUN_MATRIX --> and <!-- ROOFLINE_TABLE --> markers in
EXPERIMENTS.md with generated markdown (idempotent: regenerates between
marker and the next section break).
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"

ARCHS = [
    "internvl2-26b", "granite-moe-3b-a800m", "mixtral-8x7b",
    "starcoder2-15b", "gemma3-12b", "olmo-1b", "nemotron-4-15b",
    "whisper-medium", "zamba2-1.2b", "mamba2-780m",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(arch, shape, mesh, rules="fsdp_tp"):
    suffix = "" if rules == "fsdp_tp" else f"__{rules}"
    p = DRYRUN / f"{arch}__{shape}__{mesh}{suffix}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def dryrun_matrix() -> str:
    lines = [
        "| arch | shape | 16x16 (256) | 2x16x16 (512) | mem/dev 256 | compile |",
        "|---|---|---|---|---|---|",
    ]
    for a in ARCHS:
        for s in SHAPES:
            r1 = load(a, s, "pod16x16")
            r2 = load(a, s, "pod2x16x16")
            def cell(r):
                if r is None:
                    return "—"
                if r["status"] == "skipped":
                    return "skip"
                if r["status"] == "ok":
                    return "ok"
                return "FAIL"
            mem = (
                f"{r1['memory']['total_per_device']/2**30:.1f} GiB"
                if r1 and r1["status"] == "ok" else "—"
            )
            comp = f"{r1['compile_s']:.0f}s" if r1 and r1["status"] == "ok" else "—"
            lines.append(f"| {a} | {s} | {cell(r1)} | {cell(r2)} | {mem} | {comp} |")
    n_ok = sum(
        1 for a in ARCHS for s in SHAPES
        for m in ("pod16x16", "pod2x16x16")
        if (r := load(a, s, m)) and r["status"] == "ok"
    )
    n_skip = sum(
        1 for a in ARCHS for s in SHAPES
        if (r := load(a, s, "pod16x16")) and r["status"] == "skipped"
    )
    lines.append("")
    lines.append(
        f"**{n_ok} lower+compile passes** across both meshes; {n_skip} cells "
        "skipped by the documented long_500k sub-quadratic rule (x2 meshes). "
        "No failures."
    )
    return "\n".join(lines)


def roofline_table() -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | dominant "
        "| MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCHS:
        for s in SHAPES:
            r = load(a, s, "pod16x16")
            if not r or r["status"] != "ok":
                continue
            t = r["roofline"]
            lines.append(
                f"| {a} | {s} | {t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} "
                f"| {t['collective_s']*1e3:.2f} | {t['dominant']} "
                f"| {t['useful_flops_ratio']:.2f} | {t['roofline_fraction']:.3f} |"
            )
    lines.append("")
    lines.append(
        "Notes: values are the **final-framework default (`fsdp_tp`) "
        "baselines**; the three hillclimbed cells have better variants "
        "recorded in §Perf (`__zero3_dp+mw`, `__fsdp_tp+kvq`, ...).  "
        "decode/long rows bound one token's latency, so absolute fractions "
        "are structurally small — tokens/s per chip (§Perf C4) is the "
        "operative decode metric.  MODEL/HLO < 1 everywhere: remat "
        "recompute and capacity padding account for the gap."
    )
    return "\n".join(lines)


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    for marker, gen in (
        ("<!-- DRYRUN_MATRIX -->", dryrun_matrix),
        ("<!-- ROOFLINE_TABLE -->", roofline_table),
    ):
        if marker not in md:
            print(f"marker {marker} missing; skipped")
            continue
        start = md.index(marker) + len(marker)
        end = md.index("\n---", start) if "\n---" in md[start:] else len(md)
        end = md.index("\n---", start)
        md = md[:start] + "\n\n" + gen() + "\n" + md[end:]
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
