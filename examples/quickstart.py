"""Quickstart: the paper's machinery in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. INT8 GEMM through the systolic-array Pallas kernel (the PU datapath).
2. A conv layer executed as im2col + GEMM (the paper's unified dataflow).
3. The two-phase weight-transfer scheduler hiding load stalls.
4. One of the assigned LM architectures doing a forward + decode step.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.pu import PU_2X
from repro.core import scheduler
from repro.core.pu import TileCost
from repro.kernels import ops
from repro.models import api as model_api


def main():
    rng = np.random.default_rng(0)

    # 1. INT8 GEMM with fused bias + power-of-two shift + ReLU ------------
    w = jnp.asarray(rng.integers(-127, 128, (64, 128), dtype=np.int8))
    x = jnp.asarray(rng.integers(-127, 128, (128, 32), dtype=np.int8))
    bias = jnp.asarray(rng.integers(-500, 500, (64,), dtype=np.int32))
    y = ops.int8_gemm(w, x, bias, shift=7, relu=True)
    print(f"1. int8_gemm: {w.shape} @ {x.shape} -> {y.shape} {y.dtype}, "
          f"range [{int(y.min())}, {int(y.max())}]")

    # 2. Conv-as-GEMM (paper Fig. 3) --------------------------------------
    img = jnp.asarray(rng.integers(-64, 64, (16, 16, 8), dtype=np.int8))
    k = jnp.asarray(rng.integers(-64, 64, (3, 3, 8, 16), dtype=np.int8))
    out = ops.conv2d_int8(img, k, k=3, stride=1, pad=1, shift=8, relu=True)
    print(f"2. conv-as-GEMM: img {img.shape} * w {k.shape} -> {out.shape}")

    # 3. Two-phase weight-transfer scheduling (paper SS III) --------------
    # three tiles; tile2's load is too slow for tile1's short window but
    # fits tile0's long one -> the adaptive phase relocates it.
    tiles = [
        TileCost(load_s=1.0, exec_s=6.0, mem_bytes=10),
        TileCost(load_s=1.0, exec_s=1.0, mem_bytes=10),
        TileCost(load_s=4.0, exec_s=1.0, mem_bytes=10),
    ]
    res = scheduler.two_phase(tiles, capacity=100)
    print(f"3. scheduler: baseline stall {res.baseline.total_stall:.1f}s -> "
          f"adaptive {res.adaptive.total_stall:.1f}s "
          f"(reduction {res.stall_reduction:.0%}, "
          f"utilization {res.adaptive.utilization:.0%})")

    # 4. An assigned architecture: forward + one decode step --------------
    cfg = smoke_variant(get_config("olmo-1b"))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 16)), jnp.int32)
    logits, cache = api.prefill(cfg, params, {"tokens": tokens})
    next_tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = api.decode_step(cfg, params, cache, next_tok, jnp.int32(16))
    print(f"4. olmo-1b (smoke): prefill logits {logits.shape}, "
          f"greedy next token {int(next_tok[0, 0])}, decode logits {logits2.shape}")

    print("\nquickstart OK")


if __name__ == "__main__":
    main()
