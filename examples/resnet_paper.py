"""Paper-faithful reproduction driver: INT8 ResNet inference through the
systolic-array dataflow + the two-phase weight-transfer schedule.

    PYTHONPATH=src python examples/resnet_paper.py [--variant 18|50] [--image-size 56]

Steps, mirroring the paper's SS IV-V evaluation:
  1. Build the quantized (power-of-two scales) ResNet.
  2. Run one INT8 inference through im2col + int8 GEMM Pallas kernels
     (interpret mode on CPU; the kernels' BlockSpecs target TPU VMEM).
  3. Tile all conv/FC weights into R_SA x M_v tiles and run the two-phase
     scheduler against the PU's URAM capacity -- Fig. 5(b,c).
  4. Report the simulated Table I row (FPS / FPS-per-TOPS for 5x PU_1x +
     5x PU_2x on the Alveo U50) next to the paper's measured values.
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.pu import PU_1X, PU_2X
from repro.core import scheduler as sched
from repro.core import simulator as sim
from repro.models import resnet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", type=int, default=18, choices=(18, 50))
    ap.add_argument("--image-size", type=int, default=56,
                    help="reduced from 224 for CPU wall-time; dataflow identical")
    args = ap.parse_args()

    # 1. quantized model ---------------------------------------------------
    params = resnet.init_params(args.variant, jax.random.PRNGKey(0))
    n_params = sum(
        int(np.prod(p["w"].q.shape)) for p in params.values()
    )
    print(f"ResNet-{args.variant}: {n_params/1e6:.1f}M int8 weights "
          f"(power-of-two scales)")

    # 2. one INT8 inference through the kernels -----------------------------
    rng = np.random.default_rng(0)
    img = jnp.asarray(
        rng.integers(-100, 100, (args.image_size, args.image_size, 3), dtype=np.int8)
    )
    t0 = time.perf_counter()
    logits = resnet.forward_int8(args.variant, params, img)
    dt = time.perf_counter() - t0
    top5 = np.argsort(np.asarray(logits))[-5:][::-1]
    print(f"int8 forward ({args.image_size}x{args.image_size}): "
          f"{dt*1e3:.0f} ms on CPU-interpret, top-5 classes {top5.tolist()}")

    # 3. weight-transfer schedule (Fig. 5b,c) -------------------------------
    layers = sim.resnet_gemm_layers(args.variant)
    for pu in (PU_2X, PU_1X):
        tiles = sim.model_tiles(pu, layers)
        res = sched.two_phase(tiles, capacity=pu.fast_mem_bytes)
        weight_mb = sum(t.mem_bytes for t in tiles) / 2**20
        cap_mb = pu.fast_mem_bytes / 2**20
        print(
            f"{pu.name}: {len(tiles)} tiles, weights {weight_mb:.1f} MiB vs "
            f"URAM {cap_mb:.1f} MiB -> baseline stall "
            f"{res.baseline.total_stall*1e3:.3f} ms, adaptive "
            f"{res.adaptive.total_stall*1e3:.3f} ms "
            f"(hidden {res.stall_reduction:.0%}); "
            f"utilization {res.adaptive.utilization:.1%}"
        )

    # 4. Table I row ---------------------------------------------------------
    s1 = sim.simulate_model(PU_1X, layers)
    s2 = sim.simulate_model(PU_2X, layers)
    fleet = sim.FleetSim(sims=[("pu1x", s1, 5), ("pu2x", s2, 5)])
    paper = {18: (1237.7, 268.6), 50: (584.9, 126.9)}[args.variant]
    print(
        f"\nTable I (5x PU_1x + 5x PU_2x, {fleet.tops:.3f} TOPS):\n"
        f"  simulated  {fleet.fps:8.1f} FPS   {fleet.fps_per_tops:6.1f} FPS/TOPS\n"
        f"  paper      {paper[0]:8.1f} FPS   {paper[1]:6.1f} FPS/TOPS\n"
        f"  deviation  {abs(fleet.fps-paper[0])/paper[0]:8.1%}"
    )


if __name__ == "__main__":
    main()
