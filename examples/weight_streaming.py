"""The paper's scheduler across three memory hierarchies.

    PYTHONPATH=src python examples/weight_streaming.py [--arch mixtral-8x7b]

The two-phase heuristic is hierarchy-agnostic: the same code plans

  1. URAM @ FPGA   -- the paper's setting (ResNet tiles vs 2 MiB URAM),
  2. VMEM @ TPU    -- Pallas block-pipeline granularity on one v5e core,
  3. host->HBM @ TPU -- models larger than device HBM (the generalization
                        the paper gestures at in SS V).

For each level we print capacity pressure, baseline vs adaptive stalls and
the achieved compute utilization.
"""
import argparse

from repro.configs import ARCH_IDS, get_config
from repro.core.pu import PU_2X, host_offload_config, tpu_v5e_config
from repro.core import scheduler as sched
from repro.core import simulator as sim
from repro.runtime.serving import plan_model_streaming


def show(name, plan_summary):
    s = plan_summary
    if s["weight_bytes"] == 0:
        print(f"  {name:18s} INFEASIBLE: a single tile exceeds this "
              f"memory level's capacity (sub-tile first)")
        return
    pressure = s["weight_bytes"] / s["capacity_bytes"]
    print(
        f"  {name:18s} tiles={s['tiles']:5.0f}  "
        f"weights/capacity={pressure:7.2f}x  "
        f"stall: base {s['baseline_stall_s']*1e3:9.3f} ms -> "
        f"adaptive {s['adaptive_stall_s']*1e3:9.3f} ms  "
        f"util {s['adaptive_util']:6.1%}"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=ARCH_IDS)
    ap.add_argument("--batch-tokens", type=int, default=64)
    args = ap.parse_args()

    # 1. the paper's own level: ResNet tiles vs URAM ------------------------
    print("level 1: URAM @ FPGA (paper SS III-V, ResNet-50 on PU_2x)")
    layers = sim.resnet_gemm_layers(50)
    tiles = sim.model_tiles(PU_2X, layers)
    res = sched.two_phase(tiles, capacity=PU_2X.fast_mem_bytes)
    print(
        f"  resnet50/pu2x      tiles={len(tiles):5d}  "
        f"weights/capacity={sum(t.mem_bytes for t in tiles)/PU_2X.fast_mem_bytes:7.2f}x  "
        f"stall: base {res.baseline.total_stall*1e3:9.3f} ms -> "
        f"adaptive {res.adaptive.total_stall*1e3:9.3f} ms  "
        f"util {res.adaptive.utilization:6.1%}"
    )

    # 2. VMEM @ TPU ----------------------------------------------------------
    # At VMEM scale the schedulable tile is a Pallas *block* (R_SA = 128
    # rows), not a whole weight matrix -- whole matrices exceed the VMEM
    # budget, exactly why the kernel's BlockSpec tiling exists.
    print(f"\nlevel 2: VMEM @ TPU v5e ({args.arch}, decode round, "
          f"{args.batch_tokens} tokens, 128-row Pallas-block tiles)")
    from repro.core.streaming import gemm_sequence_tiles, plan_streaming
    from repro.runtime.serving import model_gemms

    cfg = get_config(args.arch)
    pu_vmem = tpu_v5e_config()
    per_layer = len(model_gemms(cfg, args.batch_tokens)) // cfg.n_layers
    block_tiles = gemm_sequence_tiles(
        model_gemms(cfg, args.batch_tokens)[:per_layer], pu_vmem
    )[:400]  # one layer of 128-row blocks; the plan repeats per layer
    plan = plan_streaming(block_tiles, pu_vmem)
    show(f"{args.arch} (1 layer)", plan.summary())

    # 3. host offload ---------------------------------------------------------
    print(f"\nlevel 3: host->HBM offload (weights exceed device HBM)")
    for arch in (args.arch, "internvl2-26b"):
        cfg = get_config(arch)
        gb = cfg.param_count() / 2**30
        plan = plan_model_streaming(cfg, host_offload_config(), args.batch_tokens)
        print(f"  [{arch}: {gb:.1f} GiB int8 weights vs 16 GiB HBM]")
        show(arch, plan.summary())


if __name__ == "__main__":
    main()
