"""Training driver: fault-tolerant loop on an assigned architecture.

    # reduced config, a few hundred steps on CPU:
    PYTHONPATH=src python examples/train_lm.py --steps 300

    # ~100M-parameter config (olmo-1b family at d_model 768, 12 layers):
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300

Demonstrates: deterministic data pipeline, checkpoint/auto-resume (kill it
mid-run and restart with the same command), straggler logging, loss curve.
"""
import argparse
import dataclasses
import json
from pathlib import Path

from repro.configs import get_config, smoke_variant
from repro.configs.base import ShapeConfig
from repro.launch.mesh import single_device_mesh
from repro.optim import AdamWConfig
from repro.parallel.sharding import RULES_FSDP_TP
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig


def build_config(size: str):
    base = get_config("olmo-1b")
    if size == "smoke":
        return smoke_variant(base)
    if size == "100m":
        # ~100M params: 12 x 768, ff 3072, vocab 32k
        return dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
            head_dim=64, d_ff=3072, vocab=32000,
        )
    raise ValueError(size)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="smoke", choices=("smoke", "100m"))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = build_config(args.size)
    shape = ShapeConfig(
        "train",
        seq_len=args.seq_len or (256 if args.size == "100m" else 128),
        global_batch=args.batch or 8,
        kind="train",
    )
    n_params = cfg.param_count()
    print(f"config: {cfg.n_layers}L d{cfg.d_model} vocab{cfg.vocab} "
          f"= {n_params/1e6:.0f}M params; shape {shape.seq_len}x{shape.global_batch}")

    loop = TrainLoop(
        cfg, shape, single_device_mesh(), RULES_FSDP_TP,
        TrainLoopConfig(
            steps=args.steps,
            ckpt_every=max(args.steps // 5, 25),
            ckpt_dir=args.ckpt_dir,
            log_every=10,
            metrics_path=str(Path(args.ckpt_dir) / "metrics.jsonl"),
        ),
        opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
    )
    out = loop.run()
    first = [r.loss for r in loop.records[:10]]
    last = [r.loss for r in loop.records[-10:]]
    print(json.dumps({
        "final_step": out["final_step"],
        "loss_first10": sum(first) / max(len(first), 1),
        "loss_last10": sum(last) / max(len(last), 1),
        "straggler_events": out["straggler_events"],
    }, indent=1))


if __name__ == "__main__":
    main()
