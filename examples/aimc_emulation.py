"""AIMC emulation study (paper SS VI): how PCM-style device noise degrades
inference, measured on the INT8 ResNet and an assigned LM.

    PYTHONPATH=src python examples/aimc_emulation.py

For each noise scale, the NIU injects fresh noise instances per inference
round (read-modify-write of the weight regions, as the hardware NIU does)
and we report output SNR and decision flips -- the accuracy-assessment
loop the paper's emulator is designed for.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.aimc import AIMCNoiseModel, NoiseInjectionUnit, snr_db
from repro.models import api as model_api
from repro.models import resnet


def resnet_study():
    print("== ResNet-18 (int8, reduced 28x28 input) ==")
    params = resnet.init_params(18, jax.random.PRNGKey(0), num_classes=100)
    rng = np.random.default_rng(0)
    imgs = [
        jnp.asarray(rng.integers(-100, 100, (28, 28, 3), dtype=np.int8))
        for _ in range(4)
    ]
    clean = [np.asarray(resnet.forward_int8(18, params, im)) for im in imgs]

    for scale in (0.0, 0.05, 0.1, 0.3):
        model = AIMCNoiseModel(prog_noise_scale=scale, read_noise_scale=scale / 5)
        if scale == 0.0:
            flips, snrs = 0, float("inf")
        else:
            niu = NoiseInjectionUnit(params, model,
                                     target_filter=lambda p, l: str(p[-1]) == "'w'"
                                     or "w" == str(getattr(p[-1], "key", "")))
            flips = 0
            snrs = []
            for round_i, im in enumerate(imgs):
                noisy_params = niu.refresh(jax.random.PRNGKey(round_i + 1))
                out = np.asarray(resnet.forward_int8(18, noisy_params, im))
                flips += int(np.argmax(out) != np.argmax(clean[round_i]))
                snrs.append(float(snr_db(jnp.asarray(clean[round_i], jnp.float32),
                                         jnp.asarray(out, jnp.float32))))
            snrs = np.mean(snrs)
        print(f"  prog_noise={scale:4.2f}: top1 flips {flips}/4, "
              f"logit SNR {snrs if np.isfinite(snrs) else float('inf'):.1f} dB")


def lm_study():
    print("== olmo-1b (smoke) ==")
    cfg = smoke_variant(get_config("olmo-1b"))
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 24)), jnp.int32)
    clean, _ = api.prefill(cfg, params, {"tokens": toks})
    clean = np.asarray(clean, np.float32)

    for scale in (0.02, 0.1, 0.3):
        niu = NoiseInjectionUnit(params, AIMCNoiseModel(prog_noise_scale=scale))
        outs = []
        for r in range(3):   # three inference rounds, fresh noise each
            noisy = niu.refresh(jax.random.PRNGKey(100 + r))
            l, _ = api.prefill(cfg, noisy, {"tokens": toks})
            outs.append(np.asarray(l, np.float32))
        flip = np.mean([np.argmax(o) != np.argmax(clean) for o in outs])
        snr = np.mean([
            float(snr_db(jnp.asarray(clean), jnp.asarray(o))) for o in outs
        ])
        print(f"  prog_noise={scale:4.2f}: greedy-token flip rate {flip:.2f}, "
              f"logit SNR {snr:.1f} dB over 3 rounds")


if __name__ == "__main__":
    resnet_study()
    lm_study()
