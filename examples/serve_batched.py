"""End-to-end serving driver (the paper's kind is inference acceleration):
batched requests against an assigned architecture with continuous batching,
the paper's weight-streaming schedule, and optional AIMC noise emulation.

    PYTHONPATH=src python examples/serve_batched.py \
        --arch mamba2-780m --requests 12 --max-new 16 [--aimc] [--full]

With --full the unreduced config is used (slow on CPU; default is the
reduced same-family smoke config).
"""
import argparse
import time

import numpy as np

import jax

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.core.aimc import AIMCNoiseModel
from repro.core.pu import host_offload_config
from repro.models import api as model_api
from repro.runtime.serving import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--aimc", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = smoke_variant(cfg)
    api = model_api.get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    engine = ServingEngine(
        cfg,
        params,
        ServeConfig(
            max_batch=args.max_batch,
            max_len=args.prompt_len + args.max_new + 8,
            max_new_tokens=args.max_new,
            stream_pu=host_offload_config(),
            aimc=AIMCNoiseModel() if args.aimc else None,
        ),
    )

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        engine.submit(rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32))
    done = engine.run_until_drained()
    dt = time.perf_counter() - t0

    stats = engine.stats()
    print(f"arch={args.arch} ({'full' if args.full else 'smoke'}), "
          f"{len(done)}/{args.requests} requests in {dt:.1f}s")
    print(f"  tokens: {stats['tokens']:.0f}  ({stats['tokens']/dt:.1f} tok/s, "
          f"mean TTFT {stats['mean_ttft_s']*1e3:.0f} ms)")
    print(f"  engine rounds: {stats['rounds']:.0f}, "
          f"AIMC={'on' if args.aimc else 'off'}")
    if engine.streaming_plan:
        s = engine.streaming_plan.summary()
        print(f"  weight streaming: {s['tiles']:.0f} tiles, "
              f"baseline stall {s['baseline_stall_s']*1e3:.2f} ms -> "
              f"adaptive {s['adaptive_stall_s']*1e3:.2f} ms "
              f"(util {s['adaptive_util']:.1%})")
    sample = done[0]
    print(f"  sample generation (uid 0): {sample.out_tokens}")


if __name__ == "__main__":
    main()
